"""Deployable serving driver for the semantic-SQL engine.

Default mode runs the in-process path: bind a demo catalog, register an
oracle-backed model (playing the remote-API role), and execute the
paper's core query shapes through ``IPDB.sql``.

``--frontdoor`` starts the HTTP serving tier instead: an asyncio front
door streaming NDJSON chunks over localhost, driven by two tenants of
``FrontDoorClient`` sessions so the fair-sharing gate, admission
control, and per-session stats are exercised end to end.  Add
``--hold`` to keep the server up afterwards for manual curl sessions:

    PYTHONPATH=src python launch/serve.py --frontdoor [--hold] \
        [--port 8080] [--sessions 3] [--rows 96]

    curl -N -X POST localhost:8080/query \
        -d '{"sql": "SELECT ...", "tenant": "me"}'
    curl localhost:8080/stats
"""
import argparse
import sys
import threading
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent.parent / "src"))

from repro.core.database import IPDB
from repro.frontdoor import FrontDoor, FrontDoorClient, QueryRejected
from repro.relational.table import Table


def build_db(rows: int) -> IPDB:
    db = IPDB()
    cats = ["CPU", "Motherboard", "PSU", "GPU"]
    db.register_table("Product", Table.from_rows([
        {"name": f"part-{i:04d}", "category": cats[i % len(cats)],
         "price": 40.0 + 7.0 * (i % 50)} for i in range(rows)]))

    def orc(instruction, rws):
        return [{"vendor": ["Intel", "AMD", "ASUS", "MSI"][
                    sum(map(ord, str(r.get("name", "")))) % 4],
                 "budget": float(r.get("price", 0.0)) < 150.0}
                for r in rws]

    db.register_oracle("catalog", orc)
    db.sql("CREATE LLM MODEL o4mini PATH 'oracle:catalog' ON PROMPT "
           "API 'https://api.openai.com/v1/'")
    db.set_option("chunk_size", 16)
    return db


def run_inprocess(rows: int) -> int:
    db = build_db(rows)
    print("== in-process: semantic projection ==")
    r = db.sql("SELECT name, vendor FROM LLM o4mini (PROMPT "
               "'extract the {vendor VARCHAR} from {{name}}', Product)")
    print(r.table.head_repr())
    print(f"stats: calls={r.stats.llm_calls} tokens={r.stats.tokens}\n")
    print("== in-process: selection with predict pull-up ==")
    r = db.sql("SELECT name, price FROM Product WHERE LLM o4mini (PROMPT "
               "'is {{name}} a {budget BOOLEAN} part?') = TRUE "
               "AND category = 'PSU'")
    print(r.table.head_repr())
    print(f"stats: calls={r.stats.llm_calls} (only PSUs inferred)")
    return 0


def drive_frontdoor(fd: FrontDoor, sessions: int) -> None:
    """Two tenants over the HTTP path: `batch` streams full-table
    projections on several concurrent sessions while `interactive` fires
    point queries; per-tenant latency shows the fair gate at work."""
    cli = FrontDoorClient(fd.host, fd.port)
    lat = {"batch": [], "interactive": []}
    lock = threading.Lock()

    def issue(tenant: str, sql: str) -> None:
        t0 = time.time()
        try:
            res = cli.query(sql, tenant=tenant).result()
        except QueryRejected as e:
            print(f"  [{tenant}] rejected: {e.payload}")
            return
        with lock:
            lat[tenant].append(time.time() - t0)
        print(f"  [{tenant}] {res['rows']} rows ({res['status']}) in "
              f"{lat[tenant][-1]*1e3:.0f}ms "
              f"(dispatch_batches={res['stats']['dispatch_batches']})")

    big = ("SELECT name, LLM o4mini (PROMPT 'extract the {vendor VARCHAR}"
           " from {{name}}') AS vendor FROM Product")
    small = ("SELECT name, price FROM Product WHERE LLM o4mini (PROMPT "
             "'is {{name}} a {budget BOOLEAN} part?') = TRUE LIMIT 4")
    threads = [threading.Thread(target=issue, args=("batch", big))
               for _ in range(sessions)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    for _ in range(3):
        issue("interactive", small)
    for t in threads:
        t.join()
    for tenant, xs in lat.items():
        if xs:
            print(f"  {tenant}: n={len(xs)} "
                  f"mean={sum(xs)/len(xs)*1e3:.0f}ms "
                  f"max={max(xs)*1e3:.0f}ms")
    print(f"  server: {cli.server_stats()}")


def run_frontdoor(args) -> int:
    db = build_db(args.rows)
    with db, FrontDoor(db, host="127.0.0.1", port=args.port,
                       max_sessions=args.sessions + 1,
                       max_queued=2 * (args.sessions + 1),
                       tenant_weights={"interactive": 2.0}) as fd:
        print(f"front door listening on http://{fd.host}:{fd.port} "
              f"(max_sessions={fd.max_sessions}, gate={type(fd.gate).__name__})")
        print(f"== driving {args.sessions} batch sessions + "
              "3 interactive point queries ==")
        drive_frontdoor(fd, args.sessions)
        if args.hold:
            print("holding for manual sessions — Ctrl-C to stop")
            try:
                while True:
                    time.sleep(1.0)
            except KeyboardInterrupt:
                print("shutting down")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--frontdoor", action="store_true",
                    help="serve over the HTTP front door instead of "
                         "in-process")
    ap.add_argument("--port", type=int, default=0,
                    help="front-door port (0 = ephemeral)")
    ap.add_argument("--sessions", type=int, default=3,
                    help="concurrent batch-tenant sessions to drive")
    ap.add_argument("--rows", type=int, default=96,
                    help="demo table size")
    ap.add_argument("--hold", action="store_true",
                    help="keep the front door up after the demo drive")
    args = ap.parse_args(argv)
    if args.frontdoor:
        return run_frontdoor(args)
    return run_inprocess(args.rows)


if __name__ == "__main__":
    sys.exit(main())
