"""Deterministic concurrency harness for per-backend worker-pool dispatch.

The contract under test (see core/service.py): rows, ExecStats and EXPLAIN
output are byte-identical regardless of `dispatch_workers`, of speculative
flush timing, and of which worker thread finishes first.  Scripted
backends (tests/helpers.py) make answers and modeled latencies pure
functions of the prompt, and gate hooks force worst-case interleavings on
purpose.  Also covers: flush prioritization (smallest expected makespan
first, no starvation), PromptCache/StatisticsStore thread safety under
contention, and service lifecycle (drain-during-inflight, cancel after a
flush started, clean shutdown with non-empty queues and no leaked
threads).
"""
import dataclasses
import re
import threading
import time

import pytest

from helpers import LatencyScriptedPredictor, register_scripted
from hypothesis_compat import given, settings, st

from repro.core.database import IPDB
from repro.core.predict import _MISS, PromptCache
from repro.core.service import InferenceRequest, InferenceService
from repro.core.stats import CostModel, StatisticsStore
from repro.relational.table import Table


def echo_answers(instruction, rows):
    out = []
    for r in rows:
        joined = " ".join(f"{k}={v}" for k, v in sorted(r.items()))
        h = sum(map(ord, joined))
        out.append({"tag": f"t{h % 5}", "flag": h % 3 == 0,
                    "score": h % 7})
    return out


def make_db(*, chunk=2048, inflight=1, workers=1, max_dispatch=0,
            fast=None, slow=None, n=12):
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"row {i}"} for i in range(n)]))
    fast = fast if fast is not None else \
        LatencyScriptedPredictor(echo_answers, base_latency_s=0.25)
    slow = slow if slow is not None else \
        LatencyScriptedPredictor(echo_answers, base_latency_s=1.0)
    register_scripted(db, "fastm", fast)
    register_scripted(db, "slowm", slow)
    db.set_option("chunk_size", chunk)
    db.set_option("inflight_windows", inflight)
    db.set_option("dispatch_workers", workers)
    db.set_option("max_dispatch_calls", max_dispatch)
    db.set_option("batch_size", 4)
    return db, fast, slow


Q_TWO_MODELS = ("SELECT a, LLM fastm (PROMPT 'one {tag VARCHAR} of "
                "{{txt}}') AS t1, LLM slowm (PROMPT 'two {score INTEGER} "
                "of {{txt}}') AS t2 FROM T")
Q_STACKED_SELECTS = ("SELECT a FROM T WHERE LLM fastm (PROMPT 'p "
                     "{flag BOOLEAN} of {{txt}}') = TRUE AND LLM slowm "
                     "(PROMPT 'q {flag BOOLEAN} of {{txt}}') = TRUE")


def _stats_dict(stats):
    d = dataclasses.asdict(stats)
    d.pop("wall_s")                    # real time: the one honest exception
    return d


# EXPLAIN prints the configured worker count in `-- dispatch --` (the
# configuration under test) and the binder's process-global __p_<n>
# column counter (naming, not behavior); normalize both, everything else
# must match byte-for-byte
_WORKERS_RE = re.compile(r"dispatch_workers=\d+")
_PCOUNT_RE = re.compile(r"__p_\d+_")


def _norm_explain(text: str) -> str:
    return _PCOUNT_RE.sub("__p_N_", _WORKERS_RE.sub("dispatch_workers=N",
                                                    text))


def _req(ex, prompt, *, instruction="i", dedup=True, stats_key=None):
    return InferenceRequest(
        model_name="m", instruction=instruction, prompt=prompt,
        schema=(("x", "INTEGER"),), num_rows=1, executor=ex,
        dedup=dedup, stats_key=stats_key)


# ---------------------------------------------------------------------------
# bit-identical results across the dispatch matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("query", [Q_TWO_MODELS, Q_STACKED_SELECTS])
def test_bit_identical_across_dispatch_matrix(query):
    """dispatch_workers ∈ {1, 2, 4} × inflight_windows ∈ {1, 4} × chunk
    sizes {1, 3, 2048}: rows are identical across the whole matrix, and
    for each (chunk, inflight) point the ExecStats and EXPLAIN output are
    bit-identical across worker counts — concurrency is pure mechanism."""
    reference_rows = None
    per_config = {}
    for chunk in (1, 3, 2048):
        for inflight in (1, 4):
            for workers in (1, 2, 4):
                db, _, _ = make_db(chunk=chunk, inflight=inflight,
                                   workers=workers)
                explain = _norm_explain(db.explain(query))
                r = db.sql(query)
                db.close()
                rows = r.table.rows()
                if reference_rows is None:
                    reference_rows = rows
                assert rows == reference_rows, \
                    f"rows diverged at chunk={chunk} inflight={inflight} " \
                    f"workers={workers}"
                key = (chunk, inflight)
                entry = (_stats_dict(r.stats), explain)
                if key not in per_config:
                    per_config[key] = entry
                else:
                    assert entry == per_config[key], \
                        f"stats/explain diverged at chunk={chunk} " \
                        f"inflight={inflight} workers={workers}"


def test_barrier_forced_concurrent_dispatch_identical_results():
    """Worst-case interleaving, forced: both backends' dispatch batches
    are held at a barrier until BOTH are mid-flight, and the slow backend
    finishes last.  Handle results must still resolve per-request
    correctly, on worker threads, with the same answers a synchronous
    service produces."""
    sync_ex = LatencyScriptedPredictor(echo_answers)
    svc_sync = InferenceService()
    sync_handles = svc_sync.submit(
        [_req(sync_ex, f"p{i}", instruction=f"i{i % 2}") for i in range(6)])
    svc_sync.flush()
    expected = [h.result().text for h in sync_handles]

    barrier = threading.Barrier(2, timeout=30)

    def gate(pred, prompts):
        barrier.wait()

    fast = LatencyScriptedPredictor(echo_answers, gate=gate)
    slow = LatencyScriptedPredictor(echo_answers, gate=gate,
                                    sleep_per_call_s=0.02)
    for ex in (fast, slow):
        ex.configure({"dispatch_workers": 4})
    svc = InferenceService()
    handles = []
    for i in range(6):
        ex = fast if i % 2 == 0 else slow
        h, _ = svc.submit_one(_req(ex, f"p{i}", instruction=f"i{i % 2}"))
        handles.append(h)
    svc.flush()                        # both queues scheduled concurrently
    got = [h.result().text for h in handles]
    svc.shutdown()
    assert got == expected
    assert not barrier.broken          # both dispatches really overlapped
    for ex in (fast, slow):
        assert len(ex.dispatch_log) == 1
        assert all("ipdb-dispatch" in t for t, _ in ex.dispatch_log)


def test_speculative_kick_preserves_rows_and_stats_in_sql_pipeline():
    """With max_dispatch set, operators kick() complete slices into the
    background after every submit.  Batch composition is invariant, so the
    full SQL pipeline produces identical rows AND identical ExecStats vs
    the synchronous single-worker run — while the dispatch log proves the
    work actually ran early, off the main thread."""
    ref_db, _, _ = make_db(chunk=3, inflight=4, workers=1, max_dispatch=1)
    ref = ref_db.sql(Q_TWO_MODELS)
    ref_db.close()

    db, fast, slow = make_db(chunk=3, inflight=4, workers=4, max_dispatch=1)
    r = db.sql(Q_TWO_MODELS)
    spec_batches = db.inference_service.stats.speculative_batches
    db.close()

    assert r.table.rows() == ref.table.rows()
    assert _stats_dict(r.stats) == _stats_dict(ref.stats)
    assert spec_batches > 0            # kick() really dispatched early
    worker_dispatches = [t for t, _ in fast.dispatch_log + slow.dispatch_log
                         if "ipdb-dispatch" in t]
    assert worker_dispatches           # ...and off the main thread


def test_speculative_kick_keeps_inflight_dedup_invariant():
    """Cross-window duplicate prompts + speculation: under synchronous
    dispatch the second window joins the first's still-queued handle.  A
    speculative kick dispatches that handle early, but it must stay
    joinable until the next flush — whether or not its batch already
    finished — so llm_calls and inflight_dedup_hits are identical across
    worker counts even for duplicate-heavy workloads."""
    results = {}
    for workers in (1, 4):
        db = IPDB()
        # windows of 3 rows render to identical marshaled prompts
        db.register_table("T", Table.from_rows(
            [{"a": i, "txt": f"dup{i % 3}"} for i in range(9)]))
        pred = LatencyScriptedPredictor(echo_answers)
        register_scripted(db, "m", pred)
        db.set_option("chunk_size", 3)
        db.set_option("inflight_windows", 3)
        db.set_option("dispatch_workers", workers)
        db.set_option("max_dispatch_calls", 1)
        db.set_option("batch_size", 4)
        r = db.sql("SELECT a, LLM m (PROMPT 'get {tag VARCHAR} of "
                   "{{txt}}') AS t FROM T")
        db.close()
        results[workers] = (r.table.rows(), _stats_dict(r.stats))
    assert results[1] == results[4]
    # the workload really exercised the dedup path
    assert results[1][1]["inflight_dedup_hits"] > 0
    assert results[1][1]["llm_calls"] == 1


def test_speculative_kick_unit_semantics():
    """kick() starts only the complete max_dispatch-sized slices a later
    flush would dispatch anyway; the trailing partial slice stays queued.
    No-ops: unbounded max_dispatch, synchronous backends, speculation
    disabled."""
    ex = LatencyScriptedPredictor(echo_answers)
    ex.configure({"dispatch_workers": 4})
    svc = InferenceService(max_dispatch=2)
    handles = svc.submit([_req(ex, f"p{i}") for i in range(5)])
    svc.kick()
    assert svc.wait_idle(timeout=30)
    assert [h.done for h in handles] == [True] * 4 + [False]
    assert svc.pending == 1
    assert svc.stats.speculative_batches == 2
    assert sorted(n for _, n in ex.dispatch_log) == [2, 2]
    svc.flush()                        # remainder dispatches normally
    assert svc.wait_idle(timeout=30)
    assert all(h.done for h in handles)
    assert sorted(n for _, n in ex.dispatch_log) == [1, 2, 2]
    svc.shutdown()

    # no-op cases: nothing may be dispatched by kick()
    for make in (
            lambda: (InferenceService(max_dispatch=0), 4),   # unbounded
            lambda: (InferenceService(max_dispatch=2), 1),   # sync backend
    ):
        svc2, workers = make()
        ex2 = LatencyScriptedPredictor(echo_answers)
        ex2.configure({"dispatch_workers": workers})
        svc2.submit([_req(ex2, f"p{i}") for i in range(4)])
        svc2.kick()
        assert svc2.wait_idle(timeout=5) and not ex2.dispatch_log
        assert svc2.pending == 4
        svc2.shutdown()
    svc3 = InferenceService(max_dispatch=2, speculative=False)
    ex3 = LatencyScriptedPredictor(echo_answers)
    ex3.configure({"dispatch_workers": 4})
    svc3.submit([_req(ex3, f"p{i}") for i in range(4)])
    svc3.kick()
    assert svc3.wait_idle(timeout=5) and not ex3.dispatch_log
    svc3.shutdown()


def test_async_executor_failure_surfaces_on_result():
    """A backend raising on a worker thread must surface the exception at
    result() on the submitting thread, and must not poison the in-flight
    map (later identical submits re-dispatch)."""

    class Boom(LatencyScriptedPredictor):
        def __init__(self, *a, **kw):
            super().__init__(*a, **kw)
            self.fail = True

        def complete_many(self, prompts, *a, **kw):
            if self.fail:
                self.fail = False
                raise RuntimeError("backend down")
            return super().complete_many(prompts, *a, **kw)

    ex = Boom(echo_answers)
    ex.configure({"dispatch_workers": 4})
    svc = InferenceService()
    h, _ = svc.submit_one(_req(ex, "a"))
    svc.flush()                        # scheduled async; failure is remote
    with pytest.raises(RuntimeError, match="backend down"):
        h.result()
    h2, owned = svc.submit_one(_req(ex, "a"))
    assert owned                       # fresh handle, not a join
    svc.flush()
    assert h2.result().text
    svc.shutdown()


def test_inline_failure_does_not_strand_other_queues():
    """A synchronous backend raising mid-flush must not strand the other
    queues popped in the same flush: they still dispatch, the flush
    re-raises the failure, and the failed handle reports the real error
    (not a bogus 'cancelled')."""

    class Boom(LatencyScriptedPredictor):
        def complete_many(self, prompts, *a, **kw):
            raise RuntimeError("backend down")

    boom = Boom(echo_answers)
    ok = LatencyScriptedPredictor(echo_answers)
    svc = InferenceService()
    hb, _ = svc.submit_one(_req(boom, "a"))
    hg, _ = svc.submit_one(_req(ok, "b", instruction="other"))
    with pytest.raises(RuntimeError, match="backend down"):
        svc.flush()
    assert hg.done and hg.result().text
    with pytest.raises(RuntimeError, match="backend down"):
        hb.result()


# ---------------------------------------------------------------------------
# flush prioritization
# ---------------------------------------------------------------------------
def _priority_fixture(queue_specs):
    """Build a service + cost model with one queue per (n_calls, mean
    latency) spec; returns (svc, cost_model, specs)."""
    store = StatisticsStore()
    cm = CostModel(store, {"n_threads": 4})
    svc = InferenceService(stats_store=store, cost_model=cm)
    ex = LatencyScriptedPredictor(echo_answers)
    for qi, (n, lat) in enumerate(queue_specs):
        skey = ("m", f"instr{qi}")
        store.record_call(skey, 10, 5, lat)   # observed mean latency = lat
        for j in range(n):
            svc.submit_one(_req(ex, f"p{qi}.{j}",
                                instruction=f"instr{qi}", stats_key=skey))
    return svc, cm


def _check_priority(queue_specs):
    svc, cm = _priority_fixture(queue_specs)
    got = [qkey[1] for qkey in svc.prioritized()]
    expected = sorted(
        range(len(queue_specs)),
        key=lambda qi: (cm.queue_makespan(("m", f"instr{qi}"),
                                          queue_specs[qi][0]), qi))
    assert got == [f"instr{qi}" for qi in expected]
    svc.flush()                        # prioritization never starves:
    assert svc.pending == 0            # one flush drains every queue
    svc.shutdown()


def test_flush_priority_smallest_makespan_first_fixed_cases():
    _check_priority([(3, 2.0), (1, 0.125), (4, 0.25)])
    _check_priority([(2, 1.0), (2, 1.0), (1, 1.0)])   # stable tie-break
    _check_priority([(5, 0.5)])


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 5),
                          st.floats(0.05, 4.0, allow_nan=False)),
                min_size=1, max_size=6))
def test_flush_priority_matches_cost_model_sort(queue_specs):
    _check_priority(queue_specs)


# ---------------------------------------------------------------------------
# shared-state thread safety under contention
# ---------------------------------------------------------------------------
def test_prompt_cache_and_stats_store_stress():
    """8 threads hammer the LRU PromptCache (eviction churn over a key
    space larger than capacity, so touch-on-get races the delete) and the
    StatisticsStore (read-modify-write counters).  Totals must be exact:
    any lost update or KeyError fails the test."""
    pc = PromptCache(max_entries=64)
    store = StatisticsStore()
    n_threads, n_iter = 8, 400
    skey = ("m", "instr")
    errors = []
    start = threading.Barrier(n_threads)

    def worker(tid):
        try:
            start.wait()
            for i in range(n_iter):
                k = ("k", (tid * 31 + i) % 97)
                if pc.get(k) is _MISS:
                    pc.put(k, [i])
                store.record_call(skey, 3, 2, 0.25)
                store.record_predicate(skey, 4, 2)
                if i % 7 == 0:
                    store.record_retry(skey)
                if i % 11 == 0:
                    store.record_fallback(skey)
        except Exception as e:          # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    total = n_threads * n_iter
    rec = store.get(skey)
    assert rec.calls == total
    assert rec.in_tokens == 3 * total and rec.out_tokens == 2 * total
    assert rec.latency_s == 0.25 * total          # exact binary fraction
    assert rec.rows_in == 4 * total and rec.rows_passed == 2 * total
    assert rec.retries == n_threads * len(range(0, n_iter, 7))
    assert rec.fallbacks == n_threads * len(range(0, n_iter, 11))
    assert len(pc) <= 64
    assert pc.hits + pc.misses == total


# ---------------------------------------------------------------------------
# service lifecycle
# ---------------------------------------------------------------------------
def test_drain_waits_for_inflight_background_batches():
    started = threading.Event()
    release = threading.Event()

    def gate(pred, prompts):
        started.set()
        assert release.wait(30)

    ex = LatencyScriptedPredictor(echo_answers, gate=gate)
    ex.configure({"dispatch_workers": 4})
    svc = InferenceService()
    handles = svc.submit([_req(ex, f"p{i}") for i in range(3)])
    svc.flush()
    assert started.wait(30)
    assert svc.inflight_batches >= 1
    threading.Timer(0.1, release.set).start()
    svc.drain()                        # must block until the batch ends
    assert release.is_set()
    assert all(h.done for h in handles)
    assert svc.inflight_batches == 0
    svc.shutdown()


def test_cancel_after_flush_started_is_refused():
    """Cancelling a handle whose dispatch batch already started cannot
    recall it: cancel returns False, the batch completes, the result is
    still delivered.  A sibling handle still queued cancels normally."""
    hold = threading.Event()

    def gate(pred, prompts):
        assert hold.wait(30)

    ex = LatencyScriptedPredictor(echo_answers, gate=gate)
    ex.configure({"dispatch_workers": 4})
    svc = InferenceService(max_dispatch=2)
    ha, _ = svc.submit_one(_req(ex, "a"))
    hb, _ = svc.submit_one(_req(ex, "b"))
    hc, _ = svc.submit_one(_req(ex, "c", instruction="other"))
    svc.kick()                         # (a, b) now mid-flight, held at gate
    assert svc.inflight_batches == 1
    assert not svc.cancel(ha)          # flush already started: refused
    assert svc.cancel(hc)              # still queued: removable
    hold.set()
    assert ha.result().text and hb.result().text
    with pytest.raises(RuntimeError):
        hc.result()
    svc.shutdown()


def test_shutdown_with_nonempty_queues_leaks_no_threads():
    base_threads = threading.active_count()
    ex = LatencyScriptedPredictor(echo_answers)
    ex.configure({"dispatch_workers": 4})
    svc = InferenceService()
    # one async round so pool threads actually exist...
    svc.submit([_req(ex, f"w{i}") for i in range(4)])
    svc.flush()
    assert svc.wait_idle(timeout=30)
    assert threading.active_count() > base_threads
    # ...then leave fresh requests queued and shut down hard
    handles = svc.submit([_req(ex, f"q{i}") for i in range(3)])
    svc.shutdown(cancel_pending=True)
    for h in handles:
        with pytest.raises(RuntimeError):
            h.result()
    deadline = time.time() + 10
    while threading.active_count() > base_threads and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= base_threads, "leaked worker threads"
    svc.shutdown()                     # idempotent
    with pytest.raises(RuntimeError):
        svc.submit_one(_req(ex, "late"))


def test_shutdown_releases_lane_backlog_without_hanging():
    """Hard shutdown while a lane has MORE scheduled batches than workers:
    the running batches complete (a started dispatch is never interrupted),
    the backlog that will never be pumped resolves to a shutdown error —
    and shutdown itself does not hang on the orphaned accounting."""
    hold = threading.Event()

    def gate(pred, prompts):
        assert hold.wait(30)

    ex = LatencyScriptedPredictor(echo_answers, gate=gate, max_concurrency=2)
    ex.configure({"dispatch_workers": 2})
    svc = InferenceService(max_dispatch=1)
    handles = svc.submit([_req(ex, f"p{i}") for i in range(5)])
    svc.kick()                 # 2 running (held at gate), 3 lane backlog
    assert svc.inflight_batches == 5
    threading.Timer(0.1, hold.set).start()
    svc.shutdown(cancel_pending=True)      # must not hang
    assert handles[0].result().text and handles[1].result().text
    for h in handles[2:]:
        with pytest.raises(RuntimeError, match="shut down"):
            h.result()


def test_graceful_shutdown_drains_queued_work():
    ex = LatencyScriptedPredictor(echo_answers)
    ex.configure({"dispatch_workers": 2})
    svc = InferenceService()
    handles = svc.submit([_req(ex, f"p{i}") for i in range(3)])
    svc.shutdown()                     # default: drain, then close
    assert all(h.done for h in handles)
    assert all(h.result().text for h in handles)


def test_database_close_joins_dispatch_threads():
    base_threads = threading.active_count()
    with make_db(workers=4)[0] as db:
        r = db.sql(Q_TWO_MODELS)
        assert len(r.table) == 12
    deadline = time.time() + 10
    while threading.active_count() > base_threads and time.time() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= base_threads
