"""End-to-end and unit coverage for calibrated model cascades.

The contract under test (core/cascade.py):

  * per-answer confidence plumbing — oracle/tabular/scripted backends
    populate `CallResult.confidences` from the reserved "__confidence__"
    answer key; text-only backends degrade to all-1.0;
  * correctness — with a perfect proxy the cascade's rows are
    byte-identical to the direct route's; rows in the escalation band are
    resolved by the expensive backend, so a proxy that is wrong ONLY
    where it is unconfident still yields direct-route rows;
  * contracts — a proxy that cannot meet the declared precision target
    calibrates to `unachievable` and the optimizer routes the operator
    direct (zero proxy calls);
  * accounting — observed predicate selectivity under a cascade matches
    direct execution exactly (final verdicts, base key, recorded once:
    the stage-tag split in service.staged_key);
  * determinism — rows, ExecStats and EXPLAIN are bit-identical across
    dispatch_workers {1, 2, 4} (the PR 4 concurrency contract extends to
    two-stage routing).

Scripted backends keep every modeled latency an exact binary fraction so
float sums are order-independent; confidences and verdicts are pure
functions of the row text, so calibration snapshots and audit schedules
cannot depend on batch composition.
"""
import dataclasses
import json
import re

import pytest

from helpers import LatencyScriptedPredictor, register_scripted

from repro.core.cascade import CascadePredictor, confidences_of, row_hash
from repro.core.database import IPDB
from repro.core.executors import CallResult, OracleExecutor, TabularExecutor
from repro.core.service import staged_key
from repro.core.stats import StatisticsStore
from repro.relational.table import Table


# ---------------------------------------------------------------------------
# scripted task: flag(i) = i % 2 == 0, i recovered from the row text
# ---------------------------------------------------------------------------
def _i_of(row) -> int:
    try:
        return int(str(row.get("txt", "0")).split()[-1])
    except ValueError:
        return 0


def truth_answers(instruction, rows):
    return [{"flag": _i_of(r) % 2 == 0} for r in rows]


def perfect_proxy(instruction, rows):
    """Always right, uniformly confident."""
    return [{"flag": _i_of(r) % 2 == 0, "__confidence__": 0.9}
            for r in rows]


def wrong_proxy(instruction, rows):
    """Always wrong, confidently — no threshold can meet any contract."""
    return [{"flag": _i_of(r) % 2 != 0, "__confidence__": 0.9}
            for r in rows]


def banded_proxy(instruction, rows):
    """Wrong exactly where unconfident: every i % 4 == 0 row gets a
    flipped verdict at confidence 0.3, the rest are right at 0.95 — so a
    0.95-precision contract calibrates to tau = 0.95 and the low-band
    rows escalate."""
    out = []
    for r in rows:
        i = _i_of(r)
        if i % 4 == 0:
            out.append({"flag": i % 2 != 0, "__confidence__": 0.3})
        else:
            out.append({"flag": i % 2 == 0, "__confidence__": 0.95})
    return out


PROMPT = "keep {flag BOOLEAN} of {{txt}}"
WITH = "WITH (cascade_proxy=proxym, cascade_target_precision=0.95)"
# slice A (a < 24) warms the calibration reservoir; slice B (a >= 24) is
# disjoint, so measurement prompts never hit the cross-query PromptCache
Q_WARM = (f"SELECT a FROM T WHERE a < 24 AND "
          f"LLM bigm (PROMPT '{PROMPT}') {WITH} = TRUE")
Q_MEASURE = (f"SELECT a FROM T WHERE a >= 24 AND "
             f"LLM bigm (PROMPT '{PROMPT}') {WITH} = TRUE")
Q_DIRECT = (f"SELECT a FROM T WHERE a >= 24 AND "
            f"LLM bigm (PROMPT '{PROMPT}') = TRUE")


def make_db(proxy_fn, *, workers=1, n=48):
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"row {i}"} for i in range(n)]))
    # exact binary-fraction latencies → order-independent float sums
    expensive = LatencyScriptedPredictor(truth_answers, base_latency_s=1.0)
    register_scripted(db, "bigm", expensive)
    if proxy_fn is not None:
        proxy = LatencyScriptedPredictor(proxy_fn, base_latency_s=0.0625)
        register_scripted(db, "proxym", proxy)
    db.set_option("dispatch_workers", workers)
    db.set_option("batch_size", 16)
    return db


_WORKERS_RE = re.compile(r"dispatch_workers=\d+")
_PCOUNT_RE = re.compile(r"__p_\d+_")


def _norm_explain(text: str) -> str:
    return _PCOUNT_RE.sub("__p_N_", _WORKERS_RE.sub("dispatch_workers=N",
                                                    text))


def _stats_dict(stats):
    d = dataclasses.asdict(stats)
    d.pop("wall_s")
    return d


# ---------------------------------------------------------------------------
# satellite: per-answer confidence plumbing
# ---------------------------------------------------------------------------
def test_oracle_executor_populates_confidences():
    def oracle(instruction, rows):
        return [{"flag": True, "__confidence__": 0.7},
                {"flag": False, "__confidence__": 0.4}]

    ex = OracleExecutor(oracle)
    res = ex.complete("p", (("flag", "BOOLEAN"),), 3,
                      rows=[{"x": 1}, {"x": 2}, {"x": 3}], instruction="i")
    # two answered rows carry their scores; the padded third reads 0.0
    assert res.confidences == [0.7, 0.4, 0.0]
    # the reserved key never leaks into the serialized answer text
    assert "__confidence__" not in res.text
    objs = json.loads(res.text)
    assert [o["flag"] for o in objs] == [True, False, None]


def test_tabular_executor_populates_confidences():
    def predict(rows):
        return [{"y": r["x"] * 2, "__confidence__": 0.25 * r["x"]}
                for r in rows]

    ex = TabularExecutor(predict)
    res = ex.complete("", (("y", "INTEGER"),), 2,
                      rows=[{"x": 1}, {"x": 2}])
    assert res.confidences == [0.25, 0.5]
    many = ex.complete_many(["", ""], (("y", "INTEGER"),), [1, 2],
                            rows_list=[[{"x": 3}], [{"x": 1}, {"x": 2}]])
    assert many[0].confidences == [0.75]
    assert many[1].confidences == [0.25, 0.5]
    assert "__confidence__" not in many[1].text


def test_scripted_predictor_populates_confidences():
    ex = LatencyScriptedPredictor(perfect_proxy)
    res = ex.complete("p", (("flag", "BOOLEAN"),), 2,
                      rows=[{"txt": "row 1"}, {"txt": "row 2"}])
    assert res.confidences == [0.9, 0.9]


def test_confidences_of_text_only_fallback():
    # a backend with no score channel reads as uniformly confident
    assert confidences_of(CallResult("t", 1, 1, 0.0, 0.0), 3) == \
        [1.0, 1.0, 1.0]
    # short vectors pad with 0.0 (unanswered rows), long ones truncate
    r = CallResult("t", 1, 1, 0.0, 0.0, confidences=[0.5])
    assert confidences_of(r, 3) == [0.5, 0.0, 0.0]
    r = CallResult("t", 1, 1, 0.0, 0.0, confidences=[0.5, 0.6, 0.7])
    assert confidences_of(r, 2) == [0.5, 0.6]


def test_staged_key_tags_stage():
    assert staged_key(("m", "i"), "") == ("m", "i")
    assert staged_key(("m", "i"), "cascade") == ("m#cascade", "i")


# ---------------------------------------------------------------------------
# calibration unit tests (StatisticsStore.calibrate_cascade)
# ---------------------------------------------------------------------------
KEY = ("m", "instr")


def test_calibrate_cold_below_min_records():
    store = StatisticsStore()
    for h in range(5):
        store.record_cascade_agreement(KEY, h, 0.9, True, True)
    cal = store.calibrate_cascade(KEY, 0.9, min_records=8)
    assert cal.status == "cold"
    assert cal.tau_pos > 1.0 and cal.tau_neg > 1.0
    assert cal.escalation_rate == 1.0


def test_calibrate_ok_thresholds_maximize_coverage():
    store = StatisticsStore()
    # positive class: 10 agreeing records at 0.9, 2 disagreeing at 0.3 —
    # at target 0.95 any prefix reaching into the 0.3 records fails
    # (10/11 < 0.95), so tau_pos settles at 0.9
    for h in range(10):
        store.record_cascade_agreement(KEY, h, 0.9, True, True)
    for h in range(10, 12):
        store.record_cascade_agreement(KEY, h, 0.3, True, False)
    # negative class all agree at 0.6: tau_neg accepts everything
    for h in range(12, 20):
        store.record_cascade_agreement(KEY, h, 0.6, False, True)
    cal = store.calibrate_cascade(KEY, 0.95, min_records=8)
    assert cal.status == "ok"
    assert cal.tau_pos == pytest.approx(0.9)
    assert cal.tau_neg == pytest.approx(0.6)
    assert 0.0 <= cal.escalation_rate < 1.0
    assert cal.empirical_precision == pytest.approx(1.0)


def test_calibrate_unachievable_when_proxy_never_agrees():
    store = StatisticsStore()
    for h in range(20):
        store.record_cascade_agreement(KEY, h, 0.9, h % 2 == 0, False)
    cal = store.calibrate_cascade(KEY, 0.9, min_records=8)
    assert cal.status == "unachievable"
    assert cal.tau_pos > 1.0 and cal.tau_neg > 1.0
    assert cal.escalation_rate == 1.0


def test_calibrate_violated_by_failing_audits():
    store = StatisticsStore()
    for h in range(30):
        store.record_cascade_agreement(KEY, h, 0.9, True, True)
    # 16 audited acceptances all disagreed: the contract is broken even
    # though the (low-confidence) reservoir slice still calibrates
    for h in range(30, 46):
        store.record_cascade_agreement(KEY, h, 0.2, True, False,
                                       audited=True)
    cal = store.calibrate_cascade(KEY, 0.9, min_records=8)
    assert cal.status == "violated"
    assert cal.empirical_precision == pytest.approx(0.0)


def test_reservoir_eviction_keeps_smallest_hashes():
    store = StatisticsStore()
    for h in range(300):
        store.record_cascade_agreement(KEY, h, 0.5, True, True)
    rec = store.cascade_get(KEY)
    assert rec.n_records == 256
    assert max(rec.reservoir) == 255    # keep-smallest is order-free


# ---------------------------------------------------------------------------
# e2e: perfect proxy — byte-identical rows, expensive stage mostly idle
# ---------------------------------------------------------------------------
def test_perfect_proxy_rows_match_direct():
    direct_db = make_db(None)
    direct_rows = direct_db.sql(Q_DIRECT).table.rows()
    direct_db.close()

    db = make_db(perfect_proxy)
    warm = db.sql(Q_WARM)
    # cold calibration escalates everything: the bootstrap pays full
    # direct cost but buys the held-out evidence
    assert warm.stats.proxy_calls > 0
    assert warm.stats.escalated_rows == warm.stats.cascade_rows > 0

    r = db.sql(Q_MEASURE)
    assert r.table.rows() == direct_rows
    # calibrated route: the proxy resolves (nearly) everything — only
    # deterministic 1-in-16 audits still reach the expensive backend
    assert r.stats.proxy_calls > 0
    assert r.stats.cascade_rows == 24
    assert r.stats.escalated_rows < r.stats.cascade_rows / 2
    db.close()


def test_escalation_band_resolved_by_expensive_backend():
    direct_db = make_db(None)
    direct_rows = direct_db.sql(Q_DIRECT).table.rows()
    direct_db.close()

    db = make_db(banded_proxy)
    db.sql(Q_WARM)
    r = db.sql(Q_MEASURE)
    # the proxy is WRONG on every i % 4 == 0 row — but only at
    # confidence 0.3, below tau: those rows escalate and the expensive
    # backend's verdicts splice in, so the output still matches direct
    assert r.table.rows() == direct_rows
    assert r.stats.escalated_rows >= 6          # the 0.3-confidence band
    assert r.stats.escalated_rows < r.stats.cascade_rows
    assert r.stats.escalated_calls < r.stats.proxy_calls + 1
    db.close()


def test_unachievable_contract_routes_direct():
    direct_db = make_db(None)
    direct_rows = direct_db.sql(Q_DIRECT).table.rows()
    direct_db.close()

    db = make_db(wrong_proxy)
    warm = db.sql(Q_WARM)                       # records 100% disagreement
    assert warm.stats.escalated_rows == warm.stats.cascade_rows
    explain = db.explain(Q_MEASURE)
    assert "route=direct" in explain
    assert "status=unachievable" in explain
    r = db.sql(Q_MEASURE)
    # the optimizer fell back to the direct route: zero proxy calls, and
    # a confidently-wrong proxy cannot corrupt a single row
    assert r.stats.proxy_calls == 0
    assert r.stats.escalated_calls == 0
    assert r.table.rows() == direct_rows
    db.close()


# ---------------------------------------------------------------------------
# satellite: selectivity recorded once, matching direct execution
# ---------------------------------------------------------------------------
def test_cascade_selectivity_matches_direct():
    def observed(db):
        key = next(k for k in db.stats_store.keys() if k[0] == "bigm")
        rec = db.stats_store.get(key)
        return key, (rec.rows_in, rec.rows_passed)

    direct_db = make_db(None)
    direct_db.sql(Q_DIRECT.replace("a >= 24", "a < 24"))
    direct_db.sql(Q_DIRECT)
    key, direct_obs = observed(direct_db)
    direct_db.close()

    db = make_db(perfect_proxy)
    db.sql(Q_WARM)
    db.sql(Q_MEASURE)
    _, cascade_obs = observed(db)
    # final verdicts recorded exactly once on the BASE key: warm-cache
    # selectivity is indistinguishable from direct execution
    assert cascade_obs == direct_obs
    # the stage-tagged key carries call accounting only — never
    # predicate rows (that would double-count selectivity)
    tagged = db.stats_store.get(staged_key(key, "cascade"))
    assert tagged is not None and tagged.calls > 0
    assert (tagged.rows_in, tagged.rows_passed) == (0, 0)
    # proxy-stage calls land under the proxy's own key, where the cost
    # model's cascade estimate observes them
    prox = db.stats_store.get(("proxym", key[1]))
    assert prox is not None and prox.calls > 0
    db.close()


# ---------------------------------------------------------------------------
# e2e: EXPLAIN -- cascade -- section
# ---------------------------------------------------------------------------
def test_explain_shows_cascade_section():
    db = make_db(banded_proxy)
    cold = db.explain(Q_MEASURE)
    assert "-- cascade --" in cold
    assert "status=cold" in cold and "route=cascade" in cold
    assert "accept_pos>=" in cold and "accept_neg>=" in cold

    db.sql(Q_WARM)
    warm = db.explain(Q_MEASURE)
    assert "status=ok" in warm
    assert "target_precision=0.950" in warm
    assert "accept_pos>=0.950" in warm and "accept_neg>=0.950" in warm
    assert "est_rate=0.250" in warm             # the i % 4 == 0 band
    assert re.search(r"observed=rows=\d+/\d+", warm)
    db.close()


def test_explain_direct_query_reports_no_cascade():
    db = make_db(None)
    explain = db.explain(Q_DIRECT)
    assert "-- cascade --" in explain
    assert "(no cascaded operators)" in explain
    db.close()


# ---------------------------------------------------------------------------
# determinism: bit-identical rows/stats/EXPLAIN across dispatch workers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("proxy_fn", [perfect_proxy, banded_proxy],
                         ids=["perfect", "banded"])
def test_bit_identical_across_dispatch_workers(proxy_fn):
    reference = None
    for workers in (1, 2, 4):
        db = make_db(proxy_fn, workers=workers)
        db.sql(Q_WARM)
        explain = _norm_explain(db.explain(Q_MEASURE))
        r = db.sql(Q_MEASURE)
        db.close()
        entry = (r.table.rows(), _stats_dict(r.stats), explain)
        if reference is None:
            reference = entry
        assert entry == reference, f"diverged at workers={workers}"
    # sanity: the reference actually exercised the cascade
    assert reference[1]["proxy_calls"] > 0


# ---------------------------------------------------------------------------
# predictor-level: re-marshaled escalation batches
# ---------------------------------------------------------------------------
def test_cascade_predictor_remarshals_escalations():
    """Escalated rows from several prompts re-batch into full
    `batch_size`-row expensive prompts instead of per-row dribble."""
    store = StatisticsStore()
    key = ("big", "keep flag of <txt>")
    # warm the reservoir so low-confidence rows form an escalation band
    for h in range(8):
        store.record_cascade_agreement(key, h, 0.95, True, True)
    for h in range(8, 16):
        store.record_cascade_agreement(key, h, 0.95, False, True)

    proxy = LatencyScriptedPredictor(banded_proxy, base_latency_s=0.0625)
    expensive = LatencyScriptedPredictor(truth_answers, base_latency_s=1.0)
    casc = CascadePredictor(proxy, expensive, store=store, key=key,
                            proxy_model="small", target_precision=0.95,
                            audit_every=0)
    casc.configure({"batch_size": 4, "use_batching": True})
    casc.load()
    assert casc.calibration.status == "ok"

    from repro.core.predict import render_rows
    schema = (("flag", "BOOLEAN"),)
    pre = "keep flag of <txt>\n"
    groups = [[{"txt": f"row {i}"} for i in range(s, s + 4)]
              for s in (0, 4, 8)]                # 3 prompts x 4 rows
    prompts = [pre + render_rows(g) for g in groups]
    res = casc.complete_many(prompts, schema, [4, 4, 4], rows_list=groups,
                             instruction="keep flag of <txt>")
    # i % 4 == 0 rows (0, 4, 8) escalate: ONE re-marshaled 3-row prompt
    # in ONE expensive dispatch, not three single-row dribbles
    assert [b for _, b in expensive.dispatch_log] == [1]
    merged = [obj for r, g in zip(res, groups)
              for obj in json.loads(r.text)]
    assert [o["flag"] for o in merged] == \
        [_i_of(r) % 2 == 0 for g in groups for r in g]
    assert res[0].proxy_calls == 3
    assert res[0].escalated_calls == 1
    assert res[0].cascade_rows == 12 and res[0].escalated_rows == 3
    # hash-keyed agreement reservoir grew by the three escalated rows
    assert store.cascade_get(key).n_records == 16 + 3


def test_row_hash_is_content_keyed():
    a = row_hash("instr", {"txt": "row 1"})
    assert a == row_hash("instr", {"txt": "row 1"})
    assert a != row_hash("instr", {"txt": "row 2"})
    assert a != row_hash("other", {"txt": "row 1"})
