"""Radix prefix KV cache: tree invariants (refcounts, orphans, LRU safety),
copy-on-write sample forks, int8 frozen-page quantization, the token-boundary
prefix carve, and the n_samples SQL surface.

Invariant property tests run as seeded random trajectories so they always
execute; when hypothesis is installed the same checker is additionally driven
by @given-generated operation sequences.
"""
import json

import numpy as np
import pytest

import repro.configs as C
from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core.database import IPDB
from repro.core.executors import JaxExecutor
from repro.relational.table import Table
from repro.serving.engine import InferenceEngine, PageAllocator
from repro.serving.grammar import Field, JsonGrammar
from repro.serving.radix import RadixPrefixCache
from repro.serving.scheduler import ContinuousBatcher, Request

PREFIX = "SHARED INSTRUCTION BLOCK: extract the field from the row. " * 3
PS = 4          # tiny pages make radix splits/partial matches common


def _cfg():
    return C.get_smoke_config("olmo-1b").replace(vocab_size=259,
                                                 compute_dtype="float32")


def _engine(**kw):
    kw.setdefault("max_len", 512)
    kw.setdefault("seed", 0)
    kw.setdefault("page_size", 32)
    return InferenceEngine(_cfg(), kv_layout="paged", **kw)


# ------------------------------ tree unit tests -------------------------------
def _tree(pages=64):
    a = PageAllocator(pages)
    return RadixPrefixCache(a, PS), a


def _commit(tree, alloc, tokens):
    """Engine-style commit: alloc a lease, insert, drop the lease — the
    tree keeps exactly one reference per adopted page."""
    nfull = len(tokens) // PS
    pg = alloc.alloc(nfull)
    tree.insert(list(tokens[:nfull * PS]), pg)
    alloc.release(pg)
    return pg


def test_radix_insert_match_roundtrip():
    tree, a = _tree()
    toks = list(range(10))                     # 2 full pages + tail of 2
    _commit(tree, a, toks)
    assert tree.resident_pages == 2 and a.in_use == 2
    pages, n = tree.match(toks)
    assert n == 8 and len(pages) == 2          # capped at last full page
    assert all(a.refs(p) == 2 for p in pages)  # retained for the caller
    a.release(pages)
    assert all(a.refs(p) == 1 for p in pages)


def test_radix_partial_overlap_inside_node():
    tree, a = _tree()
    _commit(tree, a, [1, 2, 3, 4, 5, 6, 7, 8])         # one 2-page node
    pages, n = tree.match([1, 2, 3, 4, 9, 9, 9, 9, 0])  # page 1 diverges
    assert n == PS and len(pages) == 1
    a.release(pages)


def test_radix_split_preserves_single_reference():
    tree, a = _tree()
    _commit(tree, a, [1, 2, 3, 4, 5, 5, 5, 5])
    _commit(tree, a, [1, 2, 3, 4, 6, 6, 6, 6])   # splits the 2-page node
    ids = tree.resident_page_ids()
    assert len(ids) == len(set(ids)) == 3        # shared first page + 2 tails
    assert a.in_use == 3
    assert all(a.refs(p) == 1 for p in ids)
    for suffix, want in (([5, 5, 5, 5], 8), ([6, 6, 6, 6], 8)):
        pages, n = tree.match([1, 2, 3, 4] + suffix + [0])
        assert n == want and len(pages) == 2
        a.release(pages)


def test_radix_evict_lru_skips_live_readers():
    tree, a = _tree()
    _commit(tree, a, [1] * PS)
    _commit(tree, a, [2] * PS)
    held, n = tree.match([1] * PS + [0])       # outside reader on node 1
    assert n == PS
    freed = tree.evict(2)
    assert freed == 1                          # only node 2 was evictable
    pages, n = tree.match([1] * PS + [0])
    assert n == PS                             # live-reader node survived
    a.release(pages)
    a.release(held)
    assert tree.evict(1) == 1                  # now it can go
    assert a.in_use == 0 and tree.resident_pages == 0


def test_radix_clear_releases_everything():
    tree, a = _tree()
    _commit(tree, a, [1, 1, 1, 1, 2, 2, 2, 2])
    _commit(tree, a, [1, 1, 1, 1, 3, 3, 3, 3])
    assert a.in_use == 3
    tree.clear()
    assert a.in_use == 0 and tree.num_nodes == 0


# --------------------------- invariant trajectories ---------------------------
def _check_invariants(tree, alloc, outstanding):
    """Core radix/allocator invariants after any operation:
    * the tree owns each resident page exactly once (no duplicates),
    * every resident page carries the tree's reference plus any live
      match leases — never less (no orphans, no double frees),
    * total pool usage is exactly tree pages + match-held pages."""
    ids = tree.resident_page_ids()
    assert len(ids) == len(set(ids)), "page owned by two nodes"
    held = {}
    for pages in outstanding:
        for p in pages:
            held[p] = held.get(p, 0) + 1
    for p in ids:
        assert alloc.refs(p) == 1 + held.get(p, 0)
    extra = [p for p in held if p not in ids]
    # matched-then-evicted pages keep only their lease references
    for p in extra:
        assert alloc.refs(p) == held[p]
    assert alloc.in_use == len(ids) + len(extra)


def _run_trajectory(ops):
    """ops: sequence of (kind, seq_idx) with kind ∈ {0: insert, 1: match,
    2: release-oldest-match, 3: evict}. Token sequences come from a tiny
    alphabet so prefixes collide and splits happen."""
    rng = np.random.default_rng(1234)
    seqs = [list(rng.integers(0, 3, size=int(rng.integers(PS, 6 * PS))))
            for _ in range(8)]
    tree, a = _tree(pages=4096)
    outstanding = []
    for kind, i in ops:
        seq = seqs[i % len(seqs)]
        if kind == 0:
            _commit(tree, a, seq)
        elif kind == 1:
            pages, n = tree.match(seq)
            assert n % PS == 0 and len(pages) == n // PS
            if pages:
                outstanding.append(pages)
        elif kind == 2 and outstanding:
            a.release(outstanding.pop(0))
        elif kind == 3:
            before = {p for pages in outstanding for p in pages}
            tree.evict(2)
            # LRU must never drop a node with live outside readers
            assert before <= set(tree.resident_page_ids()) | before
            for p in before:
                assert a.refs(p) >= 1
        _check_invariants(tree, a, outstanding)
    for pages in outstanding:
        a.release(pages)
    tree.clear()
    assert a.in_use == 0


def test_radix_invariants_random_trajectory():
    rng = np.random.default_rng(7)
    ops = [(int(rng.integers(0, 4)), int(rng.integers(0, 8)))
           for _ in range(300)]
    _run_trajectory(ops)


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 7)),
                    max_size=60))
    def test_radix_invariants_property(ops):
        _run_trajectory(ops)


# ------------------------- generate equivalence grid --------------------------
@pytest.mark.parametrize("with_prefix", [False, True])
def test_generate_radix_matches_dense_grid(with_prefix):
    """PR-5 float32 grid, radix edition: byte-identical rows vs the dense
    layout, and a second run that reuses the tree with strictly less
    prefill."""
    prefix = PREFIX if with_prefix else ""
    d = InferenceEngine(_cfg(), seed=0, max_len=512)
    p = _engine()
    g = JsonGrammar([Field("x", "INTEGER")])
    rows = [f"row {i}: " + ("detail " * (i % 4)) + f"value {i * 7}"
            for i in range(4)]
    rd = d.generate(rows, grammar=g, shared_prefix=prefix, max_new_tokens=48)
    rp = p.generate(rows, grammar=g, shared_prefix=prefix, max_new_tokens=48)
    assert rd.texts == rp.texts
    rp2 = p.generate(rows, grammar=g, shared_prefix=prefix, max_new_tokens=48)
    assert rp2.texts == rd.texts
    if with_prefix:      # generate() matches the batch-common prefix; the
        # prefixless rows share under a page of it, so nothing to reuse
        assert rp2.stats.radix_hit_tokens > 0
        assert rp2.stats.prefill_tokens < rp.stats.prefill_tokens
    # partial overlap: unseen suffixes still reuse the common prefix pages
    rows2 = [r + " extended" for r in rows]
    rd3 = d.generate(rows2, grammar=g, shared_prefix=prefix,
                     max_new_tokens=48)
    rp3 = p.generate(rows2, grammar=g, shared_prefix=prefix,
                     max_new_tokens=48)
    assert rd3.texts == rp3.texts
    if with_prefix:
        assert rp3.stats.radix_hit_tokens > 0


def test_batcher_radix_partial_overlap_reuse():
    """No caller-provided shared prefix at all: prompts that merely START
    alike still share pages through the tree (exact-string memo cannot)."""
    g = JsonGrammar([Field("v", "INTEGER")])
    mk = lambda: [Request(prompt=PREFIX + f"row {i}: value {i}", grammar=g,
                          max_new_tokens=32) for i in range(5)]
    d = InferenceEngine(_cfg(), seed=0, max_len=512)
    done_d = ContinuousBatcher(d, num_slots=4).run(mk())
    p = _engine()
    cb = ContinuousBatcher(p, num_slots=4)
    done_p = cb.run(mk())
    assert [r.text for r in done_d] == [r.text for r in done_p]
    assert cb.stats.radix_hit_tokens > 0       # later fills hit earlier pages
    # the exact-string memo gets NO reuse here (no caller-provided prefix):
    # radix prefill must be strictly below the exact engine's
    e = _engine(prefix_cache_mode="exact")
    cbe = ContinuousBatcher(e, num_slots=4)
    cbe.run(mk())
    assert cb.stats.prefill_tokens < cbe.stats.prefill_tokens
    tree = p._radix.resident_page_ids()
    assert p._alloc.in_use == len(tree)
    assert all(p._alloc.refs(x) == 1 for x in tree)


# ------------------------------ COW sample forks ------------------------------
def test_fork_samples_cow_and_majority_vote():
    base = _engine()
    cb0 = ContinuousBatcher(base, num_slots=4)
    single = cb0.run([Request(PREFIX + "classify the row", max_new_tokens=16,
                              grammar=JsonGrammar([Field("x", "BOOLEAN")]))])
    eng = _engine()
    cb = ContinuousBatcher(eng, num_slots=4)
    done = cb.run([Request(PREFIX + "classify the row", max_new_tokens=16,
                           grammar=JsonGrammar([Field("x", "BOOLEAN")]),
                           n_samples=3)])
    r = done[0]
    # greedy decoding: every forked stream is byte-identical to the
    # unforked run, so the vote is unanimous
    assert r.samples == [single[0].text] * 3
    assert r.text == single[0].text
    assert cb.stats.cow_copies > 0             # tail page privatized on write
    tree = eng._radix.resident_page_ids()
    assert eng._alloc.in_use == len(tree)
    assert all(eng._alloc.refs(x) == 1 for x in tree)
    # fork shares the prompt: far less prefill than 3 independent streams
    assert cb.stats.prefill_tokens < 2 * cb0.stats.prefill_tokens


def test_fork_sampling_votes_majority():
    eng = _engine()
    cb = ContinuousBatcher(eng, num_slots=4)
    done = cb.run([Request(PREFIX + "pick a value", max_new_tokens=8,
                           n_samples=4)], temperature=1.0)
    r = done[0]
    assert len(r.samples) == 4
    assert r.text in r.samples
    counts = {t: r.samples.count(t) for t in set(r.samples)}
    assert counts[r.text] == max(counts.values())


# ------------------------------- int8 pages -----------------------------------
def test_int8_quantize_on_commit_cuts_kv_bytes():
    g = JsonGrammar([Field("x", "INTEGER")])
    rows = [f"row {i}: value {i * 3}" for i in range(3)]
    f32 = _engine()
    r1 = f32.generate(rows, grammar=g, shared_prefix=PREFIX,
                      max_new_tokens=32)
    q8 = _engine(kv_quant="int8")
    q1 = q8.generate(rows, grammar=g, shared_prefix=PREFIX, max_new_tokens=32)
    # first run reads fp pages (freezing happens at commit, after prefill):
    # byte-identical to the unquantized engine
    assert q1.texts == r1.texts
    assert int(np.sum(q8._quant_flags > 0)) > 0    # pages froze on commit
    # second run reads the int8 shadows: bounded drift — grammar-valid JSON
    # with the same schema, and a strictly lower logical KV footprint
    q2 = q8.generate(rows, grammar=g, shared_prefix=PREFIX, max_new_tokens=32)
    assert q2.stats.radix_hit_tokens > 0
    for t in q2.texts:
        assert set(json.loads(t)) == {"x"}
    f32.generate(rows, grammar=g, shared_prefix=PREFIX, max_new_tokens=32)
    assert q8.kv_peak_bytes < f32.kv_peak_bytes


def test_int8_dequant_drift_is_bounded():
    """Round-trip error of the per-page scale quantizer on real committed
    pages: |fp − dequant(int8)| ≤ scale/2 elementwise."""
    eng = _engine(kv_quant="int8", page_size=16)
    g = JsonGrammar([Field("x", "BOOLEAN")])
    eng.generate(["row alpha beta gamma"], grammar=g, shared_prefix=PREFIX,
                 max_new_tokens=8)
    flags = np.flatnonzero(eng._quant_flags > 0)
    assert flags.size > 0
    k = np.asarray(eng._pool["k"][:, :, flags], np.float32)
    kq = np.asarray(eng._pool["kq"][:, :, flags], np.float32)
    ks = np.asarray(eng._pool["kscale"][:, :, flags], np.float32)
    deq = kq * ks[..., None, None]
    # scale = amax/127 ⇒ |x/scale| ≤ 127: rounding is the only error source
    bound = np.broadcast_to(ks[..., None, None] * 0.5 + 1e-6, k.shape)
    np.testing.assert_array_less(np.abs(k - deq), bound)


# ------------------------ token-boundary prefix carve --------------------------
def test_executor_carve_token_boundary_multibyte():
    """Regression: prompts whose common prefix ends INSIDE a multi-byte
    character (δ vs ε share the UTF-8 lead byte 0xCE).  The carve must cut
    on a token (byte) boundary that still decodes — splitting mid-character
    would corrupt every suffix."""
    stem = "αβγ " * 20                      # > one 32-byte page of overlap
    prompts = [stem + "δ value one", stem + "ε value two",
               stem + "δ value three"]
    outs = {}
    for mode in ("dense", "exact", "radix"):
        if mode == "dense":
            eng = InferenceEngine(_cfg(), seed=0, max_len=512)
        else:
            eng = _engine(prefix_cache_mode=mode)
        ex = JaxExecutor(eng)
        ex.configure({"num_slots": 4, "temperature": 0.0, "max_tokens": 48})
        res = ex.complete_many(prompts, [("v", "INTEGER")], [1] * 3)
        outs[mode] = [r.text for r in res]
    assert outs["dense"] == outs["exact"] == outs["radix"]


# ------------------------------ SQL n_samples ---------------------------------
def test_sql_n_samples_self_consistency():
    db = IPDB()
    db.register_table("Items", Table.from_rows(
        [{"name": f"item {i}"} for i in range(4)]))
    eng = _engine(max_len=512)

    def factory(entry):
        ex = JaxExecutor(eng)
        ex.configure(dict(entry.options))
        return ex

    db.register_executor("t_jax", factory)
    # batch_size 1: each row is its own prompt, so the dispatch reaches the
    # batcher's multi-prompt path (forks + cross-prompt radix matching)
    db.sql("CREATE LLM MODEL anno PATH 'custom:t_jax' ON PROMPT "
           "OPTIONS { 'batch_size': 1, 'max_str': 6, 'temperature': 0.0, "
           "'num_slots': 4, 'max_tokens': 48, 'n_samples': 3 }")
    db.set_option("batch_size", 1)
    r = db.sql("SELECT name, LLM anno (PROMPT '" + PREFIX +
               "guess the {color VARCHAR} of {{name}}') AS color FROM Items")
    assert len(r.table.rows()) == 4
    assert r.stats.radix_hit_tokens > 0
    tree = eng._radix.resident_page_ids()
    assert eng._alloc.in_use == len(tree)
    db.close()
