"""Paged KV cache: allocator bookkeeping, prefix-memo LRU, paged-vs-dense
equivalence across the slot/prefix/raggedness grid, pool bounds, and the
query-layer stats surfacing.

Cross-layout equality tests run the smoke model with float32 compute:
dense and paged attention are mathematically identical but travel
different reduction paths, and bfloat16's coarse rounding would turn the
byte-equality assertions into near-tie coin tosses.
"""
import json

import numpy as np
import pytest

import repro.configs as C
from repro.core.database import IPDB
from repro.core.executors import JaxExecutor
from repro.relational.table import Table
from repro.serving.engine import GenStats, InferenceEngine, PageAllocator
from repro.serving.grammar import Field, JsonGrammar
from repro.serving.scheduler import ContinuousBatcher, Request

PREFIX = "SHARED INSTRUCTION BLOCK: extract the field from the row. " * 3


def _cfg():
    return C.get_smoke_config("olmo-1b").replace(vocab_size=259,
                                                 compute_dtype="float32")


def _engine(layout, **kw):
    kw.setdefault("max_len", 512)
    kw.setdefault("seed", 0)
    return InferenceEngine(_cfg(), kv_layout=layout, page_size=32, **kw)


def _assert_refcount_baseline(eng):
    """After a run completes, the only live page references are cache
    residencies (prefix-memo entries and/or radix-tree nodes) with exactly
    one reference each — anything else is a leaked slot/prefix lease."""
    if eng._alloc is None:
        return
    resident = [p for e in eng._prefix_kv.values()
                if e.pages is not None for p in e.pages]
    if eng._radix is not None:
        resident += eng._radix.resident_page_ids()
    assert eng._alloc.in_use == len(resident), \
        (eng._alloc.in_use, len(resident))
    assert all(eng._alloc.refs(p) == 1 for p in resident)


# ------------------------------ page allocator --------------------------------
def test_page_allocator_alloc_free_refcount():
    a = PageAllocator(6)
    p1 = a.alloc(2)
    p2 = a.alloc(3)
    assert a.in_use == 5 and a.free_pages == 1
    assert a.peak_in_use == 5
    # introspection aliases surfaced by EXPLAIN's pool line
    assert a.resident_pages == a.in_use == 5
    assert a.high_water == a.peak_in_use == 5
    a.retain(p1)                 # second reference (shared prefix)
    a.release(p1)
    assert a.in_use == 5         # still referenced
    a.release(p1)
    assert a.in_use == 3         # now freed
    a.release(p2)
    assert a.in_use == 0 and a.free_pages == 6
    assert a.peak_in_use == 5    # high-water survives frees
    with pytest.raises(RuntimeError):
        a.alloc(7)
    a.grow(4)
    assert a.free_pages == 10
    assert len(set(a.alloc(10))) == 10


def test_page_allocator_double_free_asserts():
    a = PageAllocator(2)
    p = a.alloc(1)
    a.release(p)
    with pytest.raises(AssertionError):
        a.release(p)


# ------------------------------ prefix memo LRU -------------------------------
def test_prefix_memo_lru_cap_and_touch_on_get():
    eng = _engine("dense", prefix_memo_entries=2)
    g = JsonGrammar([Field("x", "BOOLEAN")])

    def gen(prefix):
        return eng.generate(["row a"], grammar=g, shared_prefix=prefix,
                            max_new_tokens=24)

    gen("prefix one ")
    gen("prefix two ")
    assert len(eng._prefix_kv) == 2
    # touch "one" (hit), then insert a third: "two" must be the evictee
    r = gen("prefix one ")
    assert r.stats.prefix_hits == 1
    gen("prefix three ")
    assert len(eng._prefix_kv) == 2
    keys = [k[0] for k in eng._prefix_kv]
    assert "prefix one " in keys and "prefix three " in keys
    # the untouched entry was evicted: using it again is a miss
    r2 = gen("prefix two ")
    assert r2.stats.prefix_hits == 0 and r2.stats.prefill_tokens > 0


def test_prefix_memo_eviction_releases_pages():
    # exact mode: this test pins the PR-5 whole-string memo semantics
    eng = _engine("paged", prefix_memo_entries=1, prefix_cache_mode="exact")
    g = JsonGrammar([Field("x", "BOOLEAN")])
    eng.generate(["row"], grammar=g, shared_prefix=PREFIX, max_new_tokens=16)
    resident = eng._alloc.in_use
    assert resident > 0          # prefix pages stay resident for reuse
    eng.generate(["row"], grammar=g, shared_prefix=PREFIX * 2,
                 max_new_tokens=16)
    # cap=1: the first prefix's residency was dropped when the second came in
    ents = list(eng._prefix_kv.values())
    assert len(ents) == 1
    assert eng._alloc.in_use == len(ents[0].pages)


# --------------------------- generate equivalence -----------------------------
def test_generate_paged_matches_dense_and_monolithic():
    d, p = _engine("dense"), _engine("paged")
    g = JsonGrammar([Field("x", "INTEGER")])
    rows = [f"row: item{i}{i}" for i in range(4)]
    rd = d.generate(rows, grammar=g, shared_prefix=PREFIX, max_new_tokens=48)
    rp = p.generate(rows, grammar=g, shared_prefix=PREFIX, max_new_tokens=48)
    mono = d.generate([PREFIX + r for r in rows], grammar=g,
                      max_new_tokens=48)
    assert rd.texts == rp.texts == mono.texts
    assert (rd.stats.input_tokens, rd.stats.output_tokens) == \
        (rp.stats.input_tokens, rp.stats.output_tokens)
    assert 0 < rp.stats.kv_bytes < rd.stats.kv_bytes
    # second paged call: prefix answered from resident pages, no re-prefill
    rp2 = p.generate(rows, grammar=g, shared_prefix=PREFIX, max_new_tokens=48)
    assert rp2.texts == rp.texts
    assert rp2.stats.prefix_hits == 1
    assert rp2.stats.prefill_tokens < rp.stats.prefill_tokens


# ------------------------ batcher equivalence grid ----------------------------
def _ragged_prompts(n):
    return [f"row {i}: " + ("detail " * (i % 5)) + f"value {i * 7}"
            for i in range(n)]


@pytest.mark.parametrize("num_slots", [2, 8])
@pytest.mark.parametrize("with_prefix", [False, True])
def test_batcher_paged_matches_dense(num_slots, with_prefix):
    """Identical decoded text + token accounting across layouts for
    slots {2,8} × prefix {none,long} × ragged request lengths; with a
    prefix the paged layout must do strictly less prefill work."""
    prefix = PREFIX if with_prefix else ""
    prompts = _ragged_prompts(7)

    def reqs():
        return [Request(prompt=p, grammar=JsonGrammar([Field("v", "INTEGER")]),
                        max_new_tokens=64) for p in prompts]

    d, p = _engine("dense"), _engine("paged")
    cbd = ContinuousBatcher(d, num_slots=num_slots)
    cbp = ContinuousBatcher(p, num_slots=num_slots)
    done_d = cbd.run(reqs(), shared_prefix=prefix)   # dense: prepends
    done_p = cbp.run(reqs(), shared_prefix=prefix)   # paged: shares pages
    assert [r.text for r in done_d] == [r.text for r in done_p]
    assert [r.rid for r in done_p] == list(range(len(prompts)))
    sd, sp = cbd.stats, cbp.stats
    assert (sd.input_tokens, sd.output_tokens, sd.decode_steps) == \
        (sp.input_tokens, sp.output_tokens, sp.decode_steps)
    assert 0 < sp.kv_bytes < sd.kv_bytes
    if with_prefix:
        assert sp.prefill_tokens < sd.prefill_tokens
    # paged run must leave no leaked pages (cache residency only)
    _assert_refcount_baseline(p)


def test_batcher_paged_token_budget_eviction_frees_pages():
    eng = _engine("paged")
    g = JsonGrammar([Field("s", "VARCHAR")], max_str=8)
    reqs = [Request(prompt=f"word {i}", grammar=g, max_new_tokens=48)
            for i in range(4)]
    reqs[1].max_new_tokens = 2         # cannot finish the JSON grammar
    cb = ContinuousBatcher(eng, num_slots=2)
    done = cb.run(reqs)
    assert done[1].error and "budget" in done[1].error
    for i in (0, 2, 3):
        assert done[i].error is None
        json.loads(done[i].text)
    # eviction freed the slot's pages (prompts are sub-page: nothing is
    # committed to the radix tree, so the pool must drain to empty)
    assert eng._alloc.in_use == 0
    _assert_refcount_baseline(eng)


def test_paged_pool_bound_stalls_but_completes():
    """A pinned page pool smaller than num_slots×max_len still completes
    every request: refills stall until other slots free pages."""
    # 512-token rows at ps=32 → 16 blocks/row worst case; give ~2 rows
    eng = _engine("paged", page_pool_pages=16)
    g = JsonGrammar([Field("v", "INTEGER")])
    reqs = [Request(prompt=f"n {i}", grammar=g, max_new_tokens=32)
            for i in range(6)]
    cb = ContinuousBatcher(eng, num_slots=4)
    done = cb.run(reqs)
    assert all(r.text is not None for r in done)
    assert eng._alloc.num_pages == 16  # pinned: never grew
    _assert_refcount_baseline(eng)
    # same requests through an unbounded engine decode identically
    ref = ContinuousBatcher(_engine("paged"), num_slots=4).run(
        [Request(prompt=f"n {i}", grammar=g, max_new_tokens=32)
         for i in range(6)])
    assert [r.text for r in done] == [r.text for r in ref]


def test_paged_pallas_decode_matches_jnp():
    """End-to-end check of decode_attention_paged_pallas inside the model
    (interpret mode on CPU)."""
    base = _engine("paged", max_len=128)
    kern = _engine("paged", max_len=128, use_pallas_decode=True)
    g = JsonGrammar([Field("x", "BOOLEAN")])
    prompts = ["row alpha", "row beta"]
    r1 = base.generate(prompts, grammar=g, max_new_tokens=16)
    r2 = kern.generate(prompts, grammar=g, max_new_tokens=16)
    assert r1.texts == r2.texts


# --------------------------- executor + SQL layer -----------------------------
def test_jax_executor_paged_common_prefix_split():
    prompts = [PREFIX + f"row {i}: value {i}" for i in range(5)]
    outs = {}
    for layout in ("dense", "paged"):
        ex = JaxExecutor(_engine(layout))
        ex.configure({"num_slots": 4, "temperature": 0.0, "max_tokens": 64})
        res = ex.complete_many(prompts, [("v", "INTEGER")], [1] * 5)
        outs[layout] = [r.text for r in res]
        if layout == "paged":
            assert sum(r.prefill_tokens for r in res) > 0
            _assert_refcount_baseline(ex.engine)
    assert outs["dense"] == outs["paged"]


def test_jax_executor_paged_explicit_shared_prefix():
    """Service contract: prompts are suffixes EXCLUDING shared_prefix —
    the paged batcher route must not strip the prefix from them again."""
    suffixes = [f"row {i}: value {i}" for i in range(4)]
    outs = {}
    for layout in ("dense", "paged"):
        ex = JaxExecutor(_engine(layout))
        ex.configure({"num_slots": 4, "temperature": 0.0, "max_tokens": 64})
        res = ex.complete_many(suffixes, [("v", "INTEGER")], [1] * 4,
                               shared_prefix=PREFIX)
        outs[layout] = [(r.text, r.in_tokens) for r in res]
        if layout == "paged":
            _assert_refcount_baseline(ex.engine)
    assert outs["dense"] == outs["paged"]


def _sql_db(layout):
    db = IPDB()
    db.register_table("Items", Table.from_rows(
        [{"name": f"item {i}"} for i in range(6)]))
    eng = _engine(layout)

    def factory(entry):
        ex = JaxExecutor(eng)
        ex.configure(dict(entry.options))
        return ex

    db.register_executor("t_jax", factory)
    db.sql("CREATE LLM MODEL anno PATH 'custom:t_jax' ON PROMPT "
           "OPTIONS { 'batch_size': 1, 'max_str': 6, 'temperature': 0.0, "
           "'num_slots': 4, 'max_tokens': 48 }")
    db.set_option("batch_size", 1)
    db.set_option("max_dispatch_calls", 3)    # ≥2 dispatches per query
    return db, eng


def test_execstats_surface_prefill_decode_prefix():
    q = ("SELECT name, LLM anno (PROMPT '" + PREFIX +
         "guess the {color VARCHAR} of {{name}}') AS color FROM Items")
    rows = {}
    stats = {}
    for layout in ("dense", "paged"):
        db, eng = _sql_db(layout)
        r = db.sql(q)
        rows[layout] = r.table.rows()
        stats[layout] = r.stats
        db.close()
    assert rows["dense"] == rows["paged"]
    for layout in ("dense", "paged"):
        s = stats[layout]
        assert s.prefill_tokens > 0 and s.decode_tokens > 0
    # ≥2 dispatch batches share one instruction: the later ones hit the memo
    assert stats["paged"].prefix_hits >= 1
    assert stats["paged"].prefill_tokens < stats["dense"].prefill_tokens


def test_explain_dispatch_shows_kv_layout():
    db = IPDB()
    db.register_table("Items", Table.from_rows([{"name": "x"}]))
    db.register_oracle("o", lambda instr, rows: [{"c": "red"} for _ in rows])
    db.sql("CREATE LLM MODEL m PATH 'oracle:o' ON PROMPT")
    db.set_option("kv_layout", "paged")
    out = db.explain("SELECT name, LLM m (PROMPT 'get {c VARCHAR} of "
                     "{{name}}') AS c FROM Items")
    assert "-- dispatch --" in out
    assert "kv_layout=paged" in out
    assert "prefix_hits=" in out and "prefill_tokens=" in out
    assert "radix_hit_tokens=" in out and "kv_quant=" in out
    assert "pool: 0/0 pages, hwm=0" in out   # oracle backend: no jax pool
    db.close()


def test_genstats_add_kv_bytes_is_high_water():
    a = GenStats(kv_bytes=100, prefill_tokens=5)
    b = GenStats(kv_bytes=40, prefill_tokens=7)
    a.add(b)
    assert a.kv_bytes == 100 and a.prefill_tokens == 12
    b.add(GenStats(kv_bytes=90))
    assert b.kv_bytes == 90
