"""Serving engine: prefix-cache consistency, continuous batching, sampler
parity with the Pallas kernel."""
import json

import numpy as np
import pytest

import repro.configs as C
from repro.serving.engine import InferenceEngine
from repro.serving.grammar import Field, JsonGrammar
from repro.serving.scheduler import ContinuousBatcher, Request


@pytest.fixture(scope="module")
def engine():
    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259)
    return InferenceEngine(cfg, max_len=256, seed=0)


def test_prefix_cache_matches_monolithic(engine):
    """Greedy generation with shared-prefix KV reuse must equal generating
    from the concatenated prompt."""
    g = JsonGrammar([Field("x", "INTEGER")])
    prefix = "INSTRUCTIONS: extract the number.\n"
    suffix = "row: item42"
    full = engine.generate([prefix + suffix], grammar=g, max_new_tokens=48,
                           temperature=0.0)
    split = engine.generate([suffix], grammar=g, shared_prefix=prefix,
                            max_new_tokens=48, temperature=0.0)
    assert full.texts[0] == split.texts[0]
    # and the second call with the same prefix hits the cache
    again = engine.generate([suffix], grammar=g, shared_prefix=prefix,
                            max_new_tokens=48, temperature=0.0)
    assert again.stats.prefix_hits == 1
    assert again.stats.prefill_tokens < split.stats.prefill_tokens


def test_prefix_cache_saves_prefill_tokens(engine):
    g = JsonGrammar([Field("x", "BOOLEAN")])
    prefix = "SHARED INSTRUCTION BLOCK " * 4
    r1 = engine.generate([f"row {i}" for i in range(4)], grammar=g,
                         shared_prefix=prefix, max_new_tokens=32)
    # prefix prefilled once (batch=1), suffixes tiny
    assert r1.stats.prefill_tokens < 4 * (len(prefix) + 16)


def test_continuous_batcher_all_complete(engine):
    g = JsonGrammar([Field("c", "VARCHAR")], max_str=5)
    reqs = [Request(prompt=f"item {i}", grammar=g, max_new_tokens=32)
            for i in range(9)]
    cb = ContinuousBatcher(engine, num_slots=4)
    done = cb.run(reqs, temperature=0.8)
    assert all(r.text is not None for r in done)
    for r in done:
        if not r.error:
            json.loads(r.text)


def test_continuous_batcher_more_requests_than_slots(engine):
    """Slot refill: with far more requests than decode slots, every
    request still completes and the original order is preserved."""
    g = JsonGrammar([Field("v", "INTEGER")])
    reqs = [Request(prompt=f"number {i}", grammar=g, max_new_tokens=64)
            for i in range(7)]
    cb = ContinuousBatcher(engine, num_slots=2)
    done = cb.run(reqs)
    assert len(done) == 7
    assert [r.rid for r in done] == list(range(7))
    assert all(r.text is not None for r in done)
    for r in done:
        if not r.error:
            json.loads(r.text)


def test_continuous_batcher_token_budget_eviction(engine):
    """A request exceeding its token budget is evicted with `error` set
    (partial text kept) without stalling the rest of the batch."""
    g = JsonGrammar([Field("s", "VARCHAR")], max_str=8)
    reqs = [Request(prompt=f"word {i}", grammar=g, max_new_tokens=48)
            for i in range(4)]
    reqs[1].max_new_tokens = 2         # cannot finish the JSON grammar
    cb = ContinuousBatcher(engine, num_slots=2)
    done = cb.run(reqs)
    assert done[1].error and "budget" in done[1].error
    assert done[1].text is not None    # evicted, not lost
    for i in (0, 2, 3):
        assert done[i].error is None
        json.loads(done[i].text)


def test_pallas_sampler_matches_numpy():
    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259)
    e1 = InferenceEngine(cfg, max_len=128, seed=5, use_pallas_sampler=False)
    e2 = InferenceEngine(cfg, max_len=128, seed=5, use_pallas_sampler=True)
    g = JsonGrammar([Field("v", "INTEGER")])
    r1 = e1.generate(["count 123"], grammar=g, max_new_tokens=32,
                     temperature=0.0)
    r2 = e2.generate(["count 123"], grammar=g, max_new_tokens=32,
                     temperature=0.0)
    assert r1.texts == r2.texts
