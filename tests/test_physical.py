"""Physical-pipeline tests: chunk-size result equivalence, streaming
semantic-join memory bounds (spy predict factory), vectorized join/group-by
correctness against nested-loop references, Limit early-exit, and the
database-owned cross-query prompt cache."""
import numpy as np
import pytest

from repro.core.database import IPDB
from repro.relational.physical import joint_codes
from repro.relational.table import Table


def clean_oracle(instruction, rows):
    out = []
    for r in rows:
        joined = " ".join(f"{k}={v}" for k, v in sorted(r.items()))
        h = sum(map(ord, joined))
        out.append({"flag": h % 3 == 0, "tag": f"t{h % 5}",
                    "match": h % 4 == 0})
    return out


def make_db(chunk_size=2048, n=30):
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"a": i, "k": i % 4, "txt": f"row {i % 6}"} for i in range(n)]))
    db.register_table("S", Table.from_rows(
        [{"k2": i % 4, "s_val": f"s{i}"} for i in range(10)]))
    db.register_oracle("orc", clean_oracle)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("chunk_size", chunk_size)
    return db


EQUIV_QUERIES = [
    # semantic select + cheap filter
    "SELECT a FROM T WHERE LLM m (PROMPT 'chk {flag BOOLEAN} of {{txt}}') "
    "= TRUE AND a > 2",
    # streaming semantic join
    "SELECT s_val FROM T JOIN S ON "
    "LLM m (PROMPT 'is {{txt}} {match BOOLEAN} vs {{s_val}}')",
    # vectorized hash join + group-by + order-by
    "SELECT k, count(*) AS n, sum(a) AS s, avg(a) AS m FROM T "
    "GROUP BY k ORDER BY k",
    # scalar predict + order + limit
    "SELECT a, LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') AS t1 "
    "FROM T ORDER BY a DESC LIMIT 7",
]


@pytest.mark.parametrize("query", EQUIV_QUERIES)
def test_results_identical_across_chunk_sizes(query):
    """Chunking and dispatch pipelining are pure mechanism: results are
    bit-identical for any chunk_size and any inflight_windows depth (fresh
    database per run so caching can't leak answers)."""
    reference = make_db(2048).sql(query).table.rows()
    for inflight in (1, 4):
        for chunk in (1, 3, 2048):
            db = make_db(chunk)
            db.set_option("inflight_windows", inflight)
            rows = db.sql(query).table.rows()
            assert rows == reference, \
                f"chunk_size={chunk} inflight_windows={inflight} diverged"


class SpyOperator:
    """Wraps a PredictOperator, recording every chunk size it receives
    (whether it arrives through the synchronous __call__ path or the
    pipelined submit/resolve protocol)."""

    def __init__(self, inner, seen):
        self._inner = inner
        self._seen = seen

    def __call__(self, table):
        self._seen.append(len(table))
        return self._inner(table)

    def submit(self, table):
        self._seen.append(len(table))
        return self._inner.submit(table)

    def __getattr__(self, attr):
        return getattr(self._inner, attr)


def test_semantic_join_streams_bounded_chunks():
    """200x200 semantic join: the predict operator never sees more than
    chunk_size cross rows at once — the cross product is never
    materialized."""
    chunk = 128
    db = IPDB()
    db.register_table("L", Table.from_rows(
        [{"lid": i, "ltxt": f"a{i % 20}"} for i in range(200)]))
    db.register_table("R", Table.from_rows(
        [{"rid": i, "rtxt": f"b{i % 20}"} for i in range(200)]))

    def orc(instruction, rows):
        return [{"match": str(r.get("ltxt", ""))[-1]
                 == str(r.get("rtxt", ""))[-1]} for r in rows]

    db.register_oracle("orc", orc)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("chunk_size", chunk)

    seen = []
    orig_factory = db._predict_factory
    db._predict_factory = lambda info: SpyOperator(orig_factory(info), seen)

    r = db.sql("SELECT lid, rid FROM L JOIN R ON "
               "LLM m (PROMPT 'is {{ltxt}} {match BOOLEAN} with {{rtxt}}')")
    assert seen, "predict operator never invoked"
    assert max(seen) <= chunk          # peak intermediate bounded
    assert sum(seen) == 200 * 200      # every cross row was considered
    expected = sum(1 for i in range(200) for j in range(200)
                   if str(i % 20)[-1] == str(j % 20)[-1])
    assert len(r.table) == expected


def test_inflight_dedup_across_pipelined_windows():
    """Two identical windows submitted ahead of resolution: the second
    joins the first's pending handle — one executor call total."""
    calls = {"n": 0}

    def orc(instruction, rows):
        calls["n"] += 1
        return [{"tag": f"t{len(str(r))}"} for r in rows]

    db = IPDB()
    # rows 0-3 and 4-7 render to identical inputs → identical windows
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"same{i % 4}"} for i in range(8)]))
    db.register_oracle("orc", orc)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("chunk_size", 4)
    db.set_option("inflight_windows", 2)
    r = db.sql("SELECT a, LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') "
               "AS t FROM T")
    assert len(r.table) == 8
    assert calls["n"] == 1                 # one oracle dispatch
    assert r.stats.llm_calls == 1          # second window joined in flight
    assert r.stats.inflight_dedup_hits >= 1
    # both windows resolved to the same values
    tags = list(r.table.column("t"))
    assert tags[:4] == tags[4:]


def test_prompt_cache_lru_eviction():
    """Eviction is LRU, not FIFO: touching an entry on get keeps it alive
    past an eviction that would have rotated it out."""
    from repro.core.predict import _MISS, PromptCache
    pc = PromptCache(max_entries=3)
    pc.put(("a",), [1])
    pc.put(("b",), [2])
    pc.put(("c",), [3])
    assert pc.get(("a",)) == [1]           # touch: "a" becomes MRU
    pc.put(("d",), [4])                    # evicts LRU = "b", not "a"
    assert pc.get(("a",)) == [1]
    assert pc.get(("b",)) is _MISS
    assert pc.get(("c",)) == [3]
    assert pc.get(("d",)) == [4]
    # re-putting an existing key must not evict anything
    pc.put(("c",), [30])
    assert pc.get(("a",)) == [1] and pc.get(("d",)) == [4]
    assert len(pc) == 3


def test_cross_query_prompt_cache():
    db = make_db()
    q = ("SELECT a FROM T WHERE "
         "LLM m (PROMPT 'chk {flag BOOLEAN} of {{txt}}') = TRUE")
    r1 = db.sql(q)
    assert r1.stats.llm_calls > 0
    assert r1.stats.prompt_cache_misses > 0
    r2 = db.sql(q)                      # repeated query: fully cached
    assert r2.stats.llm_calls == 0
    assert r2.stats.prompt_cache_hits > 0
    assert r2.table.rows() == r1.table.rows()
    assert db.prompt_cache.hits >= r2.stats.prompt_cache_hits


def test_prompt_cache_disabled_with_dedup_off():
    db = make_db()
    db.set_option("use_dedup", False)
    q = ("SELECT a FROM T WHERE "
         "LLM m (PROMPT 'chk {flag BOOLEAN} of {{txt}}') = TRUE")
    r1 = db.sql(q)
    r2 = db.sql(q)
    assert r1.stats.llm_calls == r2.stats.llm_calls > 0
    assert r2.stats.prompt_cache_hits == 0


def test_limit_early_exit_saves_llm_calls():
    """Limit above a streaming Predict stops pulling chunks once satisfied."""
    db = make_db(chunk_size=1, n=40)
    db.set_option("use_batching", False)
    db.set_option("use_dedup", False)
    r = db.sql("SELECT a, LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') "
               "AS t FROM T LIMIT 3")
    assert len(r.table) == 3
    assert r.stats.llm_calls == 3      # exactly the limit, not all 40 rows


def test_limit_caps_chunks_and_cancels_queued_windows():
    """Early-exit Limit must not over-compute: the Limit caps its streaming
    subtree's chunk size (LIMIT 3 over 500 rows pulls 64-row windows, not
    one 2048-row chunk), and when the limit is satisfied the still-queued
    window of the upstream PredictOp is cancelled before any flush
    dispatches it.  The scripted backend's dispatch_log is the spy:
    dispatched calls stay bounded by ~one window and stop growing the
    moment the limit is hit."""
    import time as _time

    from helpers import LatencyScriptedPredictor, register_scripted
    db = IPDB()
    n = 500
    db.register_table("big", Table.from_rows(
        [{"a": i, "txt": f"r{i}"} for i in range(n)]))
    pred = LatencyScriptedPredictor(clean_oracle, base_latency_s=0.1)
    register_scripted(db, "spy", pred)
    db.set_option("use_batching", False)
    db.set_option("use_dedup", False)
    db.set_option("inflight_windows", 2)    # window 2 submitted, not needed
    db.set_option("max_dispatch_calls", 8)  # sliced flush leaves it queued
    r = db.sql("SELECT a, LLM spy (PROMPT 'x {tag VARCHAR} of {{txt}}') "
               "AS t FROM big LIMIT 3")
    assert len(r.table) == 3
    calls = sum(b for _, b in pred.dispatch_log)
    # one capped window (max(LIMIT_CHUNK_FLOOR, 3) = 64) plus at most one
    # dispatch slice of spillover — nowhere near the 500-row input or even
    # the two 64-row windows that were submitted
    assert calls <= 64 + 8, calls
    assert calls < n // 4
    # the cancelled window's requests are gone, not parked: nothing is
    # queued and the dispatch log never grows again
    assert db.inference_service.pending == 0
    seen = len(pred.dispatch_log)
    db.inference_service.flush()
    _time.sleep(0.02)
    assert len(pred.dispatch_log) == seen


def test_hash_join_matches_nested_loop_reference():
    rng = np.random.default_rng(7)
    l_rows = [{"k": int(rng.integers(0, 5)), "j": f"x{int(rng.integers(0, 3))}",
               "lv": i} for i in range(37)]
    r_rows = [{"k2": int(rng.integers(0, 5)),
               "j2": f"x{int(rng.integers(0, 3))}", "rv": i}
              for i in range(23)]
    db = IPDB()
    db.register_table("l", Table.from_rows(l_rows))
    db.register_table("r", Table.from_rows(r_rows))
    out = db.sql("SELECT lv, rv FROM l JOIN r ON k = k2 AND j = j2").table
    expected = [(a["lv"], b["rv"]) for a in l_rows for b in r_rows
                if a["k"] == b["k2"] and a["j"] == b["j2"]]
    got = list(zip(out.column("lv"), out.column("rv")))
    assert sorted(got) == sorted(expected)
    assert len(got) == len(expected)


def test_groupby_matches_python_reference():
    rng = np.random.default_rng(11)
    rows = [{"g": int(rng.integers(0, 6)), "h": f"s{int(rng.integers(0, 3))}",
             "v": float(rng.normal())} for i in range(200)]
    db = IPDB()
    db.register_table("t", Table.from_rows(rows))
    out = db.sql("SELECT g, h, count(*) AS n, sum(v) AS s, min(v) AS lo, "
                 "max(v) AS hi FROM t GROUP BY g, h").table
    ref = {}
    for r in rows:
        ref.setdefault((r["g"], r["h"]), []).append(r["v"])
    assert len(out) == len(ref)
    for row in out.rows():
        vals = ref[(row["g"], row["h"])]
        assert row["n"] == len(vals)
        assert row["s"] == pytest.approx(np.sum(vals))
        assert row["lo"] == pytest.approx(np.min(vals))
        assert row["hi"] == pytest.approx(np.max(vals))


def test_groupby_first_occurrence_order():
    rows = [{"g": x} for x in [3, 1, 3, 2, 1, 0]]
    db = IPDB()
    db.register_table("t", Table.from_rows(rows))
    out = db.sql("SELECT g, count(*) AS n FROM t GROUP BY g").table
    assert [int(x) for x in out.column("g")] == [3, 1, 2, 0]


def test_joint_codes_shared_space():
    l = [np.array([1, 2, 3, 2], np.int64),
         np.array(["a", "b", "a", "b"], object)]
    r = [np.array([2, 9, 1], np.int64),
         np.array(["b", "a", "a"], object)]
    cl, cr = joint_codes([l, r])
    assert cl[1] == cl[3] == cr[0]     # (2,'b') everywhere
    assert cl[0] == cr[2]              # (1,'a')
    # distinct key tuples: (1,a), (2,b), (3,a), (9,a)
    assert len(set(cr.tolist()) | set(cl.tolist())) == 4


def test_explain_includes_physical_pipeline():
    db = make_db()
    text = db.explain("SELECT a FROM T WHERE LLM m (PROMPT 'chk "
                      "{flag BOOLEAN} of {{txt}}') = TRUE AND a > 2")
    assert "-- logical --" in text
    assert "-- physical --" in text
    assert "Scan[T]" in text
    assert "Predict[m]" in text
    res = db.sql("SELECT a FROM T LIMIT 2", explain=True)
    assert res.plan and "-- physical --" in res.plan


def test_empty_inputs_preserve_schema():
    db = IPDB()
    db.register_table("e", Table.from_rows(
        [], schema={"a": "INTEGER", "b": "VARCHAR"}))
    out = db.sql("SELECT a, b FROM e WHERE a > 1 ORDER BY a LIMIT 5").table
    assert out.column_names == ["a", "b"]
    assert len(out) == 0
