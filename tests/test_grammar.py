"""Grammar-forced generation: property tests (hypothesis) that EVERY path
through the automaton yields typed, json.loads-able output — the paper's
§5.2 schema-compliance claim as a mechanical property."""
import json

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.serving.grammar import Field, JsonGrammar
from repro.serving.tokenizer import EOS_ID, decode

TYPES = ["VARCHAR", "INTEGER", "DOUBLE", "BOOLEAN", "DATETIME"]


@settings(max_examples=120, deadline=None)
@given(
    types=st.lists(st.sampled_from(TYPES), min_size=1, max_size=4),
    rows=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_random_walk_always_valid_json(types, rows, seed):
    fields = [Field(f"c{i}", t) for i, t in enumerate(types)]
    g = JsonGrammar(fields, num_rows=rows, max_str=6)
    rng = np.random.default_rng(seed)
    st_ = g.init_state()
    out = []
    for _ in range(4000):
        if g.done(st_):
            break
        m = g.mask(st_)
        choices = np.nonzero(m)[0]
        assert len(choices) > 0, f"dead state after {decode(out)!r}"
        tok = int(rng.choice(choices))
        if tok != EOS_ID:
            out.append(tok)
        st_ = g.advance(st_, tok)
    assert g.done(st_)
    v = json.loads(decode(out))
    objs = [v] if rows == 1 else v
    if rows > 1:
        assert isinstance(v, list) and len(v) == rows
    for o in objs:
        assert set(o.keys()) == {f.name for f in fields}
        for f in fields:
            x = o[f.name]
            if f.type == "INTEGER":
                assert isinstance(x, int) and not isinstance(x, bool)
            elif f.type == "DOUBLE":
                assert isinstance(x, (int, float))
            elif f.type == "BOOLEAN":
                assert isinstance(x, bool)
            else:
                assert isinstance(x, str)


def test_disallowed_token_raises():
    g = JsonGrammar([Field("a", "INTEGER")])
    s = g.init_state()
    with pytest.raises(ValueError):
        g.advance(s, ord("x"))       # first token must be '{'


def test_untrained_model_always_schema_compliant():
    """The end-to-end §5.2 claim: a RANDOM-weight model under the grammar
    still emits parseable, typed rows."""
    import repro.configs as C
    from repro.serving.engine import InferenceEngine
    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259)
    eng = InferenceEngine(cfg, max_len=192, seed=3)
    g = JsonGrammar([Field("vendor", "VARCHAR"), Field("ok", "BOOLEAN")],
                    max_str=8)
    res = eng.generate(["extract vendor"] * 2, grammar=g, max_new_tokens=64,
                       temperature=1.0)
    for t in res.texts:
        v = json.loads(t)
        assert isinstance(v["vendor"], str) and isinstance(v["ok"], bool)
