"""PREDICT operator invariants + optimizer-rule tests (the paper's §6
optimizations), including plan-equivalence properties: every optimization
must preserve query results while reducing calls/tokens."""
import json
import math

import numpy as np
import pytest

from repro.core.database import IPDB
from repro.core.predict import makespan, parse_structured
from repro.relational.table import Table


def make_db(n_rows=40, dup_every=4, **oracle_kw):
    """Products with duplicated names every `dup_every` rows."""
    rows = [{"id": i, "name": f"prod{i % (n_rows // dup_every)}",
             "category": "CPU" if i % 2 == 0 else "PSU",
             "price": float(50 + i)} for i in range(n_rows)]
    db = IPDB()
    db.register_table("Product", Table.from_rows(rows))

    def orc(instruction, rws):
        return [{"vendor": "Intel" if str(r.get("name", "")).endswith("0")
                 else "AMD",
                 "score": len(str(r.get("name", "")))} for r in rws]

    db.register_oracle("orc", orc, **oracle_kw)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    return db


Q_SELECT = ("SELECT name FROM Product WHERE "
            "LLM m (PROMPT 'get {vendor VARCHAR} of {{name}}') = 'Intel'")


def test_dedup_reduces_calls_same_result():
    db1 = make_db()
    db1.set_option("use_dedup", True)
    db1.set_option("use_batching", False)
    r1 = db1.sql(Q_SELECT)

    db2 = make_db()
    db2.set_option("use_dedup", False)
    db2.set_option("use_batching", False)
    r2 = db2.sql(Q_SELECT)

    assert sorted(r1.table.column("name")) == sorted(r2.table.column("name"))
    assert r1.stats.llm_calls == 10          # unique names
    assert r2.stats.llm_calls == 40
    assert r1.stats.tokens < r2.stats.tokens


def test_marshaling_reduces_calls_same_result():
    db1 = make_db()
    db1.set_option("use_dedup", False)
    db1.set_option("batch_size", 16)
    r1 = db1.sql(Q_SELECT)

    db2 = make_db()
    db2.set_option("use_dedup", False)
    db2.set_option("use_batching", False)
    r2 = db2.sql(Q_SELECT)

    assert sorted(r1.table.column("name")) == sorted(r2.table.column("name"))
    assert r1.stats.llm_calls == math.ceil(40 / 16)
    assert r2.stats.llm_calls == 40
    assert r1.stats.tokens < r2.stats.tokens    # amortized instructions


def test_pullup_reduces_calls_same_result():
    q = ("SELECT name FROM Product WHERE "
         "LLM m (PROMPT 'get {vendor VARCHAR} of {{name}}') = 'Intel' "
         "AND category = 'CPU'")
    db1 = make_db()
    db1.set_option("use_batching", False)
    db1.set_option("use_dedup", False)
    r1 = db1.sql(q)

    db2 = make_db()
    db2.set_option("use_batching", False)
    db2.set_option("use_dedup", False)
    db2.set_option("enable_pullup", False)
    r2 = db2.sql(q)

    assert sorted(r1.table.column("name")) == sorted(r2.table.column("name"))
    assert r1.stats.llm_calls == 20            # CPU rows only
    assert r2.stats.llm_calls == 40            # inference before filter
    assert r1.stats.sim_latency_s < r2.stats.sim_latency_s


def test_merge_predicts_same_result():
    q = ("SELECT name, LLM m (PROMPT 'get {vendor VARCHAR} of {{name}}') AS v, "
         "LLM m (PROMPT 'get {score INTEGER} of {{name}}') AS s FROM Product")
    db1 = make_db()
    r1 = db1.sql(q)
    db2 = make_db()
    db2.set_option("enable_merge", False)
    r2 = db2.sql(q)
    assert r1.table.rows() == r2.table.rows()
    assert r1.stats.llm_calls < r2.stats.llm_calls


def test_retry_and_fallback_on_malformed():
    db = make_db(n_rows=8, malform_rate=0.6)
    db.set_option("use_dedup", False)
    r = db.sql("SELECT name, LLM m (PROMPT 'get {vendor VARCHAR} of {{name}}') "
               "AS v FROM Product")
    # degraded output still schema-complete: every row present, column typed
    assert len(r.table) == 8
    assert r.stats.retries > 0 or r.stats.batch_fallbacks > 0


def test_aggregate_retries_on_malformed():
    """Semantic aggregates go through the same strict-retry path as batch
    predicts: a malformed response is retried (and counted) instead of
    being given up on after one attempt."""
    db = make_db(n_rows=8, malform_rate=1.0)
    r = db.sql("SELECT category, LLM AGG m (PROMPT 'summarize the "
               "{vendor VARCHAR} of the {{name}}s') AS v "
               "FROM Product GROUP BY category")
    assert len(r.table) == 2                   # CPU / PSU groups
    # every attempt malformed: retry_limit retries per group were burned
    assert r.stats.retries == 2 * 2
    assert r.stats.llm_calls == 2 * 3          # initial + 2 retries each
    assert all(v is None for v in r.table.column("v"))


def test_refusal_degrades_gracefully():
    db = make_db(n_rows=6, refusal_rate=1.0)
    r = db.sql("SELECT LLM m (PROMPT 'get {vendor VARCHAR} of {{name}}') AS v "
               "FROM Product")
    assert len(r.table) == 6                 # NULLs, not a crashed pipeline
    assert all(v is None for v in r.table.column("v"))


def test_parse_structured_tolerates_prose():
    s = 'Sure, here you go: {"a": 3, "b": "x"} hope that helps'
    out = parse_structured(s, [("a", "INTEGER"), ("b", "VARCHAR")], 1)
    assert out == [{"a": 3, "b": "x"}]
    assert parse_structured("no json here", [("a", "INTEGER")], 1) is None
    # type coercion
    out = parse_structured('{"a": "12", "b": 3}', [("a", "INTEGER"),
                                                   ("b", "VARCHAR")], 1)
    assert out == [{"a": 12, "b": "3"}]


def test_makespan_model():
    # 10 unit calls on 1 worker = 10s; on 10 workers = 1s
    assert makespan([1.0] * 10, 1) == pytest.approx(10.0)
    assert makespan([1.0] * 10, 10) == pytest.approx(1.0)
    # rate limit dominates: 60 rpm → 1 call/s regardless of workers
    assert makespan([0.1] * 10, 100, rpm=60.0) == pytest.approx(9.1)


def test_semantic_select_vs_join_ordering():
    """PK-side semantic select: pulled above the join it costs distinct(PK
    ∩ join) calls; FK join eliminates childless PK rows (paper §7.9)."""
    pk = [{"pid": i, "desc": f"desc{i}"} for i in range(20)]
    fk = [{"fid": i, "pid": i % 5, "txt": f"t{i}"} for i in range(40)]
    db = IPDB()
    db.register_table("P", Table.from_rows(pk))
    db.register_table("F", Table.from_rows(fk))
    db.register_oracle("orc", lambda ins, rows: [
        {"flag": str(r.get("desc", "")).endswith(("1", "2", "3"))}
        for r in rows])
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("use_batching", False)
    q = ("SELECT txt FROM P JOIN F ON pid = pid WHERE "
         "LLM m (PROMPT 'check {flag BOOLEAN} of {{desc}}') = TRUE")
    r = db.sql(q)
    # only 5 distinct pids survive the FK join → ≤5 calls with the rule on
    assert r.stats.llm_calls <= 5
    db2 = IPDB()
    db2.register_table("P", Table.from_rows(pk))
    db2.register_table("F", Table.from_rows(fk))
    db2.register_oracle("orc", lambda ins, rows: [
        {"flag": str(r.get("desc", "")).endswith(("1", "2", "3"))}
        for r in rows])
    db2.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db2.set_option("use_batching", False)
    db2.set_option("enable_join_order", False)
    db2.set_option("use_dedup", False)
    r2 = db2.sql(q)
    assert sorted(r.table.column("txt")) == sorted(r2.table.column("txt"))
    assert r.stats.llm_calls < r2.stats.llm_calls


def test_select_ordering_cheaper_first():
    """§7.10: two stacked semantic selects are ordered by input size."""
    rows = [{"title": f"t{i}", "plot": "p" * 200 + str(i)} for i in range(12)]
    db = IPDB()
    db.register_table("Movie", Table.from_rows(rows))
    calls = {"title": 0, "plot": 0, "order": []}

    def orc(instruction, rws):
        out = []
        for r in rws:
            if "plot" in r:
                calls["plot"] += len(rws)
                calls["order"].append("plot")
                out.append({"genre": "drama"})
            else:
                calls["title"] += 1
                calls["order"].append("title")
                out.append({"lang": "en" if r["title"].endswith(("1", "2"))
                            else "fr"})
        return out

    db.register_oracle("orc", orc)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("use_batching", False)
    r = db.sql("SELECT title FROM Movie WHERE "
               "LLM m (PROMPT 'genre {genre VARCHAR} of {{plot}}') = 'drama' "
               "AND LLM m (PROMPT 'lang {lang VARCHAR} of {{title}}') = 'en'")
    assert len(r.table) == 3            # t1, t2, t11
    # title-based select (short inputs) must run first
    assert calls["order"][0] == "title"
    # plot predict only sees the 3 surviving rows
    assert calls["plot"] <= 3
