"""Per-kernel allclose sweeps (interpret=True) against the ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def _split(n):
    return jax.random.split(KEY, n)


# ------------------------------ flash attention -------------------------------
@pytest.mark.parametrize("B,Sq,H,KV,D", [
    (1, 65, 4, 2, 16), (2, 128, 4, 4, 32), (1, 200, 8, 1, 64),
    (2, 96, 4, 2, 80),          # hubert head_dim (pads to 128)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window,prefix", [
    (True, 0, 0), (True, 17, 0), (False, 0, 0), (True, 0, 11),
])
def test_flash_attention(B, Sq, H, KV, D, dtype, causal, window, prefix):
    ks = _split(3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sq, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, Sq, KV, D), dtype)
    pos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32)[None], (B, Sq))
    out = ops.flash_attention(q, k, v, pos, pos, causal=causal, window=window,
                              prefix_len=prefix, block_q=64, block_kv=64,
                              interpret=True)
    G = H // KV
    qr = q.reshape(B, Sq, KV, G, D).transpose(0, 2, 3, 1, 4).reshape(B * KV, G, Sq, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Sq, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Sq, D)
    pr = jnp.repeat(pos, KV, axis=0)
    r = ref.flash_attention_ref(qr, kr, vr, pr, pr, causal=causal,
                                window=window, prefix_len=prefix)
    r = r.reshape(B, KV, G, Sq, D).transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


# ------------------------------ decode attention ------------------------------
@pytest.mark.parametrize("B,H,KV,D,L,fill", [
    (2, 4, 2, 32, 96, 50), (1, 8, 1, 64, 128, 128), (3, 4, 4, 80, 64, 10),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, H, KV, D, L, fill, dtype):
    ks = _split(3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kc = jax.random.normal(ks[1], (B, L, KV, D), dtype)
    vc = jax.random.normal(ks[2], (B, L, KV, D), dtype)
    spos = jnp.broadcast_to(jnp.arange(L, dtype=jnp.int32)[None], (B, L))
    spos = jnp.where(spos < fill, spos, -1)
    qpos = jnp.full((B,), fill - 1, jnp.int32)
    out = ops.decode_attention(q, kc, vc, spos, qpos, block_l=32,
                               interpret=True)
    G = H // KV
    qr = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    kr = kc.transpose(0, 2, 1, 3).reshape(B * KV, L, D)
    vr = vc.transpose(0, 2, 1, 3).reshape(B * KV, L, D)
    r = ref.decode_attention_ref(
        qr, kr, vr, jnp.repeat(spos, KV, axis=0),
        jnp.repeat(qpos[:, None], KV, axis=0).reshape(B * KV, 1))
    r = r.reshape(B, KV, G, D).reshape(B, H, D)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


# --------------------------- paged decode attention ---------------------------
def _folded_pools(key, KV, P, ps, D, dtype):
    """Random pool in the pre-folded TPU-native layout (KV, P, ps, Dp) —
    data in the first D lanes, zero lane padding — plus the unpadded
    (KV·P, ps, D) view the oracle consumes."""
    from repro.models.model import padded_head_dim
    Dp = padded_head_dim(D)
    raw = jax.random.normal(key, (KV, P, ps, D), dtype)
    pool = jnp.pad(raw, ((0, 0), (0, 0), (0, 0), (0, Dp - D)))
    return pool, raw.reshape(KV * P, ps, D)


def _ragged_tables(B, KV, P, ps, NB, seed):
    rng = np.random.default_rng(seed)
    fills = [int(rng.integers(1, NB * ps + 1)) for _ in range(B)]
    bt = np.full((B, NB), -1, np.int32)
    perm = iter(rng.permutation(P))
    for b, f in enumerate(fills):
        for j in range((f + ps - 1) // ps):
            bt[b, j] = next(perm)
    bt = jnp.asarray(bt)
    qpos = jnp.asarray([f - 1 for f in fills], jnp.int32)
    nact = jnp.asarray([(f - 1) // ps + 1 for f in fills], jnp.int32)
    btf = (jnp.clip(bt, 0, P - 1)[:, None, :]
           + jnp.arange(KV)[None, :, None] * P).reshape(B * KV, NB)
    return bt, qpos, jnp.repeat(nact, KV), btf, \
        jnp.repeat(qpos[:, None], KV, axis=0).reshape(B * KV, 1)


@pytest.mark.parametrize("B,H,KV,D,ps,NB,P", [
    (2, 4, 2, 32, 16, 4, 12), (1, 8, 1, 64, 32, 2, 6),
    (3, 4, 4, 80, 8, 8, 32),            # pads D to 128
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_paged(B, H, KV, D, ps, NB, P, dtype):
    """Block-table Pallas kernel on the pre-folded (KV, P, ps, Dp) pool vs
    the gather-based jnp oracle, ragged fills (some rows one block, some
    full)."""
    ks = _split(3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp, kf = _folded_pools(ks[1], KV, P, ps, D, dtype)
    vp, vf = _folded_pools(ks[2], KV, P, ps, D, dtype)
    bt, qpos, nactf, btf, qposf = _ragged_tables(B, KV, P, ps, NB, B * 7 + NB)

    out = ops.decode_attention_paged(q, kp, vp, bt, qpos, head_dim=D,
                                     interpret=True)

    G = H // KV
    qr = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    r = ref.decode_attention_paged_ref(qr, kf, vf, btf, nactf, qposf)
    r = r.reshape(B, KV, G, D).reshape(B, H, D)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,H,KV,D,ps,NB,P", [
    (2, 4, 2, 32, 16, 4, 12), (3, 4, 4, 80, 8, 8, 32),
])
def test_decode_attention_paged_quant(B, H, KV, D, ps, NB, P):
    """Dequantizing kernel twin vs the quant-aware oracle: half the pages
    frozen into int8 shadows with per-page scales, half live in fp."""
    from repro.models.model import padded_head_dim
    Dp = padded_head_dim(D)
    ks = _split(3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp, kf = _folded_pools(ks[1], KV, P, ps, D, jnp.float32)
    vp, vf = _folded_pools(ks[2], KV, P, ps, D, jnp.float32)
    bt, qpos, nactf, btf, qposf = _ragged_tables(B, KV, P, ps, NB, 11)

    # freeze the even pages: per-(kv-head, page) scale over the page block
    flags = jnp.asarray([1 - (p % 2) for p in range(P)], jnp.int32)

    def quantize(pool):
        amax = jnp.max(jnp.abs(pool), axis=(2, 3))          # (KV, P)
        scale = jnp.maximum(amax, 1e-8) / 127.0
        qv = jnp.clip(jnp.round(pool / scale[..., None, None]),
                      -127, 127).astype(jnp.int8)
        return qv, scale

    kq, kscale = quantize(kp)
    vq, vscale = quantize(vp)
    quant = {"kq": kq, "vq": vq, "kscale": kscale, "vscale": vscale,
             "flags": flags}
    out = ops.decode_attention_paged(q, kp, vp, bt, qpos, head_dim=D,
                                     quant=quant, interpret=True)

    G = H // KV
    qr = q.reshape(B, KV, G, D).reshape(B * KV, G, D)
    flf = jnp.tile(flags[None, :], (KV, 1)).reshape(KV * P, 1)
    r = ref.decode_attention_paged_quant_ref(
        qr, kf, vf, kq.reshape(KV * P, ps, Dp)[..., :D],
        vq.reshape(KV * P, ps, Dp)[..., :D],
        kscale.reshape(KV * P, 1), vscale.reshape(KV * P, 1),
        flf, btf, nactf, qposf)
    r = r.reshape(B, KV, G, D).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               atol=3e-5, rtol=3e-5)
    # and the dequantized path stays within int8 drift of the fp oracle
    rf = ref.decode_attention_paged_ref(qr, kf, vf, btf, nactf, qposf)
    rf = rf.reshape(B, KV, G, D).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(rf),
                               atol=0.08, rtol=0.08)


def test_decode_attention_paged_shared_prefix_pages():
    """Rows sharing the SAME prefix pages read them in place and attend
    identically to a dense replication of that prefix."""
    from repro.models import layers as L
    B, H, KV, D, ps = 4, 4, 2, 32, 8
    P, NB = 8, 3
    ks = _split(3)
    q = jax.random.normal(ks[0], (B, H, D))
    kp, _ = _folded_pools(ks[1], KV, P, ps, D, jnp.float32)
    vp, _ = _folded_pools(ks[2], KV, P, ps, D, jnp.float32)
    # every row: shared pages [1, 2] + its own page (3 + b); fill = 20
    bt = jnp.asarray([[1, 2, 3 + b] for b in range(B)], jnp.int32)
    qpos = jnp.full((B,), 19, jnp.int32)
    out = ops.decode_attention_paged(q, kp, vp, bt, qpos, head_dim=D,
                                     interpret=True)

    kd = kp[:, bt, :, :D].transpose(1, 2, 3, 0, 4).reshape(B, NB * ps, KV, D)
    vd = vp[:, bt, :, :D].transpose(1, 2, 3, 0, 4).reshape(B, NB * ps, KV, D)
    spos = jnp.broadcast_to(jnp.arange(NB * ps, dtype=jnp.int32)[None],
                            (B, NB * ps))
    r = L.decode_attention(q, kd, vd, spos, qpos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(r),
                               atol=3e-5, rtol=3e-5)


# --------------------------------- MoE gmm ------------------------------------
@pytest.mark.parametrize("T,M,N,E,seed", [
    (64, 32, 48, 4, 0), (130, 64, 64, 8, 1), (33, 96, 16, 3, 2),
    (16, 32, 32, 5, 3),
])
def test_gmm(T, M, N, E, seed):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    gs = np.zeros(E, np.int64)
    r = np.random.default_rng(seed)
    for _ in range(T):
        gs[r.integers(0, E)] += 1
    x = jax.random.normal(k2, (T, M))
    w = jax.random.normal(k3, (E, M, N)) * 0.1
    out = ops.gmm(x, w, jnp.asarray(gs), block_m=16, block_n=16, block_k=32,
                  interpret=True)
    rr = ref.gmm_ref(x, w, jnp.asarray(gs))
    np.testing.assert_allclose(np.asarray(out), np.asarray(rr),
                               atol=3e-5, rtol=3e-5)


# ------------------------------ selective scan --------------------------------
@pytest.mark.parametrize("Bz,S,Di,N", [(1, 48, 32, 8), (2, 100, 24, 16),
                                       (2, 33, 128, 4)])
def test_selective_scan(Bz, S, Di, N):
    ks = _split(5)
    u = jax.random.normal(ks[0], (Bz, S, Di)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, Di))) * 0.2
    A = -jnp.exp(jax.random.normal(ks[2], (Di, N)) * 0.3)
    B = jax.random.normal(ks[3], (Bz, S, N)) * 0.5
    C = jax.random.normal(ks[4], (Bz, S, N)) * 0.5
    D = jnp.ones((Di,))
    y, h = ops.selective_scan(u, dt, A, B, C, D, chunk=16, block_d=16,
                              interpret=True)
    yr, hr = ref.selective_scan_ref(u, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-4,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=1e-4,
                               rtol=1e-4)


# --------------------------- constrained sampling -----------------------------
@pytest.mark.parametrize("B,V,temp", [(2, 512, 1.0), (4, 1000, 0.5),
                                      (1, 300, 2.0)])
def test_constrained_sample(B, V, temp):
    ks = _split(3)
    logits = jax.random.normal(ks[0], (B, V))
    mask = jax.random.uniform(ks[1], (B, V)) > 0.6
    mask = mask.at[:, 7].set(True)          # never fully masked
    noise = jax.random.gumbel(ks[2], (B, V))
    out = ops.constrained_sample(logits, mask, noise, temperature=temp,
                                 block_v=128, interpret=True)
    r = ref.constrained_sample_ref(logits, mask, noise, temperature=temp)
    assert np.array_equal(np.asarray(out), np.asarray(r))
    # sampled tokens always satisfy the mask
    assert bool(np.all(np.asarray(mask)[np.arange(B), np.asarray(out)]))


def test_constrained_sample_greedy():
    logits = jnp.asarray([[1.0, 5.0, 3.0, -2.0]])
    mask = jnp.asarray([[1, 0, 1, 1]], jnp.int8)
    out = ops.constrained_sample(logits, mask, None, block_v=4, interpret=True)
    assert int(out[0]) == 2                  # best *allowed* token


# -------------------------- jnp flash (model layer) ----------------------------
def test_model_flash_vs_reference_grad():
    from repro.models import layers as L
    B, S, H, KV, D = 2, 50, 4, 2, 16
    ks = _split(3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    f = lambda *a: L.flash_attention(*a, pos, pos, True, 13, 0, 32, 32).sum()
    r = lambda *a: L.reference_attention(*a, pos, pos, True, 13, 0).sum()
    gf = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-4)


# ------------------------- banded SWA flash (§Perf opt A) ----------------------
@pytest.mark.parametrize("win,bq,bkv", [(16, 32, 32), (33, 32, 64),
                                        (100, 64, 32)])
def test_banded_flash_matches_reference(win, bq, bkv):
    from repro.models import layers as L
    B, S, H, KV, D = 2, 300, 4, 2, 16
    ks = _split(3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    a = L.flash_attention(q, k, v, pos, pos, True, win, 0, bq, bkv,
                          False, True)
    b = L.reference_attention(q, k, v, pos, pos, True, win, 0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)
