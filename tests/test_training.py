"""Training substrate: checkpoint round-trip + elastic restore, resume
determinism, loss decrease, preflight of the data pipeline."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch import steps as ST
from repro.models.config import ShapeSpec
from repro.training import checkpoint as CKPT
from repro.training import optim as OPT
from repro.training.data import DataConfig, synthetic_batch


def test_checkpoint_roundtrip(tmp_path):
    cfg = C.get_smoke_config("yi-6b")
    state = ST.init_train_state(cfg, jax.random.PRNGKey(0))
    CKPT.save(str(tmp_path), 7, jax.tree.map(np.asarray, state),
              num_shards=4)
    assert CKPT.latest_step(str(tmp_path)) == 7
    specs = ST.train_state_specs(cfg)
    restored = CKPT.restore(str(tmp_path), 7, specs)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_atomicity(tmp_path):
    cfg = C.get_smoke_config("olmo-1b")
    state = jax.tree.map(np.asarray,
                         ST.init_train_state(cfg, jax.random.PRNGKey(0)))
    for s in (10, 20, 30, 40):
        CKPT.save(str(tmp_path), s, state, keep_last=2)
    steps = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert steps == ["step_00000030", "step_00000040"]


def test_data_pipeline_deterministic_and_sharded():
    cfg = C.get_smoke_config("qwen2-7b")
    d1 = DataConfig(batch=8, seq_len=32, num_hosts=1, host_id=0)
    a = synthetic_batch(cfg, d1, 5)
    b = synthetic_batch(cfg, d1, 5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_batch(cfg, d1, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # host sharding changes the stream
    d2 = DataConfig(batch=8, seq_len=32, num_hosts=2, host_id=1)
    h1 = synthetic_batch(cfg, d2, 5)
    assert h1["tokens"].shape[0] == 4


def test_loss_decreases_100m_scale_path(tmp_path):
    """Short convergence check through the real driver (checkpoint +
    restart mid-run → identical final state as uninterrupted)."""
    cfg = C.get_smoke_config("olmo-1b")
    shape = ShapeSpec("t", seq_len=64, global_batch=4, kind="train")
    step_fn, _ = ST.make_train_step(
        cfg, None, shape, num_micro=2, donate=False,
        opt_cfg=OPT.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    state = ST.init_train_state(cfg, jax.random.PRNGKey(0))
    dcfg = DataConfig(batch=4, seq_len=64)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in
                 synthetic_batch(cfg, dcfg, step).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::10]


def test_resume_bitexact(tmp_path):
    cfg = C.get_smoke_config("olmo-1b")
    shape = ShapeSpec("t", seq_len=32, global_batch=4, kind="train")
    opt_cfg = OPT.AdamWConfig(lr=1e-3, warmup_steps=0)
    dcfg = DataConfig(batch=4, seq_len=32)

    def run(n_steps, state):
        step_fn, _ = ST.make_train_step(cfg, None, shape, donate=False,
                                        opt_cfg=opt_cfg)
        for s in range(int(np.asarray(state["step"])), n_steps):
            batch = {k: jnp.asarray(v) for k, v in
                     synthetic_batch(cfg, dcfg, s).items()}
            state, _ = step_fn(state, batch)
        return state

    s_cont = run(8, ST.init_train_state(cfg, jax.random.PRNGKey(0)))

    s_half = run(4, ST.init_train_state(cfg, jax.random.PRNGKey(0)))
    CKPT.save(str(tmp_path), 4, jax.tree.map(np.asarray, s_half))
    restored = CKPT.restore(str(tmp_path), 4, ST.train_state_specs(cfg))
    s_resumed = run(8, restored)

    for a, b in zip(jax.tree.leaves(s_cont), jax.tree.leaves(s_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-6)


def test_elastic_restore_new_sharding(tmp_path):
    """Restore with explicit (single-device) shardings — the same path a
    re-meshed relaunch takes (multi-pod uses NamedShardings instead)."""
    cfg = C.get_smoke_config("hymba-1.5b")
    state = jax.tree.map(np.asarray,
                         ST.init_train_state(cfg, jax.random.PRNGKey(1)))
    CKPT.save(str(tmp_path), 3, state, num_shards=2)
    specs = ST.train_state_specs(cfg)
    dev = jax.devices()[0]
    sh = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), specs)
    restored = CKPT.restore(str(tmp_path), 3, specs, shardings=sh)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
