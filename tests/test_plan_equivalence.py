"""Property test: the semantic optimizer NEVER changes query results —
for randomized tables, predicates and optimization-flag subsets, the
optimized plan's output equals the all-optimizations-off plan's output,
while never making more LLM calls."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.database import IPDB
from repro.relational.table import Table

FLAGS = ("enable_pullup", "enable_join_order", "enable_merge",
         "enable_select_order", "enable_rewrites", "enable_reopt",
         "use_dedup", "use_batching")


def build_db(rows, flags):
    db = IPDB()
    db.register_table("T", Table.from_rows(rows))
    db.register_table("S", Table.from_rows(
        [{"k": i % 4, "s_val": f"s{i}"} for i in range(10)]))

    def orc(instruction, rws):
        out = []
        for r in rws:
            joined = " ".join(f"{k}={v}" for k, v in sorted(r.items()))
            out.append({"flag": sum(map(ord, joined)) % 3 == 0,
                        "tag": f"t{sum(map(ord, joined)) % 5}"})
        return out

    db.register_oracle("orc", orc)
    for f in FLAGS:
        db.set_option(f, f in flags)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    return db


QUERIES = [
    # semantic select + cheap filter (pull-up territory)
    "SELECT a FROM T WHERE LLM m (PROMPT 'chk {flag BOOLEAN} of {{txt}}') "
    "= TRUE AND a > 2",
    # two scalar predicts (merge territory)
    "SELECT a, LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') AS t1, "
    "LLM m (PROMPT 'get {flag BOOLEAN} of {{txt}}') AS t2 FROM T",
    # semantic select above a join (join-order territory)
    "SELECT s_val FROM T JOIN S ON k = k WHERE "
    "LLM m (PROMPT 'chk {flag BOOLEAN} of {{txt}}') = TRUE",
    # stacked semantic selects (ordering territory)
    "SELECT a FROM T WHERE LLM m (PROMPT 'c1 {flag BOOLEAN} of {{txt}}') "
    "= TRUE AND LLM m (PROMPT 'c2 {tag VARCHAR} of {{a}}') = 't0'",
    # duplicate semantic subexpression (consolidation territory)
    "SELECT a, LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') AS t1 FROM T "
    "WHERE LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') = 't0'",
    # implied predicate pair over identical predicts (subsumption territory)
    "SELECT a FROM T WHERE LLM m (PROMPT 'chk {flag BOOLEAN} of {{txt}}') "
    "= TRUE AND LLM m (PROMPT 'chk {flag BOOLEAN} of {{txt}}') = TRUE",
]


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 24),
    seed=st.integers(0, 10_000),
    flags=st.sets(st.sampled_from(FLAGS)),
    qi=st.integers(0, len(QUERIES) - 1),
)
def test_optimizations_preserve_results(n, seed, flags, qi):
    rng = np.random.default_rng(seed)
    rows = [{"a": int(rng.integers(0, 8)), "k": int(rng.integers(0, 4)),
             "txt": f"row {int(rng.integers(0, 6))}"} for i in range(n)]
    q = QUERIES[qi]

    base = build_db(rows, flags=set())          # everything off
    r0 = base.sql(q)
    opt = build_db(rows, flags=flags)
    r1 = opt.sql(q)

    key = r0.table.column_names[0]
    assert sorted(map(str, r0.table.column(key))) == \
        sorted(map(str, r1.table.column(key)))
    # optimizations may only reduce (or keep) the number of LLM calls
    assert r1.stats.llm_calls <= r0.stats.llm_calls


def test_semantic_order_by():
    db = build_db([{"a": i, "k": 0, "txt": f"row {i}"} for i in range(6)],
                  flags=set(FLAGS))
    r = db.sql("SELECT a FROM T ORDER BY "
               "LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}'), a")
    assert len(r.table) == 6
    assert r.stats.llm_calls >= 1


def test_semantic_group_by_key():
    """Scalar inference feeding GROUP BY through a derived table (paper
    Listing 5 pattern: predicted column used for grouping)."""
    db = build_db([{"a": i, "k": 0, "txt": f"row {i % 3}"} for i in range(9)],
                  flags=set(FLAGS))
    db.sql("CREATE TABLE T2 AS SELECT a, LLM m (PROMPT 'get {tag VARCHAR} "
           "of {{txt}}') AS tag FROM T")
    r = db.sql("SELECT tag, count(*) AS n FROM T2 GROUP BY tag")
    assert sum(x["n"] for x in r.table.rows()) == 9
