"""Sharding-rule validation WITHOUT device allocation: every PartitionSpec
must divide its dimension on both production meshes, for every arch, for
train/prefill/decode layouts. (The compile-level proof is the dry-run; this
is the fast structural check.)"""
import jax
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as C
from repro.configs import common as CC
from repro.models import model as MDL
from repro.models.config import SHAPES_BY_NAME

MESH_SHAPES = {
    "single": {"data": 16, "model": 16},
    "multi": {"pod": 2, "data": 16, "model": 16},
}


class FakeMesh:
    """Duck-typed stand-in for jax.Mesh (axis sizes only)."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)


def _axis_size(mesh_shape, entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        n = 1
        for e in entry:
            n *= mesh_shape[e]
        return n
    return mesh_shape[entry]


def _check(specs, pspecs, mesh_shape, what):
    flat_s = jax.tree_util.tree_flatten_with_path(specs)[0]
    flat_p = jax.tree.leaves(pspecs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p), f"{what}: tree mismatch"
    for (path, leaf), spec in zip(flat_s, flat_p):
        name = jax.tree_util.keystr(path)
        assert len(spec) <= len(leaf.shape), f"{what}{name}: rank"
        for d, entry in enumerate(spec):
            k = _axis_size(mesh_shape, entry)
            assert leaf.shape[d] % k == 0, \
                f"{what}{name}: dim {d} ({leaf.shape[d]}) not divisible " \
                f"by {entry}={k}"


@pytest.mark.parametrize("arch", C.ARCH_IDS)
@pytest.mark.parametrize("mesh_kind", ["single", "multi"])
def test_param_shardings_divide(arch, mesh_kind):
    from repro.launch import mesh as MS
    cfg = C.get_config(arch)
    mesh = FakeMesh(MESH_SHAPES[mesh_kind])
    for attn_mode in ("heads", "hd"):
        pspecs = MS.param_pspecs(cfg, mesh, fsdp=True, attn_mode=attn_mode)
        _check(MDL.param_specs(cfg), pspecs, MESH_SHAPES[mesh_kind],
               f"{arch}/{attn_mode}/")


@pytest.mark.parametrize("arch", C.ARCH_IDS)
@pytest.mark.parametrize("shape_name", ["decode_32k", "long_500k"])
def test_cache_shardings_divide(arch, shape_name):
    from repro.launch import mesh as MS
    from repro.models.config import shape_applicable
    cfg = C.get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, _ = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("cell skipped by assignment rules")
    for mesh_kind in ("single", "multi"):
        mesh = FakeMesh(MESH_SHAPES[mesh_kind])
        cspecs = MDL.cache_specs(cfg, shape.global_batch, shape.seq_len)
        pspecs = MS.cache_pspecs(cfg, mesh, cspecs)
        _check(cspecs, pspecs, MESH_SHAPES[mesh_kind],
               f"{arch}/{shape_name}/{mesh_kind}/")


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_batch_shardings_divide(arch):
    from repro.launch import mesh as MS
    cfg = C.get_config(arch)
    for mesh_kind in ("single", "multi"):
        mesh = FakeMesh(MESH_SHAPES[mesh_kind])
        for shape_name in ("train_4k", "prefill_32k"):
            shape = SHAPES_BY_NAME[shape_name]
            bs = CC.train_batch_specs(cfg, shape.global_batch, shape.seq_len) \
                if shape.kind == "train" else \
                CC.prefill_batch_specs(cfg, shape.global_batch, shape.seq_len)
            ps = MS.batch_pspecs(cfg, mesh, bs)
            _check(bs, ps, MESH_SHAPES[mesh_kind],
                   f"{arch}/{shape_name}/{mesh_kind}/")


def test_all_cells_enumerated():
    cells = C.cells(include_skipped=True)
    assert len(cells) == 40                      # the assignment matrix
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 32
    for (a, s, ok, why) in cells:
        if not ok:
            assert why, f"{a}/{s.name} skipped without a reason"


@pytest.mark.parametrize("arch", C.ARCH_IDS)
def test_resident_serving_layout_divides(arch):
    """§Perf opt B layout: resident weights must divide on both meshes and
    never shard a contraction dim (no per-step gathers by construction)."""
    from repro.launch import mesh as MS
    cfg = C.get_config(arch)
    for mesh_kind in ("single", "multi"):
        mesh = FakeMesh(MESH_SHAPES[mesh_kind])
        pspecs = MS.param_pspecs(cfg, mesh, fsdp=False, attn_mode="hd",
                                 resident=True)
        _check(MDL.param_specs(cfg), pspecs, MESH_SHAPES[mesh_kind],
               f"{arch}/resident/{mesh_kind}/")
