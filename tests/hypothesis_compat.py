"""Optional-hypothesis shim: property tests skip cleanly when hypothesis
is absent, while plain tests in the same module still run.

Usage: `from hypothesis_compat import given, settings, st`.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    class _StrategyStub:
        """Accepts any st.<name>(...) call at collection time."""
        def __getattr__(self, name):
            def strategy(*args, **kwargs):
                return None
            return strategy

    st = _StrategyStub()
