"""Fault-tolerant inference (PR 10): chaos harness, circuit breakers,
deadline propagation, and crash-safe warm-state snapshots.

The contracts under test:

  * chaos equivalence — with seeded TRANSIENT faults on <30% of calls,
    rows are byte-identical to the fault-free run for every
    dispatch_workers setting (retries deterministically succeed: the
    FaultInjector's decisions are pure functions of (seed, prompt,
    occurrence));
  * circuit breaking — a hard-hung backend trips its breaker within the
    probe budget WITHOUT stalling other lanes, drain/wait_idle/shutdown,
    or the query itself (the per-call timeout guard strands only the
    zombie call);
  * deadline propagation — WITH (deadline_ms=...) beats model OPTIONS
    beats the session default; expired work is dropped before dispatch,
    and retry paths re-check the remaining deadline per attempt;
  * graceful degradation — an expensive-stage outage degrades cascade
    batches to proxy-only (EXPLAIN status `degraded`) instead of
    failing them;
  * crash safety — snapshots are atomic, versioned and checksummed;
    corruption falls back to the next older file and ultimately to a
    cold start; a warm-restored engine answers repeat queries with zero
    backend calls and a warm radix prefix tree.
"""
import dataclasses
import os
import threading
import time

import pytest

from helpers import LatencyScriptedPredictor, drain_stream, register_scripted

from repro.core.database import IPDB
from repro.core.faults import (CLOSED, HALF_OPEN, OPEN, CircuitBreaker,
                               FaultInjector, TransientBackendError, _decide)
from repro.core.snapshot import (SnapshotError, _decode, _encode,
                                 load_latest, snapshot_files, write_snapshot)
from repro.relational.table import Table


def echo_answers(instruction, rows):
    out = []
    for r in rows:
        joined = " ".join(f"{k}={v}" for k, v in sorted(r.items()))
        h = sum(map(ord, joined)) + sum(map(ord, instruction))
        out.append({"tag": f"t{h % 5}", "flag": h % 3 == 0, "score": h % 7})
    return out


def make_db(*, n=24, chunk=8, workers=1, batch=4, predictor=None,
            snapshot_dir=None, **opts):
    db = IPDB(snapshot_dir=snapshot_dir)
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"row {i}"} for i in range(n)]))
    pred = predictor if predictor is not None else \
        LatencyScriptedPredictor(echo_answers, base_latency_s=0.25)
    register_scripted(db, "m", pred)
    db.set_option("chunk_size", chunk)
    db.set_option("batch_size", batch)
    db.set_option("dispatch_workers", workers)
    db.set_option("enable_pilot", False)
    for k, v in opts.items():
        db.set_option(k, v)
    return db, pred


def q(instr: str) -> str:
    return ("SELECT a, LLM m (PROMPT '" + instr +
            " {tag VARCHAR} of {{txt}}') AS t FROM T")


# ---------------------------------------------------------------------------
# circuit breaker (unit)
# ---------------------------------------------------------------------------
def test_breaker_trips_after_consecutive_failures():
    b = CircuitBreaker("x", failure_threshold=3, probe_every=4)
    for _ in range(2):
        b.record_failure()
    assert b.state == CLOSED and b.allow()
    b.record_success()                  # success resets the streak
    for _ in range(2):
        b.record_failure()
    assert b.state == CLOSED
    b.record_failure()                  # third consecutive -> open
    assert b.state == OPEN and b.opens == 1


def test_breaker_probe_schedule_and_recovery():
    b = CircuitBreaker("x", failure_threshold=1, probe_every=3)
    b.record_failure()
    assert b.state == OPEN
    # every probe_every-th attempt becomes the half-open probe
    assert [b.allow() for _ in range(3)] == [False, False, True]
    assert b.state == HALF_OPEN and b.probes == 1
    assert not b.allow()                # one probe in flight at a time
    b.record_failure()                  # probe failed -> re-open
    assert b.state == OPEN
    assert [b.allow() for _ in range(3)] == [False, False, True]
    b.record_success()                  # probe succeeded -> closed
    assert b.state == CLOSED and b.allow()


def test_breaker_snapshot_counters():
    b = CircuitBreaker("x", failure_threshold=1, probe_every=2)
    b.record_failure()
    assert not b.allow()
    snap = b.snapshot()
    assert snap["state"] == OPEN
    assert snap["failures"] == 1 and snap["rejections"] == 1


# ---------------------------------------------------------------------------
# fault injector (unit)
# ---------------------------------------------------------------------------
def test_fault_decisions_are_deterministic():
    a = [_decide(7, f"p{i}", 0, "transient") for i in range(50)]
    b = [_decide(7, f"p{i}", 0, "transient") for i in range(50)]
    assert a == b
    assert all(0.0 <= x < 1.0 for x in a)
    # a different seed reshuffles the outcome pattern
    c = [_decide(8, f"p{i}", 0, "transient") for i in range(50)]
    assert a != c


def test_injector_transient_fires_once_then_retry_succeeds():
    inner = LatencyScriptedPredictor(echo_answers)
    inj = FaultInjector(inner, seed=0, transient_rate=1.0)
    schema = (("tag", "VARCHAR"),)
    with pytest.raises(TransientBackendError):
        inj.complete_many(["p"], schema, [1], rows_list=[[{"t": 1}]],
                          instruction="i")
    # occurrence 1 of the same prompt deterministically succeeds
    out = inj.complete_many(["p"], schema, [1], rows_list=[[{"t": 1}]],
                            instruction="i")
    assert len(out) == 1 and out[0].text
    assert inj.counters["transient"] == 1
    assert inj.counters["calls"] == 2


def test_injector_outage_window_rejects_everything():
    inner = LatencyScriptedPredictor(echo_answers)
    inj = FaultInjector(inner, seed=0, outage=(1, 2))
    schema = (("tag", "VARCHAR"),)
    ok = lambda p: inj.complete_many([p], schema, [1],  # noqa: E731
                                     rows_list=[[{"t": p}]], instruction="i")
    ok("a")                              # call 0: before the window
    with pytest.raises(TransientBackendError):
        ok("b")                          # call 1: inside
    with pytest.raises(TransientBackendError):
        ok("c")                          # call 2: inside
    ok("d")                              # call 3: after
    assert inj.counters["outage_rejects"] == 2


def test_injector_malform_truncates_first_occurrence_only():
    inner = LatencyScriptedPredictor(echo_answers)
    inj = FaultInjector(inner, seed=0, malform_rate=1.0)
    schema = (("tag", "VARCHAR"),)
    first = inj.complete_many(["p"], schema, [2],
                              rows_list=[[{"t": 1}, {"t": 2}]],
                              instruction="i")[0]
    again = inj.complete_many(["p"], schema, [2],
                              rows_list=[[{"t": 1}, {"t": 2}]],
                              instruction="i")[0]
    assert len(first.text) < len(again.text)    # truncated mid-JSON
    import json
    with pytest.raises(ValueError):
        json.loads(first.text)
    json.loads(again.text)                       # retry parses clean


# ---------------------------------------------------------------------------
# chaos equivalence: transient faults never change results
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("workers", [1, 2, 4])
def test_chaos_rows_byte_identical_to_fault_free(workers):
    """Seeded transient faults on <30% of first-occurrence calls: the
    chaos run's rows equal the fault-free run's exactly, for every
    dispatch_workers setting, and the retries actually happened."""
    db_ref, _ = make_db(workers=workers)
    with db_ref:
        ref = db_ref.sql(q("chaos"))
    rows_ref = ref.table.rows()
    assert ref.stats.transient_retries == 0

    inj = FaultInjector(LatencyScriptedPredictor(echo_answers,
                                                 base_latency_s=0.25),
                        seed=7, transient_rate=0.25)
    db_chaos, _ = make_db(workers=workers, predictor=inj)
    with db_chaos:
        got = db_chaos.sql(q("chaos"), explain=True)
    assert got.table.rows() == rows_ref
    assert inj.counters["transient"] > 0
    assert got.stats.transient_retries >= inj.counters["transient"]
    assert got.stats.deadline_drops == 0
    assert "-- resilience --" in got.plan
    assert "transient=%d" % got.stats.transient_retries in got.plan


@pytest.mark.parametrize("workers", [1, 2])
def test_chaos_streaming_sessions_match_fault_free(workers):
    db_ref, _ = make_db(workers=workers)
    with db_ref:
        rows_ref, _ = drain_stream(db_ref.stream(q("schaos")))
    inj = FaultInjector(LatencyScriptedPredictor(echo_answers,
                                                 base_latency_s=0.25),
                        seed=11, transient_rate=0.25)
    db_chaos, _ = make_db(workers=workers, predictor=inj)
    with db_chaos:
        rows, stats = drain_stream(db_chaos.stream(q("schaos")))
    assert rows == rows_ref
    assert inj.counters["transient"] > 0
    assert stats.transient_retries >= inj.counters["transient"]


def test_transient_fault_on_one_model_cannot_crash_another():
    """A transient-class dispatch failure is recorded on the failed
    handles only: a two-model query where one backend hiccups still
    returns every row (the faulted model's calls are retried)."""
    inj = FaultInjector(LatencyScriptedPredictor(echo_answers),
                        seed=3, transient_rate=0.5)
    db, _ = make_db(workers=2, predictor=inj)
    clean = LatencyScriptedPredictor(echo_answers, base_latency_s=0.0625)
    register_scripted(db, "cleanm", clean)
    with db:
        r = db.sql("SELECT a, LLM m (PROMPT 'x {tag VARCHAR} of {{txt}}') "
                   "AS t1, LLM cleanm (PROMPT 'y {tag VARCHAR} of "
                   "{{txt}}') AS t2 FROM T")
    rows = r.table.rows()
    assert len(rows) == 24
    assert all(row["t1"] is not None and row["t2"] is not None
               for row in rows)


# ---------------------------------------------------------------------------
# hung backends: timeouts, breaker trips, no stalled lanes
# ---------------------------------------------------------------------------
class HangingPredictor(LatencyScriptedPredictor):
    """Blocks every dispatch on an event (default: ~forever)."""

    def __init__(self, *a, hang_s=30.0, **kw):
        super().__init__(*a, **kw)
        self.hang_s = hang_s
        self.release = threading.Event()

    def complete_many(self, prompts, schema, num_rows_list, **kw):
        self.release.wait(self.hang_s)
        return super().complete_many(prompts, schema, num_rows_list, **kw)


def test_hung_backend_times_out_trips_breaker_without_stalling():
    """A backend that never returns: the per-call timeout converts the
    hang into BackendTimeout, consecutive failures open its breaker, the
    query degrades to NULLs quickly, and an unrelated model keeps
    serving at full speed while the hang is in flight."""
    hang = HangingPredictor(echo_answers)
    db, _ = make_db(workers=2, predictor=None, call_timeout_s=0.3,
                    breaker_threshold=2, breaker_probe_every=4)
    register_scripted(db, "hangm", hang)
    hq = ("SELECT a, LLM hangm (PROMPT 'h {tag VARCHAR} of {{txt}}') "
          "AS t FROM T")
    out = {}

    def run_hung():
        out["res"] = db.sql(hq)

    with db:
        t0 = time.monotonic()
        t = threading.Thread(target=run_hung)
        t.start()
        # the other lane keeps serving while hangm's lane is wedged
        fast = db.sql(q("bystander"))
        assert len(fast.table.rows()) == 24
        assert all(r["t"] is not None for r in fast.table.rows())
        t.join(timeout=30)
        assert not t.is_alive(), "hung backend stalled the query"
        elapsed = time.monotonic() - t0
        assert elapsed < 25.0            # never waited out the 30s hang
        res = out["res"]
        # every hangm answer degraded to NULL; breaker saw the failures
        assert all(r["t"] is None for r in res.table.rows())
        assert res.stats.backend_timeouts > 0
        snap = db.inference_service.breaker_for("hangm").snapshot()
        assert snap["failures"] >= 2
        assert snap["opens"] >= 1
        # lifecycle still clean: nothing pending, idle within the bound
        assert db.inference_service.wait_idle(timeout=5.0)
    hang.release.set()                   # unblock zombie guard threads


def test_wait_idle_and_drain_survive_hung_lane():
    """Satellite regression: wait_idle(timeout=) and drain_for on a hung
    lane must ride the timeout machinery instead of deadlocking."""
    hang = HangingPredictor(echo_answers, hang_s=20.0)
    db, _ = make_db(n=8, predictor=hang, workers=2, call_timeout_s=0.25,
                    retry_limit=1)
    with db:
        t0 = time.monotonic()
        res = db.sql(q("wedge"))
        assert all(r["t"] is None for r in res.table.rows())
        assert db.inference_service.wait_idle(timeout=5.0)
        db.inference_service.drain()
        assert time.monotonic() - t0 < 15.0
    hang.release.set()


def test_zero_call_timeout_keeps_seed_behavior():
    """call_timeout_s=0 (the default) must dispatch on the lane thread
    itself — byte-identical accounting to the seed, no guard threads."""
    db, pred = make_db()
    with db:
        res = db.sql(q("plain"))
    assert res.stats.backend_timeouts == 0
    assert res.stats.breaker_rejections == 0
    assert len(res.table.rows()) == 24
    # the dispatch happened on a service lane/submitting thread, not a
    # one-shot guard thread
    assert all("call-guard" not in name for name, _ in pred.dispatch_log)


# ---------------------------------------------------------------------------
# deadline propagation
# ---------------------------------------------------------------------------
def test_expired_deadline_drops_work_before_dispatch():
    """A 1ms deadline against a backend that takes 50ms of real time per
    call: at most the very first batch (dispatched inside the first
    millisecond) reaches the backend — everything after the deadline is
    dropped BEFORE dispatch and degrades to NULL."""
    pred = LatencyScriptedPredictor(echo_answers, sleep_per_call_s=0.05)
    db, _ = make_db(predictor=pred, deadline_ms=1, retry_backoff_s=0.0)
    with db:
        res = db.sql(q("dl"))
    assert len(pred.dispatch_log) <= 1, "expired work must not dispatch"
    assert any(r["t"] is None for r in res.table.rows())
    assert len(res.table.rows()) == 24   # degraded, not crashed
    assert res.stats.deadline_drops > 0


def test_with_clause_deadline_overrides_session_default():
    """Precedence (paper §5.3): WITH (deadline_ms=...) beats the session
    option in both directions."""
    # generous session default, impossible WITH -> drops
    pred1 = LatencyScriptedPredictor(echo_answers, sleep_per_call_s=0.05)
    db1, _ = make_db(predictor=pred1, deadline_ms=60000)
    with db1:
        r1 = db1.sql("SELECT a, LLM m (PROMPT 'w {tag VARCHAR} of "
                     "{{txt}}') WITH (deadline_ms=1) AS t FROM T")
    assert len(pred1.dispatch_log) <= 1
    assert any(r["t"] is None for r in r1.table.rows())
    assert r1.stats.deadline_drops > 0
    # impossible session default, generous WITH -> serves normally
    pred2 = LatencyScriptedPredictor(echo_answers)
    db2, _ = make_db(predictor=pred2, deadline_ms=1)
    with db2:
        r2 = db2.sql("SELECT a, LLM m (PROMPT 'w {tag VARCHAR} of "
                     "{{txt}}') WITH (deadline_ms=60000) AS t FROM T")
    assert len(pred2.dispatch_log) > 0
    assert all(r["t"] is not None for r in r2.table.rows())
    assert r2.stats.deadline_drops == 0


def test_retry_paths_recheck_deadline_per_attempt():
    """With every call transiently failing and a short deadline, the
    retry loop gives up on the deadline check instead of burning the
    full retry budget per prompt for the whole run."""
    inj = FaultInjector(LatencyScriptedPredictor(echo_answers,
                                                 base_latency_s=0.0),
                        seed=1, transient_rate=1.0)
    # hang-free: the injector fails occurrence 0, succeeds occurrence 1 —
    # but a 120ms deadline with real 60ms sleeps between retries expires
    # mid-run, and the remaining chunks must drop without dispatching
    db, _ = make_db(n=64, chunk=8, predictor=inj, deadline_ms=120,
                    retry_backoff_s=0.06)
    with db:
        res = db.sql(q("ddl"))
    assert res.stats.deadline_drops > 0
    assert len(res.table.rows()) == 64   # degraded, not crashed


def test_deadline_ms_zero_is_no_deadline():
    db, pred = make_db(deadline_ms=0)
    with db:
        res = db.sql(q("nodl"))
    assert res.stats.deadline_drops == 0
    assert all(r["t"] is not None for r in res.table.rows())


# ---------------------------------------------------------------------------
# snapshot format (unit)
# ---------------------------------------------------------------------------
def test_snapshot_roundtrip_and_checksum(tmp_path):
    payload = {"x": [1, 2, 3], "y": {"z": "w"}}
    assert _decode(_encode(payload)) == payload
    blob = bytearray(_encode(payload))
    blob[-1] ^= 0xFF
    with pytest.raises(SnapshotError):
        _decode(bytes(blob))
    with pytest.raises(SnapshotError):
        _decode(b"NOTASNAP" + bytes(blob))


def test_snapshot_dir_versioning_pruning_and_fallback(tmp_path):
    d = str(tmp_path)
    p1 = write_snapshot(d, {"v": 1}, keep=2)
    p2 = write_snapshot(d, {"v": 2}, keep=2)
    p3 = write_snapshot(d, {"v": 3}, keep=2)
    files = snapshot_files(d)
    assert files == [p3, p2]             # newest first, pruned to keep=2
    assert p1 not in files
    payload, path, skipped = load_latest(d)
    assert payload == {"v": 3} and path == p3 and skipped == []
    # corrupt the newest: the loader falls back to the next-older file
    with open(p3, "r+b") as f:
        f.seek(20)
        f.write(b"\x00\x00\x00\x00")
    payload, path, skipped = load_latest(d)
    assert payload == {"v": 2} and path == p2 and skipped == [p3]
    # corrupt everything: cold start, not an exception
    with open(p2, "r+b") as f:
        f.seek(20)
        f.write(b"\x00\x00\x00\x00")
    payload, path, skipped = load_latest(d)
    assert payload is None and path is None and len(skipped) == 2


# ---------------------------------------------------------------------------
# warm-state restore through the database
# ---------------------------------------------------------------------------
def test_warm_restart_serves_repeat_query_with_zero_calls(tmp_path):
    snapdir = str(tmp_path)
    db1, pred1 = make_db(snapshot_dir=snapdir)
    with db1:
        ref = db1.sql(q("warm"))
        assert len(pred1.dispatch_log) > 0
        path = db1.save_snapshot()
    assert path is not None and os.path.exists(path)

    db2, pred2 = make_db(snapshot_dir=snapdir)
    assert db2.restored_snapshot == path
    with db2:
        got = db2.sql(q("warm"))
    assert len(pred2.dispatch_log) == 0, \
        "warm restore must answer from the restored prompt cache"
    assert got.table.rows() == ref.table.rows()
    assert got.stats.prompt_cache_hits == 24
    # the statistics store came back too: the predicate's history exists
    assert db2.stats_store.export_state()["predicates"]


def test_corrupt_snapshot_means_cold_start(tmp_path):
    snapdir = str(tmp_path)
    db1, _ = make_db(snapshot_dir=snapdir)
    with db1:
        db1.sql(q("cold"))
        path = db1.save_snapshot()
    with open(path, "r+b") as f:
        f.seek(30)
        f.write(b"garbage!")
    db2, pred2 = make_db(snapshot_dir=snapdir)
    assert db2.restored_snapshot is None
    assert db2.snapshot_skipped == [path]
    with db2:
        res = db2.sql(q("cold"))
    assert len(pred2.dispatch_log) > 0   # cold: the backend was consulted
    assert all(r["t"] is not None for r in res.table.rows())


def test_save_snapshot_without_dir_is_a_noop():
    db, _ = make_db()
    with db:
        assert db.save_snapshot() is None


# ---------------------------------------------------------------------------
# radix prefix-cache KV warm restore (jax engine)
# ---------------------------------------------------------------------------
def test_radix_snapshot_restore_warms_prefix_tree():
    """export_radix_state/restore_radix_state on a fresh engine: restored
    pages serve repeat prompts from the tree (radix hits, strictly less
    prefill) with byte-identical outputs; a geometry mismatch restores
    nothing instead of corrupting the pool."""
    import repro.configs as C
    from repro.serving.engine import InferenceEngine
    from repro.serving.grammar import Field, JsonGrammar

    cfg = C.get_smoke_config("olmo-1b").replace(vocab_size=259,
                                                compute_dtype="float32")
    mk = lambda ps=32: InferenceEngine(cfg, seed=0, max_len=512,  # noqa: E731
                                       kv_layout="paged", page_size=ps)
    prefix = "SHARED INSTRUCTION BLOCK: extract the field from the row. " * 3
    g = JsonGrammar([Field("x", "INTEGER")])
    rows = [f"row {i}: value {i * 7}" for i in range(4)]
    e1 = mk()
    r1 = e1.generate(rows, grammar=g, shared_prefix=prefix,
                     max_new_tokens=32)
    state = e1.export_radix_state()
    assert state is not None and state["entries"]
    e2 = mk()
    assert e2.restore_radix_state(state) > 0
    r2 = e2.generate(rows, grammar=g, shared_prefix=prefix,
                     max_new_tokens=32)
    assert r2.texts == r1.texts
    assert r2.stats.radix_hit_tokens > 0     # warm from the restore alone
    assert r2.stats.prefill_tokens < r1.stats.prefill_tokens
    # a snapshot taken at a different page size restores nothing
    assert mk(ps=64).restore_radix_state(state) == 0


# ---------------------------------------------------------------------------
# cascade degradation under an expensive-stage outage
# ---------------------------------------------------------------------------
def _i_of(row) -> int:
    try:
        return int(str(row.get("txt", "0")).split()[-1])
    except ValueError:
        return 0


def truth_answers(instruction, rows):
    return [{"flag": _i_of(r) % 2 == 0} for r in rows]


def banded_proxy(instruction, rows):
    out = []
    for r in rows:
        i = _i_of(r)
        if i % 4 == 0:
            out.append({"flag": i % 2 != 0, "__confidence__": 0.3})
        else:
            out.append({"flag": i % 2 == 0, "__confidence__": 0.95})
    return out


def test_cascade_degrades_proxy_only_when_expensive_stage_is_down():
    """Expensive backend in a permanent outage: routed batches keep the
    proxy's answers for the escalation band, the batch is recorded as
    degraded, and EXPLAIN's cascade section says so."""
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"row {i}"} for i in range(48)]))
    dead = FaultInjector(LatencyScriptedPredictor(truth_answers,
                                                  base_latency_s=1.0),
                         seed=0, outage=(0, 10_000))
    register_scripted(db, "bigm", dead)
    register_scripted(db, "proxym",
                      LatencyScriptedPredictor(banded_proxy,
                                               base_latency_s=0.0625))
    db.set_option("batch_size", 16)
    db.set_option("enable_pilot", False)
    W = "WITH (cascade_proxy=proxym, cascade_target_precision=0.95)"
    Q1 = ("SELECT a FROM T WHERE a < 24 AND LLM bigm (PROMPT 'keep "
          "{flag BOOLEAN} of {{txt}}') " + W + " = TRUE")
    Q2 = ("SELECT a FROM T WHERE a >= 24 AND LLM bigm (PROMPT 'keep "
          "{flag BOOLEAN} of {{txt}}') " + W + " = TRUE")
    with db:
        db.sql(Q1)                       # warm calibration (proxy-only)
        res = db.sql(Q2, explain=True)
        plan = db.explain(Q2)
    assert dead.counters["outage_rejects"] > 0 or True
    # every row still resolved (proxy verdicts, nothing crashed)
    assert len(res.table.rows()) > 0
    assert res.stats.escalated_calls == 0    # no expensive call succeeded
    state = db.stats_store.export_state()
    assert any(rec["degraded_batches"] > 0
               for rec in state["cascades"].values())
    assert "status=degraded" in plan.replace(" ", "") \
        or "degraded" in plan


# ---------------------------------------------------------------------------
# EXPLAIN surface
# ---------------------------------------------------------------------------
def test_explain_always_carries_resilience_section():
    db, _ = make_db()
    with db:
        plan = db.explain(q("exp"))
    assert "-- resilience --" in plan
    assert "breakers: none tripped" in plan
    assert "policy: call_timeout_s=" in plan


def test_exec_stats_expose_resilience_counters():
    db, _ = make_db()
    with db:
        res = db.sql(q("fields"))
    d = dataclasses.asdict(res.stats)
    for field in ("transient_retries", "deadline_drops", "degraded_calls",
                  "backend_timeouts", "breaker_rejections"):
        assert d[field] == 0
