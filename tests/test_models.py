"""Per-architecture smoke tests: reduced config, one forward + one train
step on CPU, asserting output shapes + finite values; prefill/decode
consistency against the train-mode forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch import steps as ST
from repro.models import model as MDL
from repro.training import optim as OPT
from repro.models.config import ShapeSpec
from repro.training.data import DataConfig, synthetic_batch

ARCHS = C.ARCH_IDS


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = C.get_smoke_config(arch)
    B, S = 2, 32
    dcfg = DataConfig(batch=B, seq_len=S)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_batch(cfg, dcfg, 0).items()}
    shape = ShapeSpec("smoke", seq_len=S, global_batch=B, kind="train")
    step_fn, _ = ST.make_train_step(
        cfg, None, shape, num_micro=1, donate=False,
        opt_cfg=OPT.AdamWConfig(warmup_steps=0))
    state = ST.init_train_state(cfg, jax.random.PRNGKey(0))

    params = state["params"]
    logits, _ = MDL.forward(cfg, params, batch, mode="train")
    exp_len = S if cfg.family != "vlm" else S  # vlm: prefix+text = S
    assert logits.shape[0] == B and logits.shape[1] == exp_len
    assert logits.shape[2] == cfg.padded_vocab
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN in logits"

    new_state, metrics = step_fn(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if C.get_config(a).supports_decode])
def test_prefill_decode_matches_forward(arch):
    cfg = C.get_smoke_config(arch)
    if cfg.has_moe:
        cfg = cfg.replace(capacity_factor=8.0)   # no drops → exact match
    B, S = 2, 24
    key = jax.random.PRNGKey(1)
    params = MDL.init_params(cfg, key)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    batch = {"tokens": toks, "positions": pos}
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, cfg.num_prefix_tokens, cfg.d_model), jnp.bfloat16)
    full, _ = MDL.forward(cfg, params, batch, mode="train")

    P = cfg.num_prefix_tokens if cfg.family == "vlm" else 0
    cache = MDL.init_cache(cfg, B, P + S + 8)
    pre_batch = dict(batch)
    pre_batch["tokens"] = toks[:, :S - 1]
    pre_batch["positions"] = pos[:, :S - 1]
    _, cache = MDL.forward(cfg, params, pre_batch, mode="prefill", cache=cache)
    # decode positions are absolute (prefix offset included for VLM)
    dec, _ = MDL.forward(cfg, params,
                         {"tokens": toks[:, S - 1:],
                          "positions": pos[:, S - 1:] + P},
                         mode="decode", cache=cache)
    err = float(jnp.max(jnp.abs(dec[:, 0] - full[:, -1])))
    assert err < 2e-2, f"{arch}: decode/train mismatch {err}"


def test_extend_prefill_matches_full():
    """Chunked prefill with cache extension == one-shot prefill."""
    cfg = C.get_smoke_config("yi-6b")
    B, P, S = 2, 16, 16
    key = jax.random.PRNGKey(2)
    params = MDL.init_params(cfg, key)
    toks = jax.random.randint(key, (B, P + S), 0, cfg.vocab_size)
    pos = jnp.broadcast_to(jnp.arange(P + S, dtype=jnp.int32)[None], (B, P + S))

    full, _ = MDL.forward(cfg, params, {"tokens": toks, "positions": pos},
                          mode="train")

    cache = MDL.init_cache(cfg, B, P + S + 4)
    _, cache = MDL.forward(cfg, params,
                           {"tokens": toks[:, :P], "positions": pos[:, :P]},
                           mode="prefill", cache=cache)
    ext, cache = MDL.forward(cfg, params,
                             {"tokens": toks[:, P:], "positions": pos[:, P:]},
                             mode="prefill", cache=cache, extend_offset=P)
    err = float(jnp.max(jnp.abs(ext[:, -1] - full[:, -1])))
    assert err < 2e-2, f"extend mismatch {err}"


def test_param_counts_match_published():
    expected = {"mixtral-8x22b": 141e9, "qwen3-moe-30b-a3b": 30e9,
                "yi-6b": 6e9, "qwen2-7b": 7.6e9, "starcoder2-15b": 16e9,
                "falcon-mamba-7b": 7.3e9, "olmo-1b": 1.2e9,
                "paligemma-3b": 2.5e9, "hymba-1.5b": 1.6e9,
                "hubert-xlarge": 0.95e9}
    for arch, n in expected.items():
        got = C.get_config(arch).param_count()
        assert abs(got - n) / n < 0.08, f"{arch}: {got/1e9:.2f}B vs {n/1e9}B"


def test_actual_params_match_spec_tree():
    for arch in ARCHS:
        cfg = C.get_smoke_config(arch)
        params = MDL.init_params(cfg, jax.random.PRNGKey(0))
        assert MDL.param_count_actual(params) == cfg.param_count(padded=True)


def test_remat_policy_dots_trains():
    """§Perf opt D: the dots-saveable remat policy must train identically
    (same loss to fp tolerance) as full-recompute remat."""
    cfg = C.get_smoke_config("qwen2-7b")
    B, S = 2, 32
    from repro.training.data import DataConfig, synthetic_batch
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_batch(cfg, DataConfig(batch=B, seq_len=S), 0).items()}
    shape = ShapeSpec("smoke", seq_len=S, global_batch=B, kind="train")
    losses = []
    for pol in ("nothing", "dots"):
        step_fn, _ = ST.make_train_step(
            cfg, None, shape, donate=False, remat_policy=pol,
            opt_cfg=OPT.AdamWConfig(warmup_steps=0))
        state = ST.init_train_state(cfg, jax.random.PRNGKey(0))
        _, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert abs(losses[0] - losses[1]) < 1e-3, losses
