"""Learned rewrite engine + mid-query re-optimization tests (PR 9).

Covers the rewrite-pattern subsystem (`core.rewrite`): rule firing, the
validation gate, predicate implication, EXPLAIN's `-- rewrites --`
section, and the SemanticSelectStackOp's chunk-level re-ranking — every
rewrite and re-rank must keep result rows byte-identical while only ever
reducing LLM calls.  Also pins the PR's satellite bugfixes: the
prompt-cache namespace covering answer-shaping options (warm-vs-cold
byte equality at n_samples=4), `_find_base_column` ambiguity under
same-named columns, and heap-based cascade-reservoir eviction.
"""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.database import IPDB
from repro.core.optimizer import _find_base_column
from repro.core.rewrite import (RewriteEngine, predicate_implies,
                                predict_signature, rewrites_section)
from repro.core.stats import _CASCADE_RESERVOIR, CostModel, StatisticsStore
from repro.relational.binder import Binder
from repro.relational.parser import parse_sql
from repro.relational.plan import Join, Scan
from repro.relational.table import Table


# ---------------------------------------------------------------------------
# harness
# ---------------------------------------------------------------------------
def _score_oracle(instruction, rws):
    """Pure per-row oracle: integer score = last digit of txt, boolean
    flag = score parity."""
    out = []
    for r in rws:
        s = int(str(r.get("txt", "x0"))[-1])
        out.append({"score": s, "flag": s % 2 == 0,
                    "tag": f"t{s % 3}"})
    return out


def _mk_db(n=30, oracle=_score_oracle, **opts):
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"id": i, "txt": f"item {i}"} for i in range(n)]))
    db.register_oracle("orc", oracle)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    for k, v in opts.items():
        db.set_option(k, v)
    return db


def _assert_rows_identical(t1: Table, t2: Table):
    assert t1.column_names == t2.column_names
    assert len(t1) == len(t2)
    for c in t1.column_names:
        assert [repr(v) for v in t1.column(c)] == \
            [repr(v) for v in t2.column(c)], f"column {c} differs"


Q_DUP = ("SELECT id, LLM m (PROMPT 'rate {score INTEGER} of {{txt}}') AS s "
         "FROM T WHERE LLM m (PROMPT 'rate {score INTEGER} of {{txt}}') > 4")

Q_IMPLIED = ("SELECT id FROM T WHERE "
             "LLM m (PROMPT 'rate {score INTEGER} of {{txt}}') > 5 "
             "AND LLM m (PROMPT 'rate {score INTEGER} of {{txt}}') > 3")


# ---------------------------------------------------------------------------
# rewrite rules, end to end
# ---------------------------------------------------------------------------
def test_consolidation_reduces_calls_same_rows():
    on = _mk_db(use_dedup=False, use_batching=False)
    off = _mk_db(use_dedup=False, use_batching=False,
                 enable_rewrites=False)
    r_on, r_off = on.sql(Q_DUP), off.sql(Q_DUP)
    _assert_rows_identical(r_on.table, r_off.table)
    assert len(r_on.table) > 0
    assert r_on.stats.llm_calls < r_off.stats.llm_calls


def test_subsumption_drops_implied_unit():
    on = _mk_db(use_dedup=False, use_batching=False)
    off = _mk_db(use_dedup=False, use_batching=False,
                 enable_rewrites=False)
    r_on, r_off = on.sql(Q_IMPLIED), off.sql(Q_IMPLIED)
    _assert_rows_identical(r_on.table, r_off.table)
    assert len(r_on.table) > 0
    assert r_on.stats.llm_calls < r_off.stats.llm_calls


def test_explain_rewrites_golden():
    """EXPLAIN gets a `-- rewrites --` section naming the fired patterns
    with their benefit estimates."""
    db = _mk_db(use_dedup=False)
    text = db.explain(Q_DUP)
    assert "-- rewrites --" in text
    sect = text.split("-- rewrites --")[1]
    assert "consolidate_duplicate_predicts" in sect
    assert "fired" in sect
    assert "saves ~" in sect

    sect2 = db.explain(Q_IMPLIED).split("-- rewrites --")[1]
    assert "subsume_implied_select" in sect2
    assert "implied by" in sect2

    # no patterns on a plain relational query
    sect3 = db.explain("SELECT id FROM T WHERE id > 3") \
        .split("-- rewrites --")[1]
    assert "(no rewrites fired)" in sect3


def test_rewrites_flag_disables_engine():
    db = _mk_db(use_dedup=False, enable_rewrites=False)
    sect = db.explain(Q_DUP).split("-- rewrites --")[1]
    assert "(no rewrites fired)" in sect


def test_engine_scan_and_validation_gate():
    """Engine-level: scan() detects without rewriting; rewrite() output
    keeps the plan schema and never adds semantic work."""
    db = _mk_db()
    plan = Binder(db.catalog, db.options).bind_select(parse_sql(Q_DUP))
    eng = RewriteEngine(db.catalog, CostModel(StatisticsStore(), {}))
    found = eng.scan(plan)
    assert any(r == "consolidate_duplicate_predicts" for r, _, _ in found)

    new = eng.rewrite(plan)
    assert list(plan.schema(db.catalog).items()) == \
        list(new.schema(db.catalog).items())
    assert any(ev.action == "fired" for ev in eng.events)
    # the fired consolidation removed one Predict
    from repro.relational.plan import Predict, walk_plan
    n_old = sum(isinstance(x, Predict) for x in walk_plan(plan))
    n_new = sum(isinstance(x, Predict) for x in walk_plan(new))
    assert n_new == n_old - 1


def test_predicate_implies_table():
    cases_true = [
        (">", 5, ">", 3), (">", 5, ">", 5), (">", 5, ">=", 5),
        (">=", 5, ">", 3), (">=", 5, ">=", 5), ("<", 2, "<", 4),
        ("<", 2, "<=", 2), ("<=", 2, "<=", 2), ("=", 5, ">", 3),
        ("=", 5, "!=", 4), ("=", True, "=", True), ("!=", 3, "!=", 3),
        (">", 5, "!=", 5), ("<", 5.0, "!=", 5.0),
    ]
    cases_false = [
        (">", 3, ">", 5), (">=", 5, ">", 5), ("<", 4, "<", 2),
        ("=", 3, "=", 5), (">", 5, "<", 9), ("!=", 3, "=", 3),
        ("=", True, "=", False), (">=", 5, "!=", 5),
        # bool is not an int for interval logic
        (">", True, ">", 0), ("=", "x", ">", 3),
    ]
    for opa, va, opb, vb in cases_true:
        assert predicate_implies(opa, va, opb, vb), (opa, va, opb, vb)
    for opa, va, opb, vb in cases_false:
        assert not predicate_implies(opa, va, opb, vb), (opa, va, opb, vb)


def test_predict_signature_covers_answer_shaping():
    db = _mk_db()
    plan = Binder(db.catalog, db.options).bind_select(parse_sql(Q_DUP))
    from repro.relational.plan import Predict, walk_plan
    infos = [x.info for x in walk_plan(plan) if isinstance(x, Predict)]
    assert len(infos) == 2
    assert predict_signature(infos[0]) == predict_signature(infos[1])
    import dataclasses
    tweaked = dataclasses.replace(
        infos[0], options={**infos[0].options, "n_samples": 4})
    assert predict_signature(tweaked) != predict_signature(infos[1])
    # explicit default == omitted default
    explicit = dataclasses.replace(
        infos[0], options={**infos[0].options, "n_samples": 1})
    assert predict_signature(explicit) == predict_signature(infos[1])


def test_rewrites_section_format():
    assert rewrites_section([]) == "(no rewrites fired)"
    out = rewrites_section([], ["chunk 2: re-ranked to [a -> b]"])
    assert out == "reopt: chunk 2: re-ranked to [a -> b]"


# ---------------------------------------------------------------------------
# mid-query re-optimization
# ---------------------------------------------------------------------------
def _drift_oracle(n):
    """Pass rates invert halfway through the table: predicate p passes the
    first half (plus every 10th row), q passes the second half (plus every
    7th row)."""
    def orc(instruction, rws):
        out = []
        for r in rws:
            i = int(str(r.get("txt", "item 0")).split()[-1])
            if '"p"' in instruction:
                out.append({"p": i < n // 2 or i % 10 == 0})
            else:
                out.append({"q": i >= n // 2 or i % 7 == 0})
        return out
    return orc


Q_DRIFT = ("SELECT id FROM T WHERE "
           "LLM m (PROMPT 'check {p BOOLEAN} of {{txt}}') = TRUE "
           "AND LLM m (PROMPT 'check {q BOOLEAN} of {{txt}}') = TRUE")


def _drift_db(n, reopt):
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"id": i, "txt": f"item {i}"} for i in range(n)]))
    db.register_oracle("orc", _drift_oracle(n))
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("use_batching", False)
    db.set_option("enable_pilot", False)
    db.set_option("chunk_size", 25)
    db.set_option("enable_reopt", reopt)
    return db


def test_midquery_rerank_beats_stale_static_order():
    n = 200
    r_on = _drift_db(n, True).sql(Q_DRIFT, explain=True)
    r_off = _drift_db(n, False).sql(Q_DRIFT)
    _assert_rows_identical(r_on.table, r_off.table)
    assert len(r_on.table) > 0
    assert r_on.stats.reranks >= 1
    assert r_off.stats.reranks == 0
    assert r_on.stats.llm_calls < r_off.stats.llm_calls
    # the re-rank decisions show up in the post-run rewrites section
    assert "reopt: chunk" in r_on.plan.split("-- rewrites --")[1]


def test_single_chunk_stack_identical_to_static():
    """One chunk = no observation boundary mid-query: the stack operator
    must reproduce the static order's calls and rows exactly."""
    n = 40
    on = _drift_db(n, True)
    off = _drift_db(n, False)
    on.set_option("chunk_size", 2048)
    off.set_option("chunk_size", 2048)
    r_on, r_off = on.sql(Q_DRIFT), off.sql(Q_DRIFT)
    _assert_rows_identical(r_on.table, r_off.table)
    assert r_on.stats.llm_calls == r_off.stats.llm_calls


def test_stack_determinism_across_chunk_sizes():
    """Rows are byte-identical however the stream is chunked (and however
    often the stack re-ranks)."""
    ref = None
    for chunk in (1, 7, 25, 2048):
        db = _drift_db(120, True)
        db.set_option("chunk_size", chunk)
        r = db.sql(Q_DRIFT)
        if ref is None:
            ref = r.table
        else:
            _assert_rows_identical(ref, r.table)


# ---------------------------------------------------------------------------
# equivalence sweep (seeded; runs without hypothesis) + property harness
# ---------------------------------------------------------------------------
RW_QUERIES = [Q_DUP, Q_IMPLIED, Q_DRIFT,
              "SELECT id, LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') "
              "AS g FROM T WHERE "
              "LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') = 't1'"]


def _equiv_oracle(instruction, rws):
    out = []
    for r in rws:
        h = sum(map(ord, str(sorted(r.items()))))
        out.append({"score": h % 10, "flag": h % 3 == 0, "tag": f"t{h % 3}",
                    "p": h % 2 == 0, "q": h % 5 != 0})
    return out


def _equiv_db(rows, chunk, rewrites, reopt):
    db = IPDB()
    db.register_table("T", Table.from_rows(rows))
    db.register_oracle("orc", _equiv_oracle)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("chunk_size", chunk)
    db.set_option("enable_pilot", False)
    db.set_option("enable_rewrites", rewrites)
    db.set_option("enable_reopt", reopt)
    return db


def _check_equiv(n, seed, chunk, qi, rewrites, reopt):
    rng = np.random.default_rng(seed)
    rows = [{"id": i, "txt": f"item {int(rng.integers(0, 9))}{i % 7}"}
            for i in range(n)]
    q = RW_QUERIES[qi]
    r0 = _equiv_db(rows, chunk, False, False).sql(q)
    r1 = _equiv_db(rows, chunk, rewrites, reopt).sql(q)
    _assert_rows_identical(r0.table, r1.table)
    if rewrites and not reopt:
        # pure plan rewrites may only reduce (or keep) call counts;
        # re-ranking is adaptive and judged by the drift benchmark instead
        assert r1.stats.llm_calls <= r0.stats.llm_calls


def test_rewrite_equivalence_sweep():
    for seed in range(4):
        for qi in range(len(RW_QUERIES)):
            for chunk in (5, 2048):
                _check_equiv(18 + 3 * seed, seed, chunk, qi,
                             rewrites=True, reopt=bool(seed % 2))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 30), seed=st.integers(0, 10_000),
       chunk=st.sampled_from([3, 11, 2048]),
       qi=st.integers(0, len(RW_QUERIES) - 1),
       rewrites=st.booleans(), reopt=st.booleans())
def test_rewrite_equivalence_property(n, seed, chunk, qi, rewrites, reopt):
    _check_equiv(n, seed, chunk, qi, rewrites, reopt)


# ---------------------------------------------------------------------------
# satellite 1: prompt-cache namespace covers answer-shaping options
# ---------------------------------------------------------------------------
def _sample_sensitive_db():
    """Backend whose answers depend on the n_samples option — a namespace
    that omits it would let a warm cache serve wrong-mode answers."""
    from helpers import LatencyScriptedPredictor, register_scripted
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"id": i, "txt": f"item {i}"} for i in range(12)]))
    box = {}

    def ans(instruction, rws):
        ns = int(box["p"].options.get("n_samples", 1))
        return [{"tag": f"s{ns}:{r.get('txt', '')}"} for r in rws]

    box["p"] = LatencyScriptedPredictor(ans, base_latency_s=0.01)
    register_scripted(db, "m", box["p"])
    return db


Q_NS = ("SELECT id, LLM m (PROMPT 'get {tag VARCHAR} of {{txt}}') AS tag "
        "FROM T")


def test_warm_vs_cold_byte_identical_at_n_samples_4():
    cold = _sample_sensitive_db()
    cold.set_option("n_samples", 4)
    r_cold = cold.sql(Q_NS)
    assert all(str(v).startswith("s4:") for v in r_cold.table.column("tag"))

    warm = _sample_sensitive_db()
    r1 = warm.sql(Q_NS)                 # warms the cache at n_samples=1
    assert all(str(v).startswith("s1:") for v in r1.table.column("tag"))
    warm.set_option("n_samples", 4)
    r_warm = warm.sql(Q_NS)             # must NOT reuse the s1 answers
    _assert_rows_identical(r_cold.table, r_warm.table)

    # and the n_samples=4 namespace caches normally against itself
    r_again = warm.sql(Q_NS)
    _assert_rows_identical(r_warm.table, r_again.table)
    assert r_again.stats.prompt_cache_hits > 0
    # switching back must also return to the single-sample answers
    warm.set_option("n_samples", 1)
    _assert_rows_identical(r1.table, warm.sql(Q_NS).table)


# ---------------------------------------------------------------------------
# satellite 2: _find_base_column ambiguity under same-named columns
# ---------------------------------------------------------------------------
def test_find_base_column_ambiguous_join_returns_none():
    db = IPDB()
    db.register_table("A", Table.from_rows(
        [{"k": i, "txt": "short"} for i in range(4)]))
    db.register_table("B", Table.from_rows(
        [{"k": i, "txt": "a much longer text value " * 8} for i in range(4)]))
    cat = db.catalog
    # unique owner: resolved
    col = _find_base_column(Scan("A"), "txt", cat)
    assert col is not None and list(col) == ["short"] * 4
    # two tables share the name: ambiguous, sizing must not guess
    join = Join(Scan("A"), Scan("B"), "inner", ["k"], ["k"])
    assert _find_base_column(join, "txt", cat) is None
    assert _find_base_column(Join(Scan("B"), Scan("A"), "inner", ["k"],
                                  ["k"]), "txt", cat) is None
    # a self-join is not ambiguous
    self_join = Join(Scan("A"), Scan("A"), "inner", ["k"], ["k"])
    assert _find_base_column(self_join, "txt", cat) is not None
    # column that only one side carries stays resolvable
    assert _find_base_column(join, "k", cat) is None  # both carry k
    db.register_table("C", Table.from_rows([{"z": 1}]))
    jc = Join(Scan("A"), Scan("C"), "cross")
    assert _find_base_column(jc, "z", cat) is not None


# ---------------------------------------------------------------------------
# satellite 3: heap-based cascade reservoir eviction
# ---------------------------------------------------------------------------
def test_cascade_reservoir_heap_keeps_smallest_hashes():
    store = StatisticsStore()
    key = ("m", "instr")
    rng = np.random.default_rng(7)
    hashes = [int(h) for h in
              rng.choice(10**9, size=_CASCADE_RESERVOIR + 200,
                         replace=False)]
    for h in hashes:
        store.record_cascade_agreement(key, h, conf=h % 100 / 100.0,
                                       verdict=bool(h % 2),
                                       agree=bool(h % 3), audited=False)
    rec = store.cascade_entry(key)
    # retained set == the reservoir-many smallest hashes, same as the old
    # sort-based eviction produced
    expect = set(sorted(hashes)[:_CASCADE_RESERVOIR])
    assert set(rec.reservoir) == expect
    # updates to an already-retained hash stay in place
    kept = min(hashes)
    store.record_cascade_agreement(key, kept, conf=0.99, verdict=True,
                                   agree=True, audited=False)
    assert rec.reservoir[kept] == (0.99, True, True)
    assert set(rec.reservoir) == expect
    # insertion order cannot change the converged set
    store2 = StatisticsStore()
    for h in reversed(hashes):
        store2.record_cascade_agreement(key, h, conf=0.5, verdict=True,
                                        agree=True, audited=False)
    assert set(store2.cascade_entry(key).reservoir) == expect
