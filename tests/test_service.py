"""InferenceService: queue batching, in-flight dedup, cancellation,
dispatch-group makespan accounting, and the JAX continuous-batching
dispatch path (one batcher run replaces N sequential generate calls)."""
import json

import pytest

from repro.core.cancel import QueryCancelled
from repro.core.database import IPDB
from repro.core.executors import CallResult, Predictor
from repro.core.service import (InferenceRequest, InferenceService,
                                makespan)
from repro.relational.table import Table


class CountingExecutor(Predictor):
    """Fake backend: constant answer, 0.5 s modeled latency per call."""
    name = "counting"

    def __init__(self):
        self.options = {}
        self.batches = []              # dispatch sizes, in order

    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        return CallResult(json.dumps({"x": 1}), 1, 1, 0.5, 0.0)

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        self.batches.append(len(prompts))
        return [self.complete(p, schema, nr)
                for p, nr in zip(prompts, num_rows_list)]


def _req(ex, prompt, *, instruction="i", dedup=True, session="", tenant=""):
    return InferenceRequest(
        model_name="m", instruction=instruction, prompt=prompt,
        schema=(("x", "INTEGER"),), num_rows=1, executor=ex,
        dedup=dedup, session=session, tenant=tenant)


def test_submit_flush_batches_one_queue():
    svc = InferenceService()
    ex = CountingExecutor()
    g = svc.open_group(workers=2)
    handles = svc.submit([_req(ex, f"p{i}") for i in range(5)])
    assert svc.pending == 5 and not any(h.done for h in handles)
    svc.flush()
    assert svc.pending == 0 and all(h.done for h in handles)
    assert ex.batches == [5]           # one complete_many for the queue
    assert svc.stats.dispatch_batches == 1
    assert svc.stats.mean_batch_occupancy == 5.0
    # group accounting: 5 calls of 0.5s on 2 workers -> greedy makespan
    for h in handles:
        g.latencies.append(h.result().sim_latency_s)
    assert g.makespan() == pytest.approx(makespan([0.5] * 5, 2))
    assert g.serial() == pytest.approx(2.5)


def test_inflight_dedup_joins_pending_handle():
    svc = InferenceService()
    ex = CountingExecutor()
    h1, o1 = svc.submit_one(_req(ex, "a"))
    h2, o2 = svc.submit_one(_req(ex, "a"))    # identical, still pending
    assert o1 and not o2 and h2 is h1
    assert svc.stats.inflight_dedup_hits == 1
    svc.flush()
    assert ex.batches == [1]
    assert h1.result().text == h2.result().text
    # after resolution the request is no longer in flight: re-dispatches
    h3, o3 = svc.submit_one(_req(ex, "a"))
    assert o3 and h3 is not h1
    svc.flush()
    assert ex.batches == [1, 1]


def test_dedup_disabled_never_joins():
    svc = InferenceService()
    ex = CountingExecutor()
    h1, _ = svc.submit_one(_req(ex, "a", dedup=False))
    h2, o2 = svc.submit_one(_req(ex, "a", dedup=False))
    assert o2 and h2 is not h1
    svc.flush()
    assert ex.batches == [2]           # both dispatched


def test_result_triggers_flush_and_cancel_drops_queued():
    svc = InferenceService()
    ex = CountingExecutor()
    h1, _ = svc.submit_one(_req(ex, "a"))
    h2, _ = svc.submit_one(_req(ex, "b"))
    assert svc.cancel(h2)              # still queued: removable
    assert h1.result().text            # implicit flush
    assert ex.batches == [1]           # cancelled request never dispatched
    assert not svc.cancel(h1)          # already resolved
    with pytest.raises(RuntimeError):
        h2.result()


def test_executor_failure_does_not_poison_inflight():
    """If the backend raises mid-dispatch, later identical submits must
    re-dispatch rather than join a handle that can never resolve."""

    class Flaky(CountingExecutor):
        def __init__(self):
            super().__init__()
            self.fail = True

        def complete_many(self, prompts, *a, **kw):
            if self.fail:
                self.fail = False
                raise RuntimeError("backend down")
            return super().complete_many(prompts, *a, **kw)

    svc = InferenceService()
    ex = Flaky()
    svc.submit_one(_req(ex, "a"))
    with pytest.raises(RuntimeError):
        svc.flush()
    h, owned = svc.submit_one(_req(ex, "a"))
    assert owned                       # fresh handle, not a join
    svc.flush()
    assert h.done and h.result().text


def test_cancel_is_refcounted_with_joiners():
    """Cancelling one submitter of a shared handle keeps the request
    queued for the joiner; only the last cancel drops it."""
    svc = InferenceService()
    ex = CountingExecutor()
    h1, _ = svc.submit_one(_req(ex, "a"))
    h2, o2 = svc.submit_one(_req(ex, "a"))
    assert h2 is h1 and not o2
    assert not svc.cancel(h1)          # joiner still interested
    assert svc.pending == 1
    assert svc.cancel(h2)              # last reference released
    assert svc.pending == 0
    svc.flush()
    assert ex.batches == []            # nothing was dispatched


def test_shared_handle_one_cancel_other_resolves_one_dispatch():
    """Refcount regression (the PR 8 edge): a handle joined by in-flight
    dedup must survive one submitter cancelling while the other still
    waits — the survivor gets a real result from exactly one dispatched
    call, and a late duplicate cancel cannot strip its reference."""
    svc = InferenceService()
    ex = CountingExecutor()
    h1, _ = svc.submit_one(_req(ex, "shared"))
    h2, o2 = svc.submit_one(_req(ex, "shared"))
    assert h2 is h1 and not o2 and h1.refs == 2
    assert not svc.cancel(h1)          # submitter A unwinds early
    assert h1.refs == 1                # joiner's reference survives
    assert h2.result().text            # submitter B still resolves
    assert ex.batches == [1]           # exactly one dispatched call
    assert not svc.cancel(h2)          # late cancel on a done handle: no-op
    assert h1.refs == 1                # and no underflow below the floor


def test_sessions_never_share_handles_and_cancel_is_isolated():
    """Two sessions submitting the byte-identical prompt must NOT join:
    the session tag is part of the dedup key precisely so cancelling one
    session can never strip a handle another session is waiting on."""
    svc = InferenceService()
    ex = CountingExecutor()
    ha, oa = svc.submit_one(_req(ex, "same", session="sA"))
    hb, ob = svc.submit_one(_req(ex, "same", session="sB"))
    assert oa and ob and ha is not hb
    assert svc.cancel_session("sA") == 1
    with pytest.raises(QueryCancelled):
        ha.result()
    assert hb.result().text            # session B untouched
    assert ex.batches == [1]
    assert svc.session_pending("sA") == 0
    svc.release_session("sA")


def test_cancel_after_session_force_fail_never_underflows():
    """cancel_session force-fails queued handles (refs -> 0); the owning
    pipeline then unwinds and calls cancel() on the same handles.  That
    late cancel must be a no-op — not an underflow that could corrupt a
    later joiner's refcount."""
    svc = InferenceService()
    ex = CountingExecutor()
    h, _ = svc.submit_one(_req(ex, "p", session="s1"))
    assert svc.cancel_session("s1") == 1
    assert h.refs == 0 and h.done
    assert not svc.cancel(h)           # unwinding pipeline's late cancel
    assert h.refs == 0                 # floored, no -1
    # tombstone: resubmits for the cancelled session fail fast...
    with pytest.raises(QueryCancelled):
        svc.submit_one(_req(ex, "p2", session="s1"))
    # ...until the session is released, after which the tag is reusable
    svc.release_session("s1")
    h2, _ = svc.submit_one(_req(ex, "p2", session="s1"))
    assert h2.result().text


def test_cancel_session_wakes_lane_blocked_waiter():
    """A handle scheduled onto a full worker lane (so its submitter waits
    on the dispatch event) must be woken with QueryCancelled — and its
    never-started lane task dropped — when its session is cancelled from
    another thread, without waiting for the running batches."""
    import threading as _t

    class Gated(CountingExecutor):
        def __init__(self, gate):
            super().__init__()
            self.options = {"dispatch_workers": 2}
            self.max_concurrency = 2
            self.gate = gate
            self.started = []
            self._slock = _t.Lock()

        def complete_many(self, prompts, *a, **kw):
            with self._slock:
                self.started.append(list(prompts))
            assert self.gate.wait(timeout=10)
            return super().complete_many(prompts, *a, **kw)

    gate = _t.Event()
    svc = InferenceService()
    ex = Gated(gate)
    # two untagged batches fill both lane workers; the tagged request is
    # scheduled third and stays in lane.pending, never started
    svc.submit_one(_req(ex, "g1", instruction="i1"))
    svc.submit_one(_req(ex, "g2", instruction="i2"))
    h_queued, _ = svc.submit_one(_req(ex, "victim", instruction="i3",
                                      session="s2"))
    svc.flush()                        # schedules all three on the lane
    deadline = 250
    while len(ex.started) < 2 and deadline:    # both workers gate-blocked
        deadline -= 1
        _t.Event().wait(0.02)
    assert len(ex.started) == 2
    outcome = {}

    def waiter():
        try:
            outcome["res"] = h_queued.result()
        except BaseException as e:
            outcome["err"] = e

    t = _t.Thread(target=waiter)
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive()                # parked on the dispatch event
    dropped = svc.cancel_session("s2")
    t.join(timeout=5)
    assert not t.is_alive()
    assert dropped == 1
    assert isinstance(outcome.get("err"), QueryCancelled)
    gate.set()                         # release the running batches
    svc.wait_idle(timeout=5)
    assert [sorted(p)[0] for p in ex.started] == ["g1", "g2"]  # never ran
    svc.release_session("s2")
    svc.shutdown()


def test_separate_instructions_separate_batches_and_max_dispatch():
    svc = InferenceService(max_dispatch=2)
    ex = CountingExecutor()
    svc.submit([_req(ex, f"p{i}", instruction="i1") for i in range(5)])
    svc.submit([_req(ex, "q", instruction="i2")])
    svc.drain()
    # i1 queue split into 2+2+1 by the dispatch cap, i2 alone
    assert sorted(ex.batches) == [1, 1, 2, 2]


# ---------------------------------------------------------------------------
def test_jax_batched_dispatch_single_batcher_run(monkeypatch):
    """A jax: model query dispatches its marshaled prompts through ONE
    ContinuousBatcher.run instead of N sequential engine.generate calls."""
    from repro.serving.engine import InferenceEngine
    from repro.serving.scheduler import ContinuousBatcher

    calls = {"run": 0, "run_sizes": [], "generate": 0}
    orig_run = ContinuousBatcher.run
    orig_gen = InferenceEngine.generate

    def spy_run(self, requests, **kw):
        calls["run"] += 1
        calls["run_sizes"].append(len(requests))
        return orig_run(self, requests, **kw)

    def spy_gen(self, prompts, **kw):
        calls["generate"] += 1
        return orig_gen(self, prompts, **kw)

    monkeypatch.setattr(ContinuousBatcher, "run", spy_run)
    monkeypatch.setattr(InferenceEngine, "generate", spy_gen)

    d = IPDB()
    d.register_table("Items", Table.from_rows(
        [{"name": f"item{i}"} for i in range(4)]))
    d.sql("CREATE LLM MODEL tiny PATH 'jax:olmo-1b' ON PROMPT "
          "OPTIONS { 'batch_size': 2, 'max_str': 6 }")
    r = d.sql("SELECT name, LLM tiny (PROMPT 'guess the {color VARCHAR} "
              "of {{name}}') AS color FROM Items")
    assert len(r.table) == 4
    assert all(isinstance(c, str) for c in r.table.column("color"))
    # 4 rows / batch_size 2 -> 2 marshaled prompts -> ONE batched run
    assert calls["run"] == 1 and calls["run_sizes"] == [2]
    assert calls["generate"] == 0
    assert r.stats.llm_calls == 2
    assert r.stats.dispatch_batches == 1
    assert r.stats.mean_batch_occupancy == pytest.approx(2.0)


def test_semantic_join_reports_batch_occupancy():
    """The semantic-join dispatch pattern fills service batches: mean
    occupancy across complete_many dispatches is > 1."""
    db = IPDB()
    db.register_table("L", Table.from_rows(
        [{"lid": i, "ltxt": f"left {i}"} for i in range(6)]))
    db.register_table("R", Table.from_rows(
        [{"rid": i, "rtxt": f"right {i}"} for i in range(6)]))
    db.register_oracle("orc", lambda ins, rows: [
        {"match": (str(r.get("ltxt", ""))[-1] == str(r.get("rtxt", ""))[-1])}
        for r in rows])
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    r = db.sql("SELECT lid, rid FROM L JOIN R ON "
               "LLM m (PROMPT 'is {{ltxt}} {match BOOLEAN} with {{rtxt}}')")
    assert len(r.table) == 6               # diagonal matches
    assert r.stats.dispatch_batches >= 1
    assert r.stats.mean_batch_occupancy > 1.0
