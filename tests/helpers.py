"""Shared fakes for the dispatch-concurrency test suite.

`LatencyScriptedPredictor` is a deterministic stand-in for a remote
backend: answers and modeled latencies are pure functions of the prompt
text, so results and accounting are bit-identical no matter which worker
thread dispatched a call or in which order batches finished.  Tests force
worst-case interleavings through the `gate` hook (barriers / events run
at the start of every dispatch) and observe scheduling through the
thread-safe `dispatch_log`.
"""
import json
import threading
import time
import zlib

from repro.core.executors import CallResult, Predictor


class LatencyScriptedPredictor(Predictor):
    """Deterministic fake backend with scripted latency and dispatch hooks.

    * `answer_fn(instruction, rows) -> List[dict]` supplies answers (same
      contract as a registered oracle) and must be pure;
    * modeled latency is keyed by the prompt text alone and is always an
      exact binary fraction (multiples of 1/64 s), so float sums of any
      subset are exact in ANY accumulation order — concurrent dispatch
      cannot perturb aggregated latency statistics even in the last bit;
    * `gate(predictor, prompts)` runs at the start of every dispatch —
      install a `threading.Barrier` to force two backends to be mid-flight
      simultaneously, or an `Event` wait to hold a flush open;
    * `sleep_per_call_s` adds real wall time per call (overlap tests);
    * `dispatch_log` records `(thread_name, batch_size)` per dispatch.
    """
    name = "scripted"

    def __init__(self, answer_fn, *, base_latency_s: float = 0.25,
                 latency_fn=None, max_concurrency: int = 8, gate=None,
                 sleep_per_call_s: float = 0.0):
        self.options = {}
        self.answer_fn = answer_fn
        self.base_latency_s = float(base_latency_s)
        self.latency_fn = latency_fn
        self.max_concurrency = int(max_concurrency)
        self.gate = gate
        self.sleep_per_call_s = float(sleep_per_call_s)
        self._log_lock = threading.Lock()
        self.dispatch_log = []

    def latency_for(self, prompt: str) -> float:
        if self.latency_fn is not None:
            return float(self.latency_fn(prompt))
        return self.base_latency_s + (zlib.crc32(prompt.encode()) % 8) / 64.0

    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        if self.sleep_per_call_s:
            time.sleep(self.sleep_per_call_s)
        answers = self.answer_fn(
            instruction, rows if rows else [{}] * max(1, num_rows))
        take = answers if num_rows == 0 else answers[:num_rows]
        objs = [{n: a.get(n) for n, _ in schema} for a in take]
        confs = [float(a.get("__confidence__", 1.0)) for a in take]
        while len(objs) < num_rows:
            objs.append({n: None for n, _ in schema})
            confs.append(0.0)
        text = json.dumps(objs[0] if num_rows == 1 else objs)
        return CallResult(text, max(1, len(shared_prefix + prompt) // 4),
                          max(1, len(text) // 4), self.latency_for(prompt),
                          self.sleep_per_call_s,
                          confidences=confs if num_rows > 0 else None)

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        if self.gate is not None:
            self.gate(self, list(prompts))
        with self._log_lock:
            self.dispatch_log.append(
                (threading.current_thread().name, len(prompts)))
        rows_list = rows_list if rows_list is not None \
            else [None] * len(prompts)
        return [self.complete(p, schema, nr, shared_prefix=shared_prefix,
                              rows=r, instruction=instruction)
                for p, nr, r in zip(prompts, num_rows_list, rows_list)]


def drain_stream(stream):
    """Collect one QueryStream: returns (rows, ExecStats).  Rows come out
    in chunk order, so equal inputs must produce byte-equal lists."""
    rows = []
    for chunk in stream.chunks():
        rows.extend(chunk.rows())
    return rows, stream.stats


def stream_stats_dict(stats) -> dict:
    """ExecStats as a comparable dict: drop wall_s (real time, the one
    honest nondeterminism) — everything else must match exactly across
    interleavings and worker counts."""
    import dataclasses as _dc
    d = _dc.asdict(stats)
    d.pop("wall_s")
    return d


def run_sessions(db, queries, *, concurrent: bool, start_barrier=None):
    """Multi-session determinism harness: run one `db.stream` per entry of
    `queries` ([(tenant, sql), ...]) either serially (submission order) or
    on N threads released together (plus `start_barrier`, if given, as an
    extra alignment hook for worst-case interleavings).  Returns the
    per-query list of (rows, stats_dict) in QUERY order regardless of
    completion order — the serial and concurrent return values of
    identical workloads must compare equal."""
    outcomes = [None] * len(queries)

    def one(i, tenant, sql):
        rows, stats = drain_stream(db.stream(sql, tenant=tenant))
        outcomes[i] = (rows, stream_stats_dict(stats))

    if not concurrent:
        for i, (tenant, sql) in enumerate(queries):
            one(i, tenant, sql)
        return outcomes
    errors = []

    def runner(i, tenant, sql):
        try:
            if start_barrier is not None:
                start_barrier.wait(timeout=10)
            one(i, tenant, sql)
        except BaseException as e:      # surfaced to the caller
            errors.append(e)

    threads = [threading.Thread(target=runner, args=(i, t, q),
                                name=f"session-{i}")
               for i, (t, q) in enumerate(queries)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    if errors:
        raise errors[0]
    return outcomes


def register_scripted(db, model_name: str, predictor: Predictor) -> None:
    """Bind a (usually shared) predictor instance to a model name through
    the custom-executor registry, so scripted backends run the full SQL
    parse → optimize → physical-pipeline → service path."""
    key = f"exec_{model_name}"
    db.register_executor(key, lambda entry: predictor)
    db.sql(f"CREATE LLM MODEL {model_name} PATH 'custom:{key}' ON PROMPT")
