"""Adaptive statistics + cost-model subsystem tests: store feedback from
execution, pilot sampling (calibration, caching, amortization guard),
cost-based select ordering under skewed selectivities (property: the
cost/(1-sel) rank never increases expected stack cost or, at uniform
cost, expected call count), the `_filter_used` regression, and the
EXPLAIN `-- stats --` section."""
import itertools
import os
import sys

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.database import IPDB
from repro.core.optimizer import Optimizer
from repro.core.stats import (CostModel, StatisticsStore, expected_stack_cost,
                              order_rank, stats_key)
from repro.relational.binder import Binder
from repro.relational.parser import parse_sql
from repro.relational.table import Table

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# skewed two-predicate workload (shared by several tests)
# ---------------------------------------------------------------------------
def skew_oracle(instruction, rows):
    out = []
    for r in rows:
        if "long_txt" in r:
            i = int(str(r["long_txt"]).split()[-1])
            out.append({"rare": i % 20 == 0})        # ~5% pass
        else:
            i = int(str(r["short_txt"])[1:])
            out.append({"common": i % 10 != 1})      # ~90% pass
    return out


SKEW_Q = ("SELECT rid FROM R WHERE "
          "LLM m (PROMPT 'is {rare BOOLEAN} in {{long_txt}}') = TRUE "
          "AND LLM m (PROMPT 'is {common BOOLEAN} in {{short_txt}}') = TRUE")


def skew_db(n=200, pilot=True, **options):
    db = IPDB()
    db.register_table("R", Table.from_rows(
        [{"rid": i, "short_txt": f"s{i}",
          "long_txt": "lorem ipsum dolor sit amet " * 10 + f"doc {i}"}
         for i in range(n)]))
    db.register_oracle("orc", skew_oracle)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("use_batching", False)
    db.set_option("enable_pilot", pilot)
    for k, v in options.items():
        db.set_option(k, v)
    return db


# ---------------------------------------------------------------------------
# statistics store feedback from execution
# ---------------------------------------------------------------------------
def test_store_records_selectivity_tokens_latency():
    db = skew_db(n=30, pilot=False)          # too small for pilots
    db.sql(SKEW_Q)
    keys = list(db.stats_store.keys())
    assert len(keys) == 2
    common = db.stats_store.get(next(k for k in keys if "common" in k[1]))
    rare = db.stats_store.get(next(k for k in keys if "rare" in k[1]))
    # cold store → static size heuristic runs the short predicate first:
    # it sees all 30 rows, 27 pass (i % 10 != 1)
    assert common.rows_in == 30
    assert common.rows_passed == 27
    assert common.selectivity == pytest.approx(27 / 30)
    assert common.calls == 30                # batching off → per-row calls
    # the long predicate sees the 27 survivors; i=0 and i=20 pass
    assert rare.rows_in == 27
    assert rare.rows_passed == 2
    assert rare.calls == 27
    assert rare.mean_in_tokens > 0
    assert rare.mean_latency_s > 0
    assert rare.pilot_calls == 0


def test_store_records_semantic_join_selectivity():
    db = IPDB()
    db.register_table("A", Table.from_rows(
        [{"a_txt": f"a{i}"} for i in range(6)]))
    db.register_table("B", Table.from_rows(
        [{"b_txt": f"b{i}"} for i in range(5)]))
    db.register_oracle("orc", lambda ins, rows: [
        {"match": str(r.get("a_txt", ""))[1:] == str(r.get("b_txt", ""))[1:]}
        for r in rows])
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    r = db.sql("SELECT a_txt FROM A JOIN B ON "
               "LLM m (PROMPT 'is {{a_txt}} {match BOOLEAN} vs {{b_txt}}')")
    assert len(r.table) == 5                 # diagonal matches
    (key,) = list(db.stats_store.keys())
    rec = db.stats_store.get(key)
    assert rec.rows_in == 30                 # full cross product observed
    assert rec.rows_passed == 5
    assert rec.selectivity == pytest.approx(5 / 30)


def test_store_records_retries():
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"txt": f"t{i}"} for i in range(6)]))
    db.register_oracle("orc", lambda ins, rows: [{"v": "x"} for r in rows],
                       malform_rate=1.0)
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    db.set_option("use_batching", False)
    db.sql("SELECT LLM m (PROMPT 'get {v VARCHAR} of {{txt}}') AS v FROM T")
    (key,) = list(db.stats_store.keys())
    rec = db.stats_store.get(key)
    assert rec.retries > 0
    assert rec.retry_rate > 0


# ---------------------------------------------------------------------------
# pilot sampling
# ---------------------------------------------------------------------------
def test_pilot_calibrates_reorders_and_reduces_calls():
    r_static = skew_db(pilot=False).sql(SKEW_Q)
    db = skew_db(pilot=True)
    r_adapt = db.sql(SKEW_Q)
    # results bit-identical
    assert sorted(r_static.table.column("rid")) == \
        sorted(r_adapt.table.column("rid"))
    # 2 predicates × 16-row reservoir, batching off → 32 pilot calls
    assert r_adapt.stats.pilot_calls == 32
    assert r_static.stats.pilot_calls == 0
    # pilot included, the adaptive plan still makes strictly fewer calls
    # and has strictly lower modeled makespan
    assert (r_adapt.stats.llm_calls + r_adapt.stats.pilot_calls
            < r_static.stats.llm_calls)
    assert r_adapt.stats.sim_latency_s < r_static.stats.sim_latency_s
    # the store marks the pilot's share of the observations
    rare_key = next(k for k in db.stats_store.keys() if "rare" in k[1])
    assert db.stats_store.get(rare_key).pilot_calls == 16


def test_pilot_answers_land_in_prompt_cache():
    db = skew_db(pilot=True)
    r = db.sql(SKEW_Q)
    # the execution re-uses the 16 piloted rows of the predicate that runs
    # first instead of re-dispatching them
    assert r.stats.prompt_cache_hits >= 16


def test_pilot_skipped_when_table_cannot_amortize():
    db = skew_db(n=40, pilot=True)           # 40 ≤ pilot_min_rows (64)
    r = db.sql(SKEW_Q)
    assert r.stats.pilot_calls == 0


def test_pilot_not_repeated_once_history_exists():
    db = skew_db(pilot=True)
    r1 = db.sql(SKEW_Q)
    assert r1.stats.pilot_calls == 32
    r2 = db.sql(SKEW_Q)
    assert r2.stats.pilot_calls == 0         # store has history now
    assert r2.stats.llm_calls == 0           # prompt cache has every answer
    assert sorted(r1.table.column("rid")) == sorted(r2.table.column("rid"))


def test_select_vs_join_placement_cost_based_with_batching():
    """The select-vs-join decision goes through the cost model even with
    marshaling on (calls quantized by batch_size).  Distinct inputs above
    the join are never more numerous than on their source side, so the
    above-join placement (dedup pays only distinct inputs) must be kept,
    with correct results and one marshaled call over the 5 distinct
    descs."""
    pk = [{"pid": i, "desc": f"desc{i}"} for i in range(5)]
    fk = [{"fid": i, "pid": i % 5, "txt": f"t{i}"} for i in range(12)]
    db = IPDB()
    db.register_table("P", Table.from_rows(pk))
    db.register_table("F", Table.from_rows(fk))
    db.register_oracle("orc", lambda ins, rows: [
        {"flag": str(r.get("desc", "")).endswith(("1", "2"))} for r in rows])
    db.sql("CREATE LLM MODEL m PATH 'oracle:orc' ON PROMPT")
    r = db.sql("SELECT txt FROM P JOIN F ON pid = pid WHERE "
               "LLM m (PROMPT 'check {flag BOOLEAN} of {{desc}}') = TRUE")
    assert r.stats.llm_calls == 1            # one batch, 5 distinct descs
    assert r.stats.prompt_cache_misses == 5
    assert sorted(r.table.column("txt")) == \
        sorted(f"t{i}" for i in range(12) if i % 5 in (1, 2))


# ---------------------------------------------------------------------------
# regression: _filter_used must not depend on enable_merge
# ---------------------------------------------------------------------------
def test_filter_used_computed_with_merge_disabled():
    db = skew_db(n=10, pilot=False)
    stmt = parse_sql(SKEW_Q)
    plan = Binder(db.catalog, db.options).bind_select(stmt)
    opt = Optimizer(db.catalog, {"enable_merge": False})
    opt.optimize(plan)
    # before the fix this stayed empty unless enable_merge was on
    assert opt._filter_used
    opt2 = Optimizer(db.catalog, {"enable_merge": True})
    opt2.optimize(Binder(db.catalog, db.options).bind_select(stmt))
    # same columns modulo the generated fresh-column counters
    import re
    norm = lambda s: {re.sub(r"\d+", "#", c) for c in s}
    assert norm(opt._filter_used) == norm(opt2._filter_used)


# ---------------------------------------------------------------------------
# cost model + ordering properties
# ---------------------------------------------------------------------------
def test_cost_model_cold_store_falls_back_to_hints():
    from repro.relational.plan import PredictInfo
    cm = CostModel(StatisticsStore(), {"use_batching": False})
    info = PredictInfo(model_name="m", prompt=None, inputs=["x"],
                       outputs=[("v", "VARCHAR")],
                       options={"selectivity_hint": 0.2})
    sel, src = cm.selectivity(info)
    assert (sel, src) == (0.2, "hint")
    est = cm.estimate(info, 100, fallback_in_tokens=80.0)
    assert est.expected_calls == 100
    assert est.makespan_s > 0
    info2 = PredictInfo(model_name="m", prompt=None, inputs=["x"],
                        outputs=[("v", "VARCHAR")])
    assert cm.selectivity(info2) == (0.5, "default")


def test_cost_model_prefers_observations():
    from repro.relational.plan import PredictInfo
    store = StatisticsStore()
    info = PredictInfo(model_name="m", prompt=None, inputs=["x"],
                       outputs=[("v", "VARCHAR")],
                       options={"selectivity_hint": 0.9})
    store.record_predicate(stats_key(info), 100, 10)
    store.record_call(stats_key(info), 120, 6, 3.0)
    cm = CostModel(store, {"use_batching": False, "n_threads": 1})
    sel, src = cm.selectivity(info)
    assert (sel, src) == (0.1, "observed")
    est = cm.estimate(info, 10)
    assert est.per_call_s == pytest.approx(3.0)
    assert est.makespan_s == pytest.approx(30.0)   # 10 calls × 3 s, 1 worker


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.floats(0.01, 50.0), st.floats(0.0, 0.99)),
                min_size=2, max_size=5),
       st.integers(1, 1000))
def test_rank_order_never_increases_expected_cost(units, n_rows):
    """cost/(1-sel)-ascending ordering of commuting semantic selects is
    optimal: its expected stack cost is the minimum over ALL permutations
    (hence never worse than the submitted order)."""
    ranked = sorted(units, key=lambda u: order_rank(u[0], u[1]))
    best = min(expected_stack_cost(n_rows, list(p))
               for p in itertools.permutations(units))
    assert expected_stack_cost(n_rows, ranked) <= best * (1 + 1e-9)
    assert expected_stack_cost(n_rows, ranked) <= \
        expected_stack_cost(n_rows, units) * (1 + 1e-9)


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(0.0, 0.99), min_size=2, max_size=5),
       st.integers(1, 1000))
def test_rank_order_never_increases_expected_calls(sels, n_rows):
    """At uniform per-call cost the rank reduces to ascending selectivity,
    which minimizes the expected number of predicate calls."""
    units = [(1.0, s) for s in sels]
    ranked = sorted(units, key=lambda u: order_rank(u[0], u[1]))
    assert expected_stack_cost(n_rows, ranked) <= \
        expected_stack_cost(n_rows, units) * (1 + 1e-9)


def test_reordering_keeps_results_bit_identical():
    """Stats-driven ordering is pure mechanism: rows AND row order of the
    final result match the unoptimized plan."""
    base = skew_db(pilot=False,
                   enable_select_order=False).sql(SKEW_Q)
    for pilot in (False, True):
        r = skew_db(pilot=pilot).sql(SKEW_Q)
        assert r.table.rows() == base.table.rows()


# ---------------------------------------------------------------------------
# EXPLAIN -- stats -- section
# ---------------------------------------------------------------------------
def test_explain_shows_estimated_vs_observed():
    db = skew_db(n=30, pilot=False)
    txt0 = db.explain(SKEW_Q)
    assert "-- stats --" in txt0
    assert "(default)" in txt0 or "(hint)" in txt0
    assert "obs: none" in txt0
    db.sql(SKEW_Q)
    txt = db.explain(SKEW_Q)
    assert "(observed)" in txt
    assert "obs: sel=" in txt
    assert "pilot_calls=" in txt
    # explain never dispatches inference (no pilots, no calls)
    assert db.last_stats.pilot_calls == 0


def test_sql_explain_kwarg_includes_stats_section():
    db = skew_db(n=30, pilot=False)
    r = db.sql(SKEW_Q, explain=True)
    assert "-- stats --" in r.plan


# ---------------------------------------------------------------------------
# acceptance: the adaptive benchmark's win conditions hold in quick mode
# ---------------------------------------------------------------------------
def test_bench_adaptive_quick():
    from benchmarks.bench_adaptive import run as bench_run
    rows = bench_run(quick=True)
    names = [r[0] for r in rows]
    assert names == ["adaptive.static", "adaptive.adaptive",
                     "adaptive.adaptive_warm"]
