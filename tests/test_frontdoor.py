"""Front-door serving tier: streaming HTTP sessions, admission control,
end-to-end cancellation, DRR fairness, and the multi-session determinism
contract (PR 8).

Determinism extends PR 4's harness (tests/helpers.py): N concurrent
streaming sessions over scripted backends must produce rows and
ExecStats byte-identical to running the same queries serially, for every
dispatch_workers setting — sessions are tagged into their own service
queues, so no interleaving can change batch composition or accounting.
Cancellation tests force worst-case orderings with gate hooks (cancel
while a flush is mid-executor-call) and assert the "within one flush"
contract: the running batch completes, nothing new dispatches, queued
requests are dropped and handles released.
"""
import threading
import time

import pytest

from helpers import (LatencyScriptedPredictor, drain_stream,
                     register_scripted, run_sessions, stream_stats_dict)

from repro.core.cancel import QueryCancelled
from repro.core.database import IPDB
from repro.frontdoor import (DeficitRoundRobin, FifoGate, FrontDoor,
                             FrontDoorClient, QueryRejected)
from repro.relational.table import Table


def scripted_answers(instruction, rows):
    out = []
    for r in rows:
        joined = " ".join(f"{k}={v}" for k, v in sorted(r.items()))
        h = sum(map(ord, joined)) + sum(map(ord, instruction))
        out.append({"tag": f"t{h % 5}", "flag": h % 3 == 0,
                    "score": h % 7})
    return out


def make_db(*, n=24, chunk=4, workers=1, predictor=None, pilot=False):
    db = IPDB()
    db.register_table("T", Table.from_rows(
        [{"a": i, "txt": f"row {i}"} for i in range(n)]))
    pred = predictor if predictor is not None else \
        LatencyScriptedPredictor(scripted_answers, base_latency_s=0.25)
    register_scripted(db, "m", pred)
    db.set_option("chunk_size", chunk)
    db.set_option("batch_size", 4)
    db.set_option("dispatch_workers", workers)
    db.set_option("enable_pilot", pilot)
    return db, pred


def q(instr: str) -> str:
    return ("SELECT a, LLM m (PROMPT '" + instr +
            " {tag VARCHAR} of {{txt}}') AS t FROM T")


def wait_for(cond, timeout=5.0):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if cond():
            return True
        time.sleep(0.02)
    return cond()


# ---------------------------------------------------------------------------
# fairness gates (unit)
# ---------------------------------------------------------------------------
def test_fifo_gate_grants_in_arrival_order():
    gate = FifoGate(1)
    order = []
    gate.acquire("a")

    def worker(tag):
        gate.acquire(tag)
        order.append(tag)
        gate.release(tag, cost=1.0)

    threads = []
    for tag in ["x", "y", "z"]:
        t = threading.Thread(target=worker, args=(tag,))
        t.start()
        time.sleep(0.05)               # deterministic arrival order
        threads.append(t)
    gate.release("a", cost=1.0)
    for t in threads:
        t.join(timeout=5)
    assert order == ["x", "y", "z"]


def test_drr_light_tenant_overtakes_indebted_heavy_tenant():
    """Post-paid DRR: after the heavy tenant is charged a large cost, the
    light tenant's queued waiters win the next slots even though they
    arrived later."""
    gate = DeficitRoundRobin(1, quantum=2.0)
    order = []
    gate.acquire("heavy")

    def worker(tenant, label):
        assert gate.acquire(tenant)
        order.append(label)
        gate.release(tenant, cost=1.0)

    threads = []
    # heavy's backlog arrives first, light's afterwards
    for tenant, label in [("heavy", "h1"), ("heavy", "h2"),
                          ("light", "l1"), ("light", "l2")]:
        t = threading.Thread(target=worker, args=(tenant, label))
        t.start()
        time.sleep(0.05)
        threads.append(t)
    gate.release("heavy", cost=50.0)   # heavy just consumed a huge chunk
    for t in threads:
        t.join(timeout=5)
    # light drains completely before heavy's backlog continues
    assert order[:2] == ["l1", "l2"]
    assert sorted(order[2:]) == ["h1", "h2"]
    assert gate.grants["light"] == 2 and gate.grants["heavy"] == 3


def test_drr_weights_bias_replenishment():
    """With weight 3 vs 1 and everyone in debt, the heavier-weighted
    tenant replenishes past zero first and wins the slot."""
    gate = DeficitRoundRobin(1, quantum=1.0, weights={"gold": 3.0})
    gate.acquire("seed")               # hold the only slot
    got = []

    def worker(tenant):
        assert gate.acquire(tenant)
        got.append(tenant)
        gate.release(tenant, cost=0.0)

    threads = []
    for tenant in ["basic", "gold"]:
        t = threading.Thread(target=worker, args=(tenant,))
        t.start()
        time.sleep(0.05)
        threads.append(t)
    # both start at credit 0 -> replenish: basic +1, gold +3 -> gold wins
    gate.release("seed", cost=5.0)
    for t in threads:
        t.join(timeout=5)
    assert got[0] == "gold"


def test_gate_acquire_abort_event_returns_false():
    gate = DeficitRoundRobin(1)
    assert gate.acquire("a")
    abort = threading.Event()
    res = {}

    def worker():
        res["got"] = gate.acquire("a", abort=abort)

    t = threading.Thread(target=worker)
    t.start()
    time.sleep(0.05)
    abort.set()
    gate.kick()                        # what a CancelScope callback does
    t.join(timeout=5)
    assert res["got"] is False
    assert gate.waiting() == 0
    gate.release("a")


# ---------------------------------------------------------------------------
# streaming sessions over HTTP
# ---------------------------------------------------------------------------
def test_http_stream_rows_and_exec_stats_trailer():
    db, pred = make_db()
    with db, FrontDoor(db, max_sessions=2, max_queued=2) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        h = cli.query(q("one"), tenant="acme")
        assert h.session_id.startswith("fd")
        frames = list(h.frames())
        chunks = [f for f in frames if f["type"] == "chunk"]
        trailer = frames[-1]
        assert trailer["type"] == "trailer" and trailer["status"] == "ok"
        assert len(chunks) == 24 // 4          # one frame per 4-row chunk
        assert [c["seq"] for c in chunks] == list(range(len(chunks)))
        rows = [r for c in chunks for r in c["rows"]]
        assert [r["a"] for r in rows] == list(range(24))
        # the trailer carries the same ExecStats the Python API reports
        ref = db.sql(q("one"))                 # fully prompt-cached rerun
        assert set(trailer["stats"]) == (
            set(stream_stats_dict(ref.stats)) | {"wall_s"})
        assert trailer["stats"]["llm_calls"] == 24 // 4
        assert trailer["stats"]["cancelled"] is False
        assert trailer["rows"] == 24


def test_http_explain_trailer_carries_plan():
    db, _ = make_db()
    with db, FrontDoor(db) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        res = cli.query(q("exp"), explain=True).result()
        assert res["status"] == "ok"
        assert "-- physical --" in res["plan"]
        assert "-- dispatch --" in res["plan"]


def test_http_streams_incrementally_not_all_at_end():
    """Chunk frames must arrive while later chunks are still being
    produced: hold the backend after the first dispatch and check the
    first frame is already readable."""
    release = threading.Event()
    seen = []

    def gate(pred, prompts):
        seen.append(len(prompts))
        if len(seen) > 1:              # first batch passes, rest wait
            assert release.wait(timeout=10)

    pred = LatencyScriptedPredictor(scripted_answers, gate=gate)
    db, _ = make_db(predictor=pred)
    with db, FrontDoor(db) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        h = cli.query(q("inc"))
        frames = h.frames()
        first = next(frames)
        assert first["type"] == "chunk" and len(first["rows"]) == 4
        release.set()
        rest = list(frames)
        assert rest[-1]["status"] == "ok"
        assert sum(len(f["rows"]) for f in rest
                   if f["type"] == "chunk") == 20


def test_admission_control_rejects_with_429():
    """max_sessions=1, max_queued=0: while one session is pinned inside
    the backend, a second POST /query is rejected up front."""
    release = threading.Event()

    def gate(pred, prompts):
        assert release.wait(timeout=10)

    pred = LatencyScriptedPredictor(scripted_answers, gate=gate)
    db, _ = make_db(predictor=pred)
    with db, FrontDoor(db, max_sessions=1, max_queued=0) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        h1 = cli.query(q("adm"))
        deadline = time.time() + 5
        while fd._active < 1 and time.time() < deadline:
            time.sleep(0.01)
        with pytest.raises(QueryRejected) as ei:
            cli.query(q("adm2"))
        assert ei.value.status == 429
        release.set()
        assert h1.result()["status"] == "ok"
        assert wait_for(lambda: cli.server_stats().get("completed") == 1)
        assert cli.server_stats()["rejected"] == 1


def test_delete_cancels_within_one_flush():
    """DELETE /query/<id> while the session is mid-flush: the running
    batch completes, no further batch dispatches for that session, its
    queued handles are released, and the trailer reports cancelled."""
    entered = threading.Event()
    release = threading.Event()

    def gate(pred, prompts):
        entered.set()
        assert release.wait(timeout=10)

    pred = LatencyScriptedPredictor(scripted_answers, gate=gate)
    db, _ = make_db(predictor=pred)
    with db, FrontDoor(db) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        h = cli.query(q("del"))
        assert entered.wait(timeout=10)        # first flush is running
        dispatched_before = len(pred.dispatch_log) + 1  # the one in-flight
        assert cli.cancel(h.session_id)
        release.set()                          # let the running batch end
        res = h.result()
        assert res["status"] == "cancelled"
        assert res["stats"]["cancelled"] is True
        # within one flush: the in-flight batch was the LAST dispatch
        time.sleep(0.1)
        assert len(pred.dispatch_log) == dispatched_before
        assert db.inference_service.session_pending(h.session_id) == 0
        assert wait_for(
            lambda: cli.server_stats().get("cancelled_sessions") == 1)


def test_client_disconnect_cancels_session():
    """Dropping the socket mid-stream must cancel the session exactly
    like an explicit DELETE: dispatch stops within one flush."""
    entered = threading.Event()
    release = threading.Event()

    def gate(pred, prompts):
        entered.set()
        assert release.wait(timeout=10)

    pred = LatencyScriptedPredictor(scripted_answers, gate=gate)
    db, _ = make_db(predictor=pred)
    with db, FrontDoor(db) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        h = cli.query(q("dis"))
        assert entered.wait(timeout=10)
        dispatched_cap = len(pred.dispatch_log) + 1
        h.abort()                              # EOF on the server side
        # wait until the server noticed and fired the scope, THEN let the
        # in-flight batch finish — worst-case ordering on purpose
        assert wait_for(lambda: fd._sessions.get(h.session_id) is None
                        or fd._sessions[h.session_id].scope.cancelled)
        release.set()
        assert wait_for(lambda: fd._active == 0 and not fd._sessions)
        assert len(pred.dispatch_log) <= dispatched_cap
        assert wait_for(
            lambda: cli.server_stats().get("cancelled_sessions") == 1)


# ---------------------------------------------------------------------------
# multi-session determinism (PR 4 harness, extended)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("workers", [1, 2, 4])
def test_multi_session_rows_and_stats_match_serial(workers):
    """N concurrent sessions x dispatch_workers: rows and ExecStats are
    byte-identical to running the same queries serially on a fresh
    database.  Distinct per-session instructions keep prompt-cache keys
    disjoint, so the contract covers scheduling, not cache luck."""
    queries = [("acme", q("alpha")), ("acme", q("beta")),
               ("zeta", q("gamma")), ("", q("delta"))]

    def fresh():
        return make_db(n=24, chunk=4, workers=workers)[0]

    db_serial = fresh()
    with db_serial:
        expect = run_sessions(db_serial, queries, concurrent=False)
    for round_no in range(3):           # several interleavings
        db_conc = fresh()
        barrier = threading.Barrier(len(queries))
        with db_conc:
            got = run_sessions(db_conc, queries, concurrent=True,
                               start_barrier=barrier)
        assert got == expect, f"divergence on round {round_no}"


@pytest.mark.parametrize("workers", [1, 2])
def test_cancel_mid_flush_is_deterministic_and_bounded(workers):
    """Barrier-forced worst case: session B cancels while its flush is
    inside the executor.  The surviving session's rows/stats are
    untouched, B stops within one flush, and B's handles are released."""
    entered = threading.Event()
    release = threading.Event()

    def gate(pred, prompts):
        # only session B's prompts gate (distinct instruction text)
        if any("victim" in p for p in prompts):
            entered.set()
            assert release.wait(timeout=10)

    pred = LatencyScriptedPredictor(scripted_answers, gate=gate)
    db, _ = make_db(n=24, chunk=4, workers=workers, predictor=pred)
    with db:
        survivor_rows, survivor_stats = drain_stream(
            db.stream(q("bystander")))
        stream_b = db.stream(q("victim"), tenant="b")
        outcome = {}

        def run_b():
            try:
                outcome["res"] = drain_stream(stream_b)
            except QueryCancelled as e:
                outcome["err"] = e

        t = threading.Thread(target=run_b)
        t.start()
        assert entered.wait(timeout=10)       # B is mid-executor-call
        stream_b.cancel("test")
        release.set()
        t.join(timeout=10)
        assert not t.is_alive()
        rows_b, stats_b = outcome["res"]
        assert stats_b.cancelled is True
        # within one flush: every dispatched batch for B happened before
        # the cancel was observed; nothing dispatched afterwards
        dispatched_after = len(pred.dispatch_log)
        time.sleep(0.1)
        db.inference_service.flush()
        assert len(pred.dispatch_log) == dispatched_after
        assert db.inference_service.session_pending(stream_b.session) == 0
        # the bystander session, re-run on a fresh identical db, is
        # byte-identical — the cancelled neighbor never leaked into it
        db2, _ = make_db(n=24, chunk=4, workers=workers)
        with db2:
            rows2, stats2 = drain_stream(db2.stream(q("bystander")))
        assert rows2 == survivor_rows
        assert stream_stats_dict(stats2) == stream_stats_dict(
            survivor_stats)


def test_stream_rejects_non_select():
    db, _ = make_db()
    with db:
        with pytest.raises(ValueError):
            db.stream("SET chunk_size = 8")


# ---------------------------------------------------------------------------
# resilience (PR 10): breaker shed, HTTP deadlines, cancel racing chaos
# ---------------------------------------------------------------------------
def test_breaker_open_sheds_with_503_and_retry_after():
    """While any backend breaker is open, POST /query is shed with 503 +
    Retry-After BEFORE admission; recovery reopens the front door."""
    db, _ = make_db()
    with db, FrontDoor(db, retry_after_s=2) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        b = db.inference_service.breaker_for("m")
        for _ in range(3):
            b.record_failure()           # trip the breaker by hand
        with pytest.raises(QueryRejected) as ei:
            cli.query(q("shed"))
        assert ei.value.status == 503
        assert wait_for(
            lambda: cli.server_stats().get("rejected_breaker") == 1)
        b.record_success()               # backend recovered
        res = cli.query(q("shed")).result()
        assert res["status"] == "ok" and res["rows"] == 24


def test_http_deadline_ms_degrades_to_nulls_not_errors():
    """A 1ms deadline_ms in the POST body flows through the session into
    the operators: the query still completes (status ok) with dropped
    work degraded to NULLs and the drops visible in the trailer stats."""
    pred = LatencyScriptedPredictor(scripted_answers, sleep_per_call_s=0.05)
    db, _ = make_db(predictor=pred)
    with db, FrontDoor(db) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        res = cli.query(q("dlh"), deadline_ms=1).result()
        assert res["status"] == "ok"
        assert res["rows"] == 24
        assert res["stats"]["deadline_drops"] > 0
        assert len(pred.dispatch_log) <= 1


def test_cancel_races_injected_faults_without_leaks():
    """DELETE /query while the backend is mid-chaos (seeded transient
    faults + per-call wall time): the session terminates cleanly, its
    queued handles are released within one flush, and the database keeps
    serving afterwards."""
    from repro.core.faults import FaultInjector
    inj = FaultInjector(
        LatencyScriptedPredictor(scripted_answers, base_latency_s=0.25,
                                 sleep_per_call_s=0.02),
        seed=5, transient_rate=0.4)
    db, _ = make_db(predictor=inj, workers=2)
    with db, FrontDoor(db) as fd:
        cli = FrontDoorClient(fd.host, fd.port)
        h = cli.query(q("race"))
        # cancel only after chaos has started (faults possibly in flight)
        assert wait_for(lambda: inj.counters["calls"] > 0)
        cli.cancel(h.session_id)
        res = h.result()
        assert res["status"] in ("cancelled", "ok")
        assert wait_for(lambda: db.inference_service.session_pending(
            h.session_id) == 0)
        assert wait_for(lambda: fd._active == 0 and not fd._sessions)
        # the race leaked nothing: a follow-up query serves every row
        after = db.sql(q("after"))
        assert len(after.table.rows()) == 24
        assert all(r["t"] is not None for r in after.table.rows())


def test_periodic_snapshots_persist_warm_state(tmp_path):
    """FrontDoor(snapshot_every_s=...) snapshots the db's warm state in
    the background and once more at stop(); a restarted db+front door
    serves the same query without consulting the backend."""
    snapdir = str(tmp_path)

    def fresh():
        db = IPDB(snapshot_dir=snapdir)
        db.register_table("T", Table.from_rows(
            [{"a": i, "txt": f"row {i}"} for i in range(24)]))
        pred = LatencyScriptedPredictor(scripted_answers,
                                        base_latency_s=0.25)
        register_scripted(db, "m", pred)
        db.set_option("chunk_size", 4)
        db.set_option("batch_size", 4)
        db.set_option("enable_pilot", False)
        return db, pred

    db1, pred1 = fresh()
    with db1, FrontDoor(db1, snapshot_every_s=0.1) as fd1:
        cli = FrontDoorClient(fd1.host, fd1.port)
        assert cli.query(q("persist")).result()["status"] == "ok"
        assert len(pred1.dispatch_log) > 0
        assert wait_for(
            lambda: cli.server_stats().get("snapshots", 0) >= 1)

    db2, pred2 = fresh()
    assert db2.restored_snapshot is not None
    with db2, FrontDoor(db2) as fd2:
        cli2 = FrontDoorClient(fd2.host, fd2.port)
        res = cli2.query(q("persist")).result()
        assert res["status"] == "ok" and res["rows"] == 24
    assert len(pred2.dispatch_log) == 0, \
        "warm-restored front door must serve from the snapshot"
