"""End-to-end behaviour tests: the paper's Table 1 example queries (Q1–Q6)
through the full parse→bind→optimize→execute pipeline, plus the real-JAX
executor path."""
import json

import numpy as np
import pytest

from repro.core.database import IPDB
from repro.relational.table import Table

MOVIES = [
    {"mid": 1, "title": "Titanic", "plot": "ship sinks romance tragedy",
     "lang": "English"},
    {"mid": 2, "title": "Alien", "plot": "violence horror in space",
     "lang": "English"},
    {"mid": 3, "title": "Amelie", "plot": "whimsical paris romance",
     "lang": "French"},
]
REVIEWS = [
    {"rid": 1, "mid": 1, "review": "loved it, fantastic"},
    {"rid": 2, "mid": 1, "review": "terrible and boring"},
    {"rid": 3, "mid": 2, "review": "scary, bad sleep"},
]
CAST = [
    {"mid": 1, "cname": "James Cameron", "role": "Director"},
    {"mid": 2, "cname": "Ridley Scott", "role": "Director"},
]


def _g(row, name, default=""):
    if name in row:
        return row[name]
    for k, v in row.items():
        if k.endswith("__" + name):
            return v
    return default


def movie_oracle(instruction, rows):
    out = []
    for r in rows:
        o = {}
        plot = str(_g(r, "plot"))
        o["genre"] = ("horror" if "horror" in plot else
                      "romance" if "romance" in plot else "drama")
        o["main_character"] = "protagonist"
        o["language"] = str(_g(r, "lang", "English"))
        o["negative"] = any(w in str(_g(r, "review"))
                            for w in ("terrible", "boring", "bad"))
        o["match"] = ("violence" in plot) == \
            ("violence" in str(_g(r, "description")))
        o["style"] = "sweeping epic"
        o["maturity_label"] = "R" if "violence" in plot else "PG"
        o["description"] = "desc"
        out.append(o)
    if "maturity" in instruction and not rows:
        return [{"maturity_label": l, "description": f"d{l}"}
                for l in ("G", "PG", "PG-13", "R")]
    return out


@pytest.fixture()
def db():
    d = IPDB()
    d.register_table("Movie", Table.from_rows(MOVIES))
    d.register_table("Review", Table.from_rows(REVIEWS))
    d.register_table("CastT", Table.from_rows(CAST))
    d.register_oracle("movies", movie_oracle)
    d.sql("CREATE LLM MODEL o4mini PATH 'oracle:movies' ON PROMPT "
          "API 'https://api.openai.com/v1/'")
    return d


def test_q1_table_inference_projection(db):
    r = db.sql("SELECT title, genre, main_character FROM LLM o4mini (PROMPT "
               "'extract the {genre VARCHAR} and {main_character VARCHAR} "
               "from the {{plot}}', Movie)")
    assert r.table.column_names == ["title", "genre", "main_character"]
    byt = {x["title"]: x["genre"] for x in r.table.rows()}
    assert byt == {"Titanic": "romance", "Alien": "horror",
                   "Amelie": "romance"}


def test_q2_scalar_projection(db):
    r = db.sql("SELECT title, LLM o4mini (PROMPT 'what is the "
               "{language VARCHAR} of the movie {{title}}') AS language "
               "FROM Movie")
    assert len(r.table) == 3
    assert "language" in r.table.column_names


def test_q3_table_generation(db):
    r = db.sql("CREATE TABLE MaturityRating AS SELECT maturity_label, "
               "description FROM LLM o4mini (PROMPT 'Get all the maturity "
               "{maturity_label VARCHAR} and {description VARCHAR} in US')")
    assert len(r.table) == 4
    assert db.catalog.has_table("MaturityRating")


def test_q4_semantic_select_with_join(db):
    r = db.sql("SELECT review FROM Movie AS m NATURAL JOIN Review AS r WHERE "
               "LLM o4mini (PROMPT 'is the sentiment of the {{review}} "
               "{negative BOOLEAN}?') = TRUE AND title = 'Titanic'")
    assert [x["review"] for x in r.table.rows()] == ["terrible and boring"]


def test_q5_semantic_join(db):
    db.sql("CREATE TABLE MR AS SELECT maturity_label, description FROM "
           "LLM o4mini (PROMPT 'Get all the maturity {maturity_label VARCHAR} "
           "and {description VARCHAR} in US')")
    r = db.sql("SELECT title, maturity_label FROM Movie AS m JOIN MR AS mr "
               "ON LLM o4mini (PROMPT 'is maturity rating "
               "{{mr.description}} depicted in the {{m.plot}}')")
    assert len(r.table) >= 1


def test_q6_semantic_aggregate(db):
    r = db.sql("SELECT cname, LLM AGG o4mini (PROMPT 'Summarize the "
               "cinematography {style VARCHAR} by the {{plot}}s') AS style "
               "FROM CastT AS c NATURAL JOIN Movie AS m "
               "WHERE role = 'Director' GROUP BY cname")
    assert len(r.table) == 2
    assert all(x["style"] == "sweeping epic" for x in r.table.rows())


def test_real_jax_executor_end_to_end():
    """PREDICT through a real (random-weight) JAX model: structure is
    guaranteed by the grammar even though answers are noise."""
    d = IPDB()
    d.register_table("Items", Table.from_rows(
        [{"name": f"item{i}"} for i in range(3)]))
    d.sql("CREATE LLM MODEL tiny PATH 'jax:olmo-1b' ON PROMPT "
          "OPTIONS { 'batch_size': 2, 'max_str': 6 }")
    r = d.sql("SELECT name, LLM tiny (PROMPT 'guess the {color VARCHAR} "
              "of {{name}}') AS color FROM Items")
    assert len(r.table) == 3
    assert all(isinstance(c, str) for c in r.table.column("color"))
    assert r.stats.llm_calls == 2          # ceil(3 unique / batch 2)


def test_tabular_model_full_path():
    """CREATE TABULAR MODEL → TabularExecutor (hubert-style classifier)."""
    d = IPDB()
    d.register_table("Clips", Table.from_rows(
        [{"cid": i, "loudness": float(i)} for i in range(4)]))
    d.register_tabular("cls", lambda rows: [
        {"category_id": int(r["loudness"] > 1.5)} for r in rows])
    d.sql("CREATE TABULAR MODEL categorizer PATH 'tabular:cls' "
          "ON TABLE Clips FEATURES (loudness) OUTPUT (category_id INTEGER)")
    # table-bound model: PREDICT relation in FROM (paper Listing 4 usage)
    r2 = d.sql("SELECT category_id FROM PREDICT categorizer (Clips)")
    assert list(r2.table.column("category_id")) == [0, 0, 1, 1]
