import os
import sys

# NOTE: never set xla_force_host_platform_device_count here — smoke tests
# and benches must see the single real CPU device (dryrun.py owns the flag).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
