"""Relational engine: parser, expressions, joins, group-by + hypothesis
property tests of operator semantics."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.database import IPDB
from repro.relational.expr import BinOp, Col, Lit
from repro.relational.parser import parse_sql, SelectStmt, CreateModel
from repro.relational.table import Table


def db_with(tables):
    db = IPDB()
    for k, v in tables.items():
        db.register_table(k, v)
    return db


def test_parser_basic():
    s = parse_sql("SELECT a, b AS bb FROM t WHERE a > 3 AND b = 'x' "
                  "ORDER BY a DESC LIMIT 5")
    assert isinstance(s, SelectStmt)
    assert len(s.select) == 2 and s.select[1][0] == "bb"
    assert s.limit == 5 and not s.order_by[0][1]


def test_parser_create_llm_model():
    s = parse_sql("CREATE LLM MODEL o4mini PATH 'o4-mini' ON PROMPT "
                  "API 'https://api.openai.com/v1/' "
                  "OPTIONS { 'n_threads': 1, 'batch_size': 16, "
                  "'temperature': 0.5 }")
    assert isinstance(s, CreateModel)
    assert s.path == "o4-mini" and s.api and s.on_prompt
    assert s.options == {"n_threads": 1, "batch_size": 16, "temperature": 0.5}


def test_parser_llm_clauses():
    s = parse_sql("SELECT state FROM LLM m (PROMPT 'find {state VARCHAR} "
                  "from {{addr}}', Orders) WHERE country = 'USA'")
    assert s.from_rel.kind == "llm" and s.from_rel.source.name == "Orders"
    s2 = parse_sql("SELECT a FROM t WHERE LLM m (PROMPT 'is {x BOOLEAN}?')")
    from repro.relational.expr import find_predicts
    assert find_predicts(s2.where)


def test_prompt_placeholders():
    from repro.relational.expr import PromptTemplate
    pt = PromptTemplate.parse(
        "extract the {genre VARCHAR} and {year INT} from {{plot}} and {{t.title}}")
    assert pt.inputs == ["plot", "t.title"]
    assert pt.outputs == [("genre", "VARCHAR"), ("year", "INTEGER")]


def test_sql_end_to_end_relational_only():
    t = Table.from_rows([{"a": i, "b": f"s{i % 3}", "c": float(i)}
                         for i in range(10)])
    db = db_with({"t": t})
    r = db.sql("SELECT b, count(*) AS n, avg(c) AS m FROM t "
               "WHERE a >= 2 GROUP BY b ORDER BY b")
    rows = r.table.rows()
    assert [x["b"] for x in rows] == ["s0", "s1", "s2"]
    assert sum(x["n"] for x in rows) == 8


def test_join_matches_nested_loop():
    l = Table.from_rows([{"k": i % 4, "lv": i} for i in range(12)])
    r = Table.from_rows([{"k2": i % 3, "rv": i * 10} for i in range(7)])
    db = db_with({"l": l, "r": r})
    out = db.sql("SELECT lv, rv FROM l JOIN r ON k = k2").table
    expected = {(lv["lv"], rv["rv"]) for lv in l.rows() for rv in r.rows()
                if lv["k"] == rv["k2"]}
    got = {(x["lv"], x["rv"]) for x in out.rows()}
    assert got == expected


@settings(max_examples=30, deadline=None)
@given(vals=st.lists(st.integers(-50, 50), min_size=0, max_size=40),
       thr=st.integers(-50, 50))
def test_filter_property(vals, thr):
    t = Table({"x": np.array(vals, np.int64)})
    m = BinOp(">", Col("x"), Lit(thr)).evaluate(t)
    out = t.mask(m)
    assert list(out.column("x")) == [v for v in vals if v > thr]


@settings(max_examples=30, deadline=None)
@given(data=st.lists(st.tuples(st.integers(0, 5), st.integers(-9, 9)),
                     min_size=1, max_size=40))
def test_groupby_sum_property(data):
    t = Table.from_rows([{"g": g, "v": v} for g, v in data])
    db = db_with({"t": t})
    out = db.sql("SELECT g, sum(v) AS s FROM t GROUP BY g").table
    expected = {}
    for g, v in data:
        expected[g] = expected.get(g, 0) + v
    got = {int(r["g"]): r["s"] for r in out.rows()}
    assert {k: float(v) for k, v in expected.items()} == got


@settings(max_examples=20, deadline=None)
@given(vals=st.lists(st.integers(-100, 100), min_size=0, max_size=30))
def test_orderby_property(vals):
    t = Table({"x": np.array(vals, np.int64)})
    db = db_with({"t": t})
    out = db.sql("SELECT x FROM t ORDER BY x").table
    assert list(out.column("x")) == sorted(vals)
    out2 = db.sql("SELECT x FROM t ORDER BY x DESC").table
    assert list(out2.column("x")) == sorted(vals, reverse=True)
