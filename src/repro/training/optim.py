"""AdamW with global-norm clipping and a linear-warmup/cosine schedule.

Self-contained (no optax dependency). Optimizer state lives in two fp32
trees sharded exactly like the parameters, so the FSDP layout extends to
the full train state.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    frac = (s - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    frac = jnp.clip(frac, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def init_opt_state(params: PyTree) -> Dict[str, PyTree]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params)}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 opt: Dict[str, PyTree], step: jax.Array
                 ) -> Tuple[PyTree, Dict[str, PyTree], Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        step_dir = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v):
        a, b, c = upd(p, g, m, v)
        new_p.append(a)
        new_m.append(b)
        new_v.append(c)
    return (treedef.unflatten(new_p),
            {"m": treedef.unflatten(new_m), "v": treedef.unflatten(new_v)},
            {"grad_norm": gnorm, "lr": lr})
