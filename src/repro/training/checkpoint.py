"""Sharded checkpointing with elastic restore.

Layout: <dir>/step_<N>/
    manifest.json            — tree structure, shapes, dtypes, step
    shard_<i>_of_<k>.npz     — flat leaves, each leaf split on axis 0 into
                               k host shards (k = number of writer hosts)

Properties needed at 1000+ nodes:
  * per-host shard files (no single-writer bottleneck); manifest written
    LAST and atomically (tmp+rename) → a crash mid-write never yields a
    readable-but-corrupt checkpoint (restore only trusts manifested steps)
  * elastic restore: the reader reassembles logical arrays from any k and
    re-device_puts with the CURRENT mesh's shardings — checkpoint layout is
    independent of mesh shape, so scaling 256→512 chips (or mesh reshapes)
    is a restore, not a migration
  * async save: serialization happens on a snapshot copy so the train loop
    continues (here: thread handed a host copy)
  * retention: keep_last N
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(p), v) for p, v in leaves], treedef


def save(ckpt_dir: str, step: int, state: PyTree, *, num_shards: int = 1,
         keep_last: int = 3) -> Path:
    """Synchronous sharded save. Returns the checkpoint path."""
    root = Path(ckpt_dir)
    root.mkdir(parents=True, exist_ok=True)
    final = root / f"step_{step:08d}"
    tmp = Path(tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=str(root)))
    leaves, _ = _flatten(state)

    manifest = {"step": step, "num_shards": num_shards, "leaves": []}
    shard_payloads: List[Dict[str, np.ndarray]] = [dict() for _ in range(num_shards)]
    for idx, (name, v) in enumerate(leaves):
        a = np.asarray(v)
        key = f"leaf_{idx}"
        manifest["leaves"].append({
            "name": name, "key": key, "shape": list(a.shape),
            "dtype": str(a.dtype),
            "sharded": bool(a.ndim > 0 and a.shape[0] % num_shards == 0
                            and num_shards > 1)})
        if manifest["leaves"][-1]["sharded"]:
            parts = np.split(a, num_shards, axis=0)
            for s, p in enumerate(parts):
                shard_payloads[s][key] = p
        else:
            shard_payloads[0][key] = a
    for s, payload in enumerate(shard_payloads):
        np.savez(tmp / f"shard_{s}_of_{num_shards}.npz", **payload)
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)

    # retention
    steps = sorted(p for p in root.glob("step_*") if (p / "manifest.json").exists())
    for old in steps[:-keep_last]:
        shutil.rmtree(old, ignore_errors=True)
    return final


def save_async(ckpt_dir: str, step: int, state: PyTree, **kw) -> threading.Thread:
    """Snapshot to host memory, then write on a background thread."""
    host_state = jax.tree.map(lambda x: np.asarray(x), state)
    th = threading.Thread(target=save, args=(ckpt_dir, step, host_state),
                          kwargs=kw, daemon=True)
    th.start()
    return th


def latest_step(ckpt_dir: str) -> Optional[int]:
    root = Path(ckpt_dir)
    if not root.exists():
        return None
    steps = []
    for p in root.glob("step_*"):
        if (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: PyTree, *,
            shardings: Optional[PyTree] = None) -> PyTree:
    """Restore into the structure of `like` (specs or arrays). If
    `shardings` given, leaves are device_put with them — this is the
    elastic path (any current mesh)."""
    path = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    k = manifest["num_shards"]
    shards = [np.load(path / f"shard_{s}_of_{k}.npz") for s in range(k)]

    by_name: Dict[str, np.ndarray] = {}
    for leaf in manifest["leaves"]:
        if leaf["sharded"]:
            a = np.concatenate([shards[s][leaf["key"]] for s in range(k)],
                               axis=0)
        else:
            a = shards[0][leaf["key"]]
        by_name[leaf["name"]] = a

    leaves, treedef = _flatten(like)
    out = []
    flat_sh = jax.tree.leaves(shardings) if shardings is not None else \
        [None] * len(leaves)
    for (name, spec), sh in zip(leaves, flat_sh):
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        a = by_name[name]
        want_shape = tuple(spec.shape)
        if tuple(a.shape) != want_shape:
            raise ValueError(f"shape mismatch for {name}: "
                             f"{a.shape} vs {want_shape}")
        a = a.astype(spec.dtype)
        out.append(jax.device_put(a, sh) if sh is not None else jnp.asarray(a))
    return treedef.unflatten(out)
