"""Deterministic synthetic LM data pipeline.

Sharded, stateless-resumable: batch i is a pure function of (seed, step,
host_shard), so restart-after-failure reproduces the exact token stream
with no data-state checkpointing (the production pattern for elastic
clusters — the loader re-shards by recomputing, never by migrating state).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ENCODER, VLM, ModelConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 1234
    batch: int = 8
    seq_len: int = 128
    num_hosts: int = 1
    host_id: int = 0


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def synthetic_batch(mcfg: ModelConfig, dcfg: DataConfig, step: int
                    ) -> Dict[str, np.ndarray]:
    """Markov-ish token stream (structured enough that a model can reduce
    loss quickly — used by the convergence smoke tests)."""
    rng = _rng_for(dcfg, step)
    b = dcfg.batch // dcfg.num_hosts
    s = dcfg.seq_len
    v = mcfg.vocab_size

    if mcfg.family == ENCODER:
        embeds = rng.normal(0, 1, (b, s, mcfg.d_model)).astype(np.float32)
        labels = rng.integers(0, v, (b, s), dtype=np.int64)
        return {"embeds": embeds.astype(np.float32),
                "positions": np.broadcast_to(np.arange(s, dtype=np.int32),
                                             (b, s)).copy(),
                "labels": labels.astype(np.int32),
                "mask": np.ones((b, s), np.float32)}

    # token LM: repeating n-gram motifs + noise
    motif_len = 8
    n_motifs = 32
    # motifs are global (host-independent, step-independent)
    motifs = np.random.default_rng(dcfg.seed).integers(1, v, (n_motifs, motif_len))
    seqs = np.zeros((b, s + 1), np.int64)
    for i in range(b):
        pos = 0
        while pos < s + 1:
            m = motifs[rng.integers(0, n_motifs)]
            k = min(motif_len, s + 1 - pos)
            seqs[i, pos:pos + k] = m[:k]
            pos += k
        noise = rng.uniform(size=s + 1) < 0.05
        seqs[i, noise] = rng.integers(1, v, noise.sum())

    out = {"tokens": seqs[:, :-1].astype(np.int32),
           "labels": seqs[:, 1:].astype(np.int32),
           "positions": np.broadcast_to(np.arange(s, dtype=np.int32),
                                        (b, s)).copy(),
           "mask": np.ones((b, s), np.float32)}
    if mcfg.family == VLM:
        p = mcfg.num_prefix_tokens
        text = s - p
        out = {"tokens": seqs[:, :text].astype(np.int32),
               "prefix_embeds": rng.normal(0, 1, (b, p, mcfg.d_model))
               .astype(np.float32),
               "positions": np.broadcast_to(np.arange(text, dtype=np.int32),
                                            (b, text)).copy(),
               "labels": np.concatenate(
                   [np.zeros((b, p), np.int32),
                    seqs[:, 1:text + 1].astype(np.int32)], axis=1),
               "mask": np.concatenate(
                   [np.zeros((b, p), np.float32),
                    np.ones((b, text), np.float32)], axis=1)}
    return out


def batches(mcfg: ModelConfig, dcfg: DataConfig, start_step: int = 0
            ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(mcfg, dcfg, step)
        step += 1
