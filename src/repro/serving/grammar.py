"""Grammar-forced generation (iPDB §5.2), TPU-adapted.

The paper constrains llama.cpp's sampler with a BNF grammar. Here the
grammar is a byte-level pushdown automaton compiled from the PREDICT
clause's output schema (column names + SQL types): the decoder must emit

    {"col1": <v1>, "col2": <v2>, ...}            (single row)
    [{...}, {...}, ...]                          (marshaled rows)

The automaton steps on the host (O(bytes), trivially cheap next to a
forward pass) and emits a per-step vocab mask; the mask is APPLIED on
device by the fused `constrained_logits` Pallas kernel. Every reachable
terminal state yields a string that json.loads() accepts and that casts to
the declared SQL types — the paper's schema-compliance guarantee becomes a
mechanical property (tests/test_grammar.py proves it by property testing
against a random-weight model).

Supported SQL types (paper Table 3): VARCHAR, INTEGER, DOUBLE, BOOLEAN,
DATETIME.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.tokenizer import EOS_ID, VOCAB_SIZE

DIGITS = frozenset(b"0123456789")
# characters allowed inside VARCHAR values (no quote/backslash/control)
STR_BYTES = frozenset(b for b in range(32, 127) if b not in (34, 92))


@dataclasses.dataclass(frozen=True)
class Field:
    name: str
    type: str  # VARCHAR | INTEGER | DOUBLE | BOOLEAN | DATETIME


def _lit(s: str) -> List[Tuple[str, object]]:
    return [("lit", b) for b in s.encode()]


def _value_prog(ftype: str, max_str: int) -> List[Tuple[str, object]]:
    t = ftype.upper()
    if t in ("VARCHAR", "TEXT", "STRING"):
        return [("lit", 34), ("str", max_str), ("lit", 34)]
    if t in ("INTEGER", "INT", "BIGINT"):
        return [("int", 12)]
    if t in ("DOUBLE", "FLOAT", "REAL"):
        return [("num", 16)]
    if t in ("BOOLEAN", "BOOL"):
        return [("bool", None)]
    if t in ("DATETIME", "DATE", "TIMESTAMP"):
        # "YYYY-MM-DD HH:MM:SS" — digit/sep template inside quotes
        prog: List[Tuple[str, object]] = [("lit", 34)]
        for ch in "dddd-dd-dd dd:dd:dd":
            prog.append(("digit", None) if ch == "d" else ("lit", ord(ch)))
        prog.append(("lit", 34))
        return prog
    raise ValueError(f"unsupported type {ftype}")


def compile_program(fields: Sequence[Field], num_rows: int = 1,
                    max_str: int = 48) -> List[Tuple[str, object]]:
    """Flatten the schema into a linear program of byte-class instructions.
    Variable-length instructions (str/int/num/bool) consume multiple steps
    with internal sub-state."""
    row: List[Tuple[str, object]] = [("lit", 123)]                 # '{'
    for i, f in enumerate(fields):
        if i:
            row += _lit(", ")
        row += _lit(f'"{f.name}": ')
        row += _value_prog(f.type, max_str)
    row.append(("lit", 125))                                       # '}'

    if num_rows == 1:
        return row + [("end", None)]
    prog: List[Tuple[str, object]] = [("lit", 91)]                 # '['
    for r in range(num_rows):
        if r:
            prog += _lit(", ")
        prog += row
    prog.append(("lit", 93))                                       # ']'
    return prog + [("end", None)]


@dataclasses.dataclass
class GrammarState:
    pc: int = 0          # program counter
    sub: int = 0         # chars consumed inside a variable-length instr
    aux: int = 0         # e.g. bool branch (0=undecided, 1=true, 2=false),
                         # num: bit0 seen digit, bit1 seen dot


class JsonGrammar:
    """Schema-driven constrained decoder. One instance per PREDICT schema
    (stateless); per-sequence state is a GrammarState."""

    def __init__(self, fields: Sequence[Field], num_rows: int = 1,
                 max_str: int = 48, vocab_size: int = VOCAB_SIZE):
        self.fields = list(fields)
        self.num_rows = num_rows
        self.max_str = max_str
        self.vocab = vocab_size
        self.prog = compile_program(self.fields, num_rows, max_str)

    def init_state(self) -> GrammarState:
        return GrammarState()

    def done(self, st: GrammarState) -> bool:
        return self.prog[st.pc][0] == "end" and st.sub > 0

    # -- allowed byte sets ----------------------------------------------------
    def _allowed(self, st: GrammarState) -> Tuple[frozenset, bool]:
        """Returns (allowed bytes, may_advance_to_next_instr). For
        variable-length instrs the 'next literal byte' is also allowed once
        the minimum length is satisfied — handled by advance()."""
        op, arg = self.prog[st.pc]
        if op == "lit":
            return frozenset((arg,)), False
        if op == "digit":
            return DIGITS, False
        if op == "str":
            allowed = set(STR_BYTES) if st.sub < arg else set()
            return frozenset(allowed), st.sub >= 1      # non-empty strings
        if op == "int":
            # aux bits: 1 = seen digit, 8 = leading zero (closes int part —
            # JSON forbids further digits after a leading 0)
            allowed = set() if (st.aux & 8) else set(DIGITS)
            if st.sub == 0:
                allowed.add(ord("-"))
            can_term = (st.aux & 1) == 1
            if st.sub >= arg and can_term:
                allowed = set()       # length cap (only once terminable)
            return frozenset(allowed), can_term
        if op == "num":
            # aux bits: 1 seen digit, 2 seen dot, 4 last-was-dot,
            # 8 leading zero in integer part
            allowed: set = set()
            if st.sub == 0:
                allowed.add(ord("-"))
            if st.aux & 2:
                allowed |= DIGITS                       # fraction digits
            elif st.aux & 8:
                allowed.add(ord("."))                   # only ".x" after 0
            else:
                allowed |= DIGITS
                if st.aux & 1:
                    allowed.add(ord("."))
            can_term = (st.aux & 1) == 1 and not (st.aux & 4)
            if st.sub >= arg and can_term:
                allowed = set()
            return frozenset(allowed), can_term
        if op == "bool":
            TRUE, FALSE = b"true", b"false"
            if st.aux == 0:
                return frozenset((TRUE[0], FALSE[0])), False
            word = TRUE if st.aux == 1 else FALSE
            if st.sub < len(word):
                return frozenset((word[st.sub],)), False
            return frozenset(), True
        if op == "end":
            return frozenset(), False
        raise AssertionError(op)

    def _next_literal(self, pc: int) -> Optional[int]:
        """First byte of the next instruction (for terminating var-length
        values)."""
        if pc + 1 >= len(self.prog):
            return EOS_ID
        op, arg = self.prog[pc + 1]
        if op == "lit":
            return arg
        if op == "end":
            return EOS_ID
        return None

    def mask(self, st: GrammarState) -> np.ndarray:
        m = np.zeros(self.vocab, dtype=np.int8)
        if self.prog[st.pc][0] == "end":
            m[EOS_ID] = 1
            return m
        allowed, can_term = self._allowed(st)
        for b in allowed:
            m[b] = 1
        if can_term or not allowed:
            nxt = self._next_literal(st.pc)
            if nxt is not None:
                m[nxt] = 1
        return m

    def advance(self, st: GrammarState, token: int) -> GrammarState:
        op, arg = self.prog[st.pc]
        if op == "end":
            return GrammarState(pc=st.pc, sub=1)
        allowed, can_term = self._allowed(st)
        if token in allowed:
            if op == "lit":
                return GrammarState(pc=st.pc + 1)
            if op == "digit":
                return GrammarState(pc=st.pc + 1)
            if op == "str":
                return GrammarState(st.pc, st.sub + 1, st.aux)
            if op == "int":
                aux = st.aux
                if token in DIGITS:
                    if not (aux & 1) and token == ord("0"):
                        aux |= 8        # leading zero closes the int part
                    aux |= 1
                return GrammarState(st.pc, st.sub + 1, aux)
            if op == "num":
                aux = st.aux
                if token in DIGITS:
                    if not (aux & 1) and not (aux & 2) and token == ord("0"):
                        aux |= 8
                    aux |= 1
                    aux &= ~4
                elif token == ord("."):
                    aux |= 2 | 4        # bit4: last char was the dot
                return GrammarState(st.pc, st.sub + 1, aux)
            if op == "bool":
                if st.aux == 0:
                    aux = 1 if token == ord("t") else 2
                    return GrammarState(st.pc, 1, aux)
                return GrammarState(st.pc, st.sub + 1, st.aux)
        # termination byte of a variable-length value → consume next instr
        nxt = self._next_literal(st.pc)
        if nxt is not None and token == nxt:
            if nxt == EOS_ID:
                return GrammarState(pc=st.pc + 1, sub=1)
            return GrammarState(pc=st.pc + 2)
        raise ValueError(
            f"token {token} not allowed at pc={st.pc} ({op}, sub={st.sub})")
