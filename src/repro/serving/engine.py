"""In-process LLM serving engine — the in-database inference backend that
the PREDICT operator drives (DESIGN.md §2: iPDB's "local model executor" on
a TPU pod).

Features mapped from the paper's optimizations:
  * batched prefill + decode with jit-compiled bucketed steps
  * grammar-constrained decoding (per-step masks from serving.grammar,
    applied by the fused constrained_logits kernel or the jnp ref)
  * shared-prefix KV reuse: the instruction prefix of a marshaled prompt is
    prefilled once and extended — the compute-side realization of multi-row
    prompt marshaling (§6.2).  Two layouts:
      - kv_layout="dense": per-row contiguous caches; the memoized prefix
        KV is broadcast (physically replicated) across the row batch
      - kv_layout="paged": one global pool of fixed-size KV pages plus
        per-row block tables; shared FULL prefix pages are referenced —
        not copied — by every row (O(1) memory, zero per-row device
        copies) and decode attention walks only the pages a row occupies
  * continuous batching (scheduler.py) with per-row cache indices (dense)
    or page-table slot lifecycle (paged)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL
from repro.models.config import ModelConfig
from repro.serving import tokenizer as TOK
from repro.serving.grammar import JsonGrammar
from repro.serving.radix import RadixPrefixCache

NEG_INF = -1e30


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclasses.dataclass
class GenStats:
    calls: int = 0
    input_tokens: int = 0
    output_tokens: int = 0
    prefill_tokens: int = 0        # actually prefit through the model
    decode_steps: int = 0
    wall_s: float = 0.0
    prefix_hits: int = 0
    radix_hit_tokens: int = 0      # prompt tokens served from the radix tree
    cow_copies: int = 0            # pages privatized by copy-on-write forks
    kv_bytes: int = 0              # peak KV-cache footprint (high-water)

    def add(self, other: "GenStats") -> None:
        for f in dataclasses.fields(self):
            if f.name == "kv_bytes":       # high-water mark, not a flow
                self.kv_bytes = max(self.kv_bytes, other.kv_bytes)
            else:
                setattr(self, f.name,
                        getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class GenResult:
    texts: List[str]
    stats: GenStats


class PageAllocator:
    """Host-side bookkeeping for the global KV page pool: a free list plus
    per-page refcounts (shared instruction-prefix pages are referenced by
    the prefix memo AND by every running batch that uses them) and a
    high-water mark — the `peak cache bytes` number the benchmarks report."""

    def __init__(self, num_pages: int):
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, -1, -1))       # pop() → 0 first
        self._ref = np.zeros(num_pages, np.int64)
        self.peak_in_use = 0

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.num_pages - len(self._free)

    @property
    def resident_pages(self) -> int:
        """Pages currently referenced by anyone (memo, radix tree, runs)."""
        return self.in_use

    @property
    def high_water(self) -> int:
        return self.peak_in_use

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"page pool exhausted: need {n}, free {len(self._free)}"
                f" of {self.num_pages}")
        ids = [self._free.pop() for _ in range(n)]
        for p in ids:
            self._ref[p] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return ids

    def retain(self, ids: Sequence[int]) -> None:
        for p in ids:
            self._ref[p] += 1

    def refs(self, page: int) -> int:
        return int(self._ref[page])

    def release(self, ids: Sequence[int]) -> None:
        for p in ids:
            self._ref[p] -= 1
            assert self._ref[p] >= 0, f"double free of page {p}"
            if self._ref[p] == 0:
                self._free.append(p)

    def grow(self, extra: int) -> None:
        start = self.num_pages
        self.num_pages += extra
        self._ref = np.concatenate([self._ref, np.zeros(extra, np.int64)])
        self._free.extend(range(self.num_pages - 1, start - 1, -1))


@dataclasses.dataclass
class _PrefixEntry:
    """Memoized shared-prefix KV: the host copy (layout-independent source
    of truth; decode steps donate their buffers and must never alias it)
    plus, in paged mode, the pool pages it is currently resident in."""
    host_kv: dict
    off: int                        # bucketed prefix length (dense slots)
    real_len: int                   # true token count
    pages: Optional[List[int]] = None


class InferenceEngine:
    """Single-host engine around one model. Tiny configs run the real JAX
    forward on CPU; the same code drives full configs on a TPU mesh (the
    steps come from launch.steps builders in that path)."""

    def __init__(self, cfg: ModelConfig, params=None, *,
                 seed: int = 0, max_len: int = 1024,
                 use_pallas_sampler: bool = False,
                 kv_layout: str = "dense", page_size: int = 64,
                 page_pool_pages: Optional[int] = None,
                 prefix_memo_entries: int = 16,
                 use_pallas_decode: bool = False,
                 prefix_cache_mode: str = "radix",
                 kv_quant: str = "none"):
        assert cfg.supports_decode, f"{cfg.name} cannot generate"
        assert kv_layout in ("dense", "paged"), kv_layout
        assert prefix_cache_mode in ("exact", "radix"), prefix_cache_mode
        assert kv_quant in ("none", "int8"), kv_quant
        if kv_layout == "paged":
            assert cfg.has_attention, "paged KV layout needs attention"
        self.cfg = cfg
        self.max_len = max_len
        self.params = params if params is not None else \
            MDL.init_params(cfg, jax.random.PRNGKey(seed))
        self.use_pallas_sampler = use_pallas_sampler
        self.use_pallas_decode = use_pallas_decode
        self.kv_layout = kv_layout
        self.page_size = int(page_size)
        self.page_pool_pages = page_pool_pages
        self.prefix_memo_entries = int(prefix_memo_entries)
        #: "radix": partial-overlap prefix reuse through a refcounted radix
        #: tree over token sequences; "exact": PR-5 exact-string prefix memo
        self.prefix_cache_mode = prefix_cache_mode
        #: "int8": frozen (tree-committed) pages are quantized on commit to
        #: an int8 shadow pool with per-page scales; live pages stay fp
        self.kv_quant = kv_quant
        #: per-row block-table width: max_len tokens worth of pages
        self.num_table_blocks = max(1, -(-max_len // self.page_size))
        self._prefill_cache: Dict[Tuple, object] = {}
        self._decode_fns: Dict[object, object] = {}
        #: LRU memo of shared-prefix KV (touch-on-get, capped — mirrors
        #: PromptCache semantics); evicting a paged-resident entry releases
        #: its pool pages
        self._prefix_kv: Dict[Tuple[str, int], _PrefixEntry] = {}
        self._rng = np.random.default_rng(seed)
        #: session-cumulative stats (EXPLAIN `-- dispatch --` surfacing)
        self.total = GenStats()
        # paged-layout state (lazy): device page pool + host allocator +
        # radix prefix tree + host-side frozen-page quant flags
        self._pool: Optional[Dict[str, jax.Array]] = None
        self._alloc: Optional[PageAllocator] = None
        self._radix: Optional[RadixPrefixCache] = None
        self._quant_flags: Optional[np.ndarray] = None
        #: running peak of the pool's logical KV bytes, counting quantized
        #: pages at 1 byte/element — the `kv_bytes` number runs report
        self.kv_peak_bytes = 0

    # ----------------------------- compiled steps -----------------------------
    def _prefill_fn(self, batch: int, length: int, offset: int):
        key = (batch, length, offset)
        if key not in self._prefill_cache:
            cfg = self.cfg

            def fn(params, tokens, positions, cache):
                logits, cache = MDL.forward(
                    cfg, params, {"tokens": tokens, "positions": positions},
                    mode="prefill", cache=cache, remat=False,
                    extend_offset=offset, last_only=True)
                return logits[:, -1], cache

            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    def _decode_fn(self):
        if "fn" not in self._decode_fns:
            cfg = self.cfg
            datt = None
            if self.use_pallas_decode:
                from repro.kernels import ops as KOPS
                datt = KOPS.decode_attention

            def fn(params, tokens, positions, cache):
                logits, cache = MDL.forward(
                    cfg, params, {"tokens": tokens, "positions": positions},
                    mode="decode", cache=cache, remat=False,
                    decode_attn_fn=datt)
                return logits[:, 0], cache

            self._decode_fns["fn"] = jax.jit(fn, donate_argnums=(3,))
        return self._decode_fns["fn"]

    def _decode_fn_paged(self, num_blocks: int):
        """Decode step against the page pool; jit-cached per block-table
        width, so the attention grid covers only the blocks the batch
        actually occupies (the caller buckets `num_blocks`)."""
        key = ("paged", num_blocks)
        if key not in self._decode_fns:
            cfg = self.cfg
            datt = None
            if self.use_pallas_decode:
                from repro.kernels import ops as KOPS
                datt = KOPS.decode_attention_paged

            def fn(params, tokens, positions, cache, bt, qf):
                cache = dict(cache, block_tables=bt)
                if qf is not None:
                    cache["quant_flags"] = qf
                logits, cache = MDL.forward(
                    cfg, params, {"tokens": tokens, "positions": positions},
                    mode="decode", cache=cache, remat=False,
                    decode_attn_fn=datt)
                return logits[:, 0], cache

            self._decode_fns[key] = jax.jit(fn, donate_argnums=(3,))
        return self._decode_fns[key]

    # ------------------------------- prefill ----------------------------------
    def _prefill(self, token_lists: List[List[int]], *, offset: int = 0,
                 pos_offset: Optional[int] = None,
                 cache: Optional[dict] = None, row_idx_mode: bool = False):
        """offset = cache slot offset (bucketed prefix length);
        pos_offset = absolute position offset (REAL prefix length — RoPE
        positions must not jump over the prefix bucket padding)."""
        if pos_offset is None:
            pos_offset = offset
        B = len(token_lists)
        L = _bucket(max(len(t) for t in token_lists))
        toks = np.full((B, L), TOK.PAD_ID, np.int32)
        pos = np.zeros((B, L), np.int32)
        for i, t in enumerate(token_lists):
            pad = L - len(t)
            toks[i, pad:] = t                                # left padding
            pos[i] = np.arange(L) - pad + pos_offset
            pos[i, :pad] = -1      # pads masked (never overlap the prefix)
        if cache is None:
            cache = MDL.init_cache(self.cfg, B, self.max_len)
            if row_idx_mode:
                cache["row_idx"] = jnp.zeros((B,), jnp.int32)
        logits, cache = self._prefill_fn(B, L, offset)(
            self.params, jnp.asarray(toks), jnp.asarray(pos), cache)
        if "row_idx" in cache or row_idx_mode:
            cache = dict(cache)
            cache["row_idx"] = jnp.full((B,), offset + L, jnp.int32)
        lens = np.array([pos_offset + len(t) for t in token_lists], np.int32)
        return np.asarray(logits, np.float32), cache, lens, B * L

    # ------------------------------ page pool ---------------------------------
    def _page_bytes(self) -> int:
        cfg = self.cfg
        itemsize = 2 if cfg.compute_dtype in ("bfloat16", "float16") else 4
        return (2 * cfg.num_layers * self.page_size * cfg.num_kv_heads
                * cfg.head_dim * itemsize)

    def _page_bytes_quant(self) -> int:
        """Logical bytes of a frozen int8 page (scales are negligible)."""
        cfg = self.cfg
        return (2 * cfg.num_layers * self.page_size * cfg.num_kv_heads
                * cfg.head_dim)

    def _note_kv(self) -> None:
        """Fold the pool's current logical KV footprint into the running
        peak: live pages at full precision, frozen pages at int8."""
        a = self._alloc
        if a is None:
            return
        nq = 0
        if self._quant_flags is not None:
            nq = int(np.sum((self._quant_flags[:a.num_pages] > 0)
                            & (a._ref[:a.num_pages] > 0)))
        cur = (a.in_use - nq) * self._page_bytes() \
            + nq * self._page_bytes_quant()
        self.kv_peak_bytes = max(self.kv_peak_bytes, cur)

    # page lifecycle wrappers: every allocation flows through here so quant
    # flags are reset on reuse and the kv-bytes peak is tracked in one place
    def alloc_pages(self, n: int) -> List[int]:
        ids = self._alloc.alloc(n)
        if self._quant_flags is not None and ids:
            self._quant_flags[np.asarray(ids, np.int64)] = 0
        self._note_kv()
        return ids

    def retain_pages(self, ids: Sequence[int]) -> None:
        self._alloc.retain(ids)

    def release_pages(self, ids: Sequence[int]) -> None:
        self._alloc.release(ids)

    def copy_pages(self, srcs: Sequence[int], dsts: Sequence[int]) -> None:
        """Copy-on-write privatization: batched device copy of fp pages
        (COW sources are live, never-quantized pages by construction)."""
        if not srcs:
            return
        s = jnp.asarray(srcs, jnp.int32)
        d = jnp.asarray(dsts, jnp.int32)
        for kk in ("k", "v"):
            self._pool[kk] = self._pool[kk].at[:, :, d].set(
                self._pool[kk][:, :, s])

    def _quantize_pages(self, pages: Sequence[int]) -> None:
        """Quantize-on-commit: symmetric per-(layer, kv-head, page) int8
        with scale = abs-max / 127, written to the shadow pool.  Only ever
        called for freshly tree-committed (frozen) pages; the fp copy stays
        authoritative until the flag flips, and flags are host state so the
        very next device step reads the quantized form."""
        if not pages:
            return
        n = 1                       # pow-2 pad (repeat id 0 — idempotent)
        while n < len(pages):
            n *= 2
        padded = list(pages) + [pages[0]] * (n - len(pages))
        pg = jnp.asarray(padded, jnp.int32)
        for kk, qk, sk in (("k", "kq", "kscale"), ("v", "vq", "vscale")):
            src = self._pool[kk][:, :, pg].astype(jnp.float32)
            amax = jnp.max(jnp.abs(src), axis=(3, 4))      # (ln, kv, n)
            scale = jnp.maximum(amax, 1e-8) / 127.0
            qv = jnp.clip(jnp.round(src / scale[..., None, None]),
                          -127, 127).astype(jnp.int8)
            self._pool[qk] = self._pool[qk].at[:, :, pg].set(qv)
            self._pool[sk] = self._pool[sk].at[:, :, pg].set(scale)
        self._quant_flags[np.asarray(pages, np.int64)] = 1
        self._note_kv()

    # ------------------------------ radix cache --------------------------------
    def radix_match(self, ids: Sequence[int], stats: GenStats,
                    limit: Optional[int] = None) -> Tuple[List[int], int]:
        """Deepest page-aligned prefix of `ids` resident in the radix tree.
        Returned pages are retained for the caller (release when done)."""
        if self._radix is None:
            return [], 0
        pages, n = self._radix.match(ids, limit=limit)
        if n:
            stats.prefix_hits += 1
            stats.radix_hit_tokens += n
        return pages, n

    def radix_insert(self, ids: Sequence[int], pages: Sequence[int]
                     ) -> List[int]:
        """Commit the full-page span of `ids` (backed by `pages`) to the
        radix tree; newly adopted pages are frozen and, in int8 mode,
        quantized on the spot."""
        if self._radix is None:
            return []
        nfull = len(ids) // self.page_size
        if nfull == 0:
            return []
        adopted = self._radix.insert(
            list(ids[:nfull * self.page_size]), list(pages[:nfull]))
        if adopted and self.kv_quant == "int8":
            self._quantize_pages(adopted)
        return adopted

    # -- warm-state snapshots (core/snapshot.py) -------------------------
    def export_radix_state(self) -> Optional[dict]:
        """Host-side payload of the radix prefix cache: every node's full
        root-to-node token path plus the fp KV of its own pages, in
        parent-before-child order so a restore can rebuild the tree with
        plain `radix_insert` calls.  int8 shadow pages are NOT exported —
        restore re-quantizes adopted pages from the fp data, yielding the
        identical quantized form.  None when nothing is resident."""
        if self._radix is None or self._pool is None:
            return None
        entries = []
        stack = [(self._radix._root, ())]
        order = []
        while stack:
            node, path = stack.pop()
            if node.key:
                order.append((node, path + tuple(node.key)))
            for c in node.children.values():
                stack.append((c, path + tuple(node.key)))
        # DFS pop order is not parent-first for siblings' subtrees; sort
        # by path length, which is: a parent's path is a strict prefix
        # (hence strictly shorter) than any descendant's
        order.sort(key=lambda t: len(t[1]))
        for node, path in order:
            pg = np.asarray(node.pages, np.int64)
            entries.append({
                "path": list(path),
                "k": np.asarray(self._pool["k"][:, :, pg]),
                "v": np.asarray(self._pool["v"][:, :, pg]),
            })
        if not entries:
            return None
        return {"page_size": self.page_size, "entries": entries}

    def restore_radix_state(self, payload: dict) -> int:
        """Rebuild the radix tree from an `export_radix_state` payload on
        a (typically fresh) engine: alloc pages, write the KV back, and
        commit each node with `radix_insert` (which re-freezes and, in
        int8 mode, re-quantizes the adopted pages).  Returns the number
        of pages restored; a payload from a different page-size geometry
        is ignored."""
        if not payload or int(payload.get("page_size", -1)) != self.page_size:
            return 0
        ps = self.page_size
        restored = 0
        pages_for_path: Dict[Tuple[int, ...], List[int]] = {(): []}
        for ent in payload.get("entries", []):
            path = tuple(int(t) for t in ent["path"])
            k_host, v_host = ent["k"], ent["v"]
            own_np = int(k_host.shape[2])
            parent_path = path[: len(path) - own_np * ps]
            parent_pages = pages_for_path.get(parent_path)
            if parent_pages is None or len(path) % ps:
                continue               # orphaned entry: skip defensively
            if not self._ensure_pool(own_np):
                break                  # pinned pool exhausted: partial warm
            own = self.alloc_pages(own_np)
            pg = jnp.asarray(own, jnp.int32)
            self._pool["k"] = self._pool["k"].at[:, :, pg].set(
                jnp.asarray(k_host, self._pool["k"].dtype))
            self._pool["v"] = self._pool["v"].at[:, :, pg].set(
                jnp.asarray(v_host, self._pool["v"].dtype))
            full_pages = list(parent_pages) + list(own)
            self.radix_insert(list(path), full_pages)
            # the tree now holds its own reference to the adopted pages;
            # drop ours so restored nodes are plain LRU-evictable leaves
            self.release_pages(own)
            pages_for_path[path] = full_pages
            restored += own_np
        self._note_kv()
        return restored

    def _dense_cache_bytes(self, cache: dict) -> int:
        return int(cache["k"].size * cache["k"].dtype.itemsize
                   + cache["v"].size * cache["v"].dtype.itemsize) \
            if "k" in cache else 0

    def _ensure_pool(self, need_pages: int) -> bool:
        """Make `need_pages` allocatable: create the pool lazily, then free
        pages by dropping LRU prefix residencies, then grow the device
        arrays — unless the operator pinned `page_pool_pages`, in which
        case the pool is a hard memory bound and False is returned when the
        demand cannot fit (callers wait for slot frees or raise)."""
        quant = self.kv_quant == "int8"
        if self._pool is None:
            n = self.page_pool_pages or \
                max(2 * need_pages, 2 * self.num_table_blocks)
            n = max(n, 1)
            if self.page_pool_pages is None:
                n = max(n, need_pages)
            full = MDL.init_paged_cache(self.cfg, n, self.page_size,
                                        quant=quant)
            keys = ("k", "v") + (("kq", "vq", "kscale", "vscale")
                                 if quant else ())
            self._pool = {kk: full[kk] for kk in keys}
            self._alloc = PageAllocator(n)
            if quant:
                self._quant_flags = np.zeros(n, np.int8)
            if self.prefix_cache_mode == "radix":
                self._radix = RadixPrefixCache(self._alloc, self.page_size)
            return self._alloc.free_pages >= need_pages
        a = self._alloc
        if a.free_pages >= need_pages:
            return True
        for key in list(self._prefix_kv):      # LRU-first residency drop
            if a.free_pages >= need_pages:
                break
            ent = self._prefix_kv[key]
            # skip entries whose pages an in-flight run still retains:
            # releasing the memo's reference would free nothing while
            # permanently discarding the zero-copy residency
            if ent.pages is not None and \
                    all(a.refs(p) == 1 for p in ent.pages):
                a.release(ent.pages)
                ent.pages = None
        if a.free_pages < need_pages and self._radix is not None:
            # radix eviction: LRU leaf nodes with no outside readers
            self._radix.evict(need_pages - a.free_pages)
        if a.free_pages >= need_pages:
            return True
        if self.page_pool_pages is not None:
            return False                       # pinned pool: hard bound
        extra = max(need_pages - a.free_pages, a.num_pages // 2)
        for kk in self._pool:
            pool = self._pool[kk]
            # page axis of the folded (ln, KV, P, ...) layout
            pad = jnp.zeros(pool.shape[:2] + (extra,) + pool.shape[3:],
                            pool.dtype)
            self._pool[kk] = jnp.concatenate([pool, pad], axis=2)
        if self._quant_flags is not None:
            self._quant_flags = np.concatenate(
                [self._quant_flags, np.zeros(extra, np.int8)])
        a.grow(extra)
        return True

    def _ssm_state(self, batch: int) -> Dict[str, jax.Array]:
        """Per-row SSM state for paged runs (shapes owned by
        model.paged_cache_specs — single source of truth)."""
        if not self.cfg.has_ssm:
            return {}
        specs = MDL.paged_cache_specs(self.cfg, 1, self.page_size, batch)
        return {k: jnp.zeros(specs[k].shape, specs[k].dtype)
                for k in ("conv", "h")}

    # ----------------------------- prefix memo --------------------------------
    def _prefix_memo_get(self, key) -> Optional[_PrefixEntry]:
        ent = self._prefix_kv.get(key)
        if ent is not None:
            del self._prefix_kv[key]           # touch-on-get: move to MRU end
            self._prefix_kv[key] = ent
        return ent

    def _prefix_memo_put(self, key, ent: _PrefixEntry) -> None:
        while len(self._prefix_kv) >= max(1, self.prefix_memo_entries):
            k0 = next(iter(self._prefix_kv))
            old = self._prefix_kv.pop(k0)
            if old.pages is not None and self._alloc is not None:
                self._alloc.release(old.pages)   # refcounted: in-flight
                old.pages = None                 # users keep them alive
        self._prefix_kv[key] = ent

    def _prefix_entry_for(self, prefix_text: str, stats: GenStats
                          ) -> _PrefixEntry:
        """Memo lookup; on miss the prefix is prefilled ONCE (batch=1) and
        its KV kept on host."""
        ids = TOK.encode(prefix_text)
        key = (prefix_text, self.max_len)
        ent = self._prefix_memo_get(key)
        if ent is None:
            _, cache1, _, _ = self._prefill([ids])
            ent = _PrefixEntry(
                host_kv=jax.tree.map(lambda x: np.asarray(x), cache1),
                off=int(np.asarray(cache1["idx"])), real_len=len(ids))
            self._prefix_memo_put(key, ent)
            stats.prefill_tokens += len(ids)
        else:
            stats.prefix_hits += 1
        return ent

    # ----------------------------- shared prefix ------------------------------
    def prefix_cache_for(self, prefix_text: str, batch: int):
        """Dense layout: prefill the shared instruction prefix ONCE
        (batch=1), memoize, broadcast to the row batch. Returns
        (cache, offset, real_len, new_prefill_tokens, hit)."""
        ids = TOK.encode(prefix_text)
        probe = GenStats()
        ent = self._prefix_entry_for(prefix_text, probe)
        hit = probe.prefix_hits > 0
        cache1, off, real_len = ent.host_kv, ent.off, ent.real_len

        def rep(x):
            x = jnp.asarray(x)
            if x.ndim >= 2 and x.shape[1] == 1:     # (L, 1, ...) layer caches
                return jnp.repeat(x, batch, axis=1)
            if x.ndim >= 1 and x.shape[0] == 1:     # (1, lc) slot_pos
                return jnp.repeat(x, batch, axis=0)
            return x
        cache = {k: (rep(v) if k not in ("idx",) else v)
                 for k, v in cache1.items()}
        return cache, off, real_len, (0 if hit else len(ids)), hit

    def prefix_pages_for(self, prefix_text: str, stats: GenStats
                         ) -> Tuple[List[int], int, List[int]]:
        """Paged layout: resolve the shared prefix to pool pages.  Only
        FULL pages are shared (every referencing row reads them in place);
        the sub-page tail rides with each row's suffix so rows never write
        into a shared page.  Returns (page_ids, shared_token_count,
        tail_token_ids)."""
        ids = TOK.encode(prefix_text)
        ps = self.page_size
        n_share = (len(ids) // ps) * ps
        if n_share == 0:
            return [], 0, ids
        npre = n_share // ps
        peek = self._prefix_kv.get((prefix_text, self.max_len))
        if (peek is None or peek.pages is None) and not self._ensure_pool(npre):
            # pinned pool too small to ever share: bail BEFORE the memo so
            # no batch=1 prefill is wasted and no phantom prefix_hits are
            # counted for reuse that cannot physically happen
            return [], 0, ids
        ent = self._prefix_entry_for(prefix_text, stats)
        if ent.pages is None:
            pages = self.alloc_pages(npre)
            cfg = self.cfg
            k1 = jnp.asarray(ent.host_kv["k"])        # (ln, 1, lc, kv, hd)
            v1 = jnp.asarray(ent.host_kv["v"])
            # prefill wrote the bucketed sequence at slots 0..off-1 with the
            # left padding first: token t lives at slot (off - len) + t
            pad = ent.off - len(ids)
            dp = MDL.padded_head_dim(cfg.head_dim)
            shp = (cfg.num_layers, npre, ps, cfg.num_kv_heads, cfg.head_dim)

            def fold(src):
                # (ln, npre, ps, kv, hd) → (ln, kv, npre, ps, Dp)
                src = src.reshape(shp).transpose(0, 3, 1, 2, 4)
                return jnp.pad(src, [(0, 0)] * 4
                               + [(0, dp - cfg.head_dim)])
            ksrc = fold(k1[:, 0, pad:pad + n_share])
            vsrc = fold(v1[:, 0, pad:pad + n_share])
            pg = jnp.asarray(pages, jnp.int32)
            self._pool["k"] = self._pool["k"].at[:, :, pg].set(
                ksrc.astype(self._pool["k"].dtype))
            self._pool["v"] = self._pool["v"].at[:, :, pg].set(
                vsrc.astype(self._pool["v"].dtype))
            ent.pages = pages
        return list(ent.pages), n_share, ids[n_share:]

    # ----------------------------- paged prefill ------------------------------
    def paged_prefill(self, token_lists: List[List[int]], table_rows,
                      prefix_pages: Sequence[int], prefix_len: int, *,
                      extra: Optional[dict] = None):
        """Prefill suffixes straight into their block-table pages, reading
        shared prefix pages in place (no per-row replication).  table_rows:
        np.ndarray (B, NB) page ids.  Returns (logits, lens, prefill_token
        count, extra_out) — extra carries per-row SSM state for hybrid
        models."""
        B = len(token_lists)
        L = _bucket(max(len(t) for t in token_lists))
        toks = np.full((B, L), TOK.PAD_ID, np.int32)
        pos = np.zeros((B, L), np.int32)
        for i, t in enumerate(token_lists):
            pad = L - len(t)
            toks[i, pad:] = t
            pos[i] = np.arange(L) - pad + prefix_len
            pos[i, :pad] = -1
        npre = len(prefix_pages)
        cache = dict(self._pool, idx=jnp.int32(0))
        if extra:
            cache.update(extra)
        key = ("paged", B, L, table_rows.shape[1], npre)
        if key not in self._prefill_cache:
            cfg = self.cfg

            # block table / prefix table / quant flags ride OUTSIDE the
            # donated cache: they are rebuilt host-side every call,
            # donation buys nothing
            def fn(params, tokens, positions, cache, bt, ptab, plen, qf):
                cache = dict(cache, block_tables=bt, prefix_table=ptab,
                             prefix_len=plen)
                if qf is not None:
                    cache["quant_flags"] = qf
                logits, cache = MDL.forward(
                    cfg, params, {"tokens": tokens, "positions": positions},
                    mode="prefill", cache=cache, remat=False, last_only=True)
                return logits[:, -1], cache

            self._prefill_cache[key] = jax.jit(fn, donate_argnums=(3,))
        qf = None if self._quant_flags is None \
            else jnp.asarray(self._quant_flags)
        logits, out = self._prefill_cache[key](
            self.params, jnp.asarray(toks), jnp.asarray(pos), cache,
            jnp.asarray(np.ascontiguousarray(table_rows)),
            jnp.asarray(np.asarray(prefix_pages, np.int32).reshape(npre)),
            jnp.int32(prefix_len), qf)
        for kk in self._pool:
            self._pool[kk] = out[kk]
        extra_out = {k: out[k] for k in ("conv", "h") if k in out}
        lens = np.array([prefix_len + len(t) for t in token_lists], np.int32)
        return np.asarray(logits, np.float32), lens, B * L, extra_out

    def paged_decode(self, toks, positions, table, num_blocks: int, *,
                     extra: Optional[dict] = None):
        """One lock-step decode tick against the page pool.  `table` is the
        host block table (B, NB_full); only its first `num_blocks` columns
        (the batch's actual fill, bucketed by the caller) reach the device,
        so attention work scales with occupancy, not max_len."""
        cache = dict(self._pool, idx=jnp.int32(0))
        if extra:
            cache.update(extra)
        dec = self._decode_fn_paged(num_blocks)
        qf = None if self._quant_flags is None \
            else jnp.asarray(self._quant_flags)
        lg, out = dec(self.params, jnp.asarray(toks[:, None]),
                      jnp.asarray(positions[:, None]), cache,
                      jnp.asarray(np.ascontiguousarray(table[:, :num_blocks])),
                      qf)
        for kk in self._pool:
            self._pool[kk] = out[kk]
        extra_out = {k: out[k] for k in ("conv", "h") if k in out}
        return np.asarray(lg, np.float32), extra_out

    def active_blocks(self, fills) -> int:
        """Bucketed block count covering the given fill levels (pow-2 so
        decode-step jit caches stay few)."""
        need = max(1, max((int(f) // self.page_size) + 1 for f in fills))
        nb = 1
        while nb < need:
            nb *= 2
        return min(nb, self.num_table_blocks)

    # ------------------------------- generate ---------------------------------
    @staticmethod
    def _consume_tokens(toks, gs, states, out_tokens, done,
                        stats: GenStats) -> None:
        """Apply one sampled token per not-yet-done row: grammar advance,
        EOS, completion + per-tick stats. Shared by the dense and paged
        generate loops so their semantics cannot drift."""
        for i in range(len(done)):
            if done[i]:
                continue
            t = int(toks[i])
            if gs[i] is not None:
                states[i] = gs[i].advance(states[i], t)
                if t != TOK.EOS_ID:
                    out_tokens[i].append(t)
                if gs[i].done(states[i]):
                    done[i] = True
            else:
                if t == TOK.EOS_ID:
                    done[i] = True
                else:
                    out_tokens[i].append(t)
        stats.decode_steps += 1
        stats.output_tokens += int((~done).sum())

    def generate(self, prompts: Sequence[str], *,
                 grammar: Optional[JsonGrammar] = None,
                 grammars: Optional[List[JsonGrammar]] = None,
                 max_new_tokens: int = 256, temperature: float = 0.0,
                 shared_prefix: str = "") -> GenResult:
        """Generate for a batch of prompts. If shared_prefix is given it is
        prefilled once and KV-reused across rows (prompts are then the
        suffixes). Grammar-constrained when grammar(s) provided."""
        t0 = time.time()
        stats = GenStats(calls=1)
        B = len(prompts)
        gs = grammars or ([grammar] * B if grammar else [None] * B)
        states = [g.init_state() if g else None for g in gs]

        if self.kv_layout == "paged":
            texts = self._generate_paged(prompts, gs, states, max_new_tokens,
                                         temperature, shared_prefix, stats)
            stats.wall_s = time.time() - t0
            self.total.add(stats)
            return GenResult(texts, stats)

        offset = 0
        pos_offset = None
        cache = None
        if shared_prefix:
            cache, offset, pos_offset, new_prefix_toks, hit = \
                self.prefix_cache_for(shared_prefix, B)
            stats.prefill_tokens += new_prefix_toks
            stats.prefix_hits += int(hit)
            stats.input_tokens += TOK.count_tokens(shared_prefix)

        token_lists = [TOK.encode(p, bos=not shared_prefix) for p in prompts]
        stats.input_tokens += sum(len(t) for t in token_lists)
        logits, cache, lens, pre = self._prefill(
            token_lists, offset=offset, pos_offset=pos_offset,
            cache=cache, row_idx_mode=True)
        stats.prefill_tokens += pre
        stats.kv_bytes = self._dense_cache_bytes(cache)

        decode = self._decode_fn()
        out_tokens: List[List[int]] = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        positions = lens.copy()

        for step in range(max_new_tokens):
            toks = self._sample(logits, gs, states, temperature)
            self._consume_tokens(toks, gs, states, out_tokens, done, stats)
            if done.all():
                break
            lg, cache = decode(self.params, jnp.asarray(toks[:, None]),
                               jnp.asarray(positions[:, None]), cache)
            logits = np.asarray(lg, np.float32)
            positions += 1

        stats.wall_s = time.time() - t0
        self.total.add(stats)
        return GenResult([TOK.decode(t) for t in out_tokens], stats)

    def _generate_paged(self, prompts, gs, states, max_new_tokens,
                        temperature, shared_prefix, stats: GenStats
                        ) -> List[str]:
        """Paged-layout generate: per-row block tables over the global page
        pool.  prefix_cache_mode="exact": a shared prefix resolves through
        the exact-string memo and contributes the SAME page ids to every
        row's table.  "radix": the batch-common token prefix is matched
        against the radix tree (discovering partial overlap with ANY prior
        prompt), suffix prefill starts at the deepest matched page, and the
        rows' full prompt pages are committed back to the tree."""
        B = len(prompts)
        ps = self.page_size
        NBf = self.num_table_blocks
        cap = NBf * ps

        pages_pre: List[int] = []
        n_share = 0
        if self.prefix_cache_mode == "radix":
            self._ensure_pool(0)               # materialize pool + tree
            token_lists = [TOK.encode(shared_prefix + p) if shared_prefix
                           else TOK.encode(p) for p in prompts]
            if shared_prefix:
                stats.input_tokens += TOK.count_tokens(shared_prefix)
                npre_tok = len(TOK.encode(shared_prefix))
            else:
                npre_tok = 0
            stats.input_tokens += sum(len(t) - npre_tok for t in token_lists)
            # batch-common token prefix, leaving >= 1 suffix token per row
            common = list(token_lists[0])
            for t in token_lists[1:]:
                n = 0
                while n < len(common) and n < len(t) and common[n] == t[n]:
                    n += 1
                common = common[:n]
            aligned = min(len(common), min(len(t) for t in token_lists) - 1)
            aligned = (aligned // ps) * ps
            pages_pre, n_share = self.radix_match(common, stats,
                                                  limit=aligned)
            if B >= 2 and n_share < aligned and \
                    self._ensure_pool((aligned - n_share) // ps):
                # seed prefill: materialize the still-missing span of the
                # batch-common prefix ONCE (batch=1) and commit it, so the
                # per-row prefills below all start at `aligned`
                seed = self.alloc_pages((aligned - n_share) // ps)
                st = np.full((1, NBf), -1, np.int32)
                st[0, :n_share // ps] = pages_pre
                st[0, n_share // ps:aligned // ps] = seed
                _, _, pre, _ = self.paged_prefill(
                    [common[n_share:aligned]], st, pages_pre, n_share,
                    extra=self._ssm_state(1))
                stats.prefill_tokens += pre
                self.radix_insert(common[:aligned],
                                  list(st[0, :aligned // ps]))
                pages_pre = pages_pre + seed   # run holds one ref on each
                n_share = aligned
            token_lists = [t[n_share:] for t in token_lists]
        elif shared_prefix:
            pages_pre, n_share, tail = self.prefix_pages_for(
                shared_prefix, stats)
            stats.input_tokens += TOK.count_tokens(shared_prefix)
            token_lists = [tail + TOK.encode(p, bos=False) for p in prompts]
            stats.input_tokens += sum(len(t) - len(tail)
                                      for t in token_lists)
            if self._alloc is not None and pages_pre:
                self.retain_pages(pages_pre)   # survive memo eviction
        else:
            token_lists = [TOK.encode(p) for p in prompts]
            stats.input_tokens += sum(len(t) for t in token_lists)

        npre = len(pages_pre)
        table = np.full((B, NBf), -1, np.int32)
        if npre:
            table[:, :npre] = pages_pre        # shared: same ids every row
        owned: List[List[int]] = []
        try:
            need_each = [max(0, -(-min(n_share + len(t) + max_new_tokens,
                                       cap) // ps) - npre)
                         for t in token_lists]
            if not self._ensure_pool(sum(need_each)):
                raise RuntimeError(
                    f"page pool ({self.page_pool_pages} pages) too small "
                    f"for batch of {B} rows")
            for i, need in enumerate(need_each):
                ids = self.alloc_pages(need)
                owned.append(ids)
                table[i, npre:npre + need] = ids

            extra = self._ssm_state(B)
            logits, lens, pre, extra = self.paged_prefill(
                token_lists, table, pages_pre, n_share, extra=extra)
            stats.prefill_tokens += pre
            if self.prefix_cache_mode == "radix":
                # commit every row's full-page prompt span (clamped to the
                # pages actually allocated when the row is capacity-bound);
                # identical or overlapping rows dedup inside the tree
                for i, t in enumerate(token_lists):
                    nfull = min((n_share + len(t)) // ps,
                                npre + need_each[i])
                    if nfull > npre:
                        self.radix_insert((common[:n_share] + t)[:nfull * ps],
                                          list(table[i, :nfull]))

            out_tokens: List[List[int]] = [[] for _ in range(B)]
            done = np.zeros(B, bool)
            positions = lens.copy()

            for step in range(max_new_tokens):
                toks = self._sample(logits, gs, states, temperature)
                self._consume_tokens(toks, gs, states, out_tokens, done,
                                     stats)
                if done.all():
                    break
                nb = self.active_blocks(positions[~done])
                logits, extra = self.paged_decode(toks, positions, table, nb,
                                                  extra=extra)
                positions += 1
        finally:
            # errors must not leak refcounts: a pinned pool would shrink
            # permanently
            for ids in owned:
                self.release_pages(ids)
            if pages_pre:
                self.release_pages(pages_pre)
        self._note_kv()
        stats.kv_bytes = self.kv_peak_bytes
        return [TOK.decode(t) for t in out_tokens]

    # ------------------------------- sampling ---------------------------------
    def _sample(self, logits: np.ndarray, gs, states, temperature: float
                ) -> np.ndarray:
        B, V = logits.shape
        mask = np.ones((B, V), np.int8)
        for i, (g, st) in enumerate(zip(gs, states)):
            if g is not None:
                m = g.mask(st)
                mask[i, :] = 0
                mask[i, :len(m)] = m
        noise = None
        if temperature > 0:
            u = self._rng.uniform(1e-9, 1.0, size=(B, V))
            noise = -np.log(-np.log(u))
        if self.use_pallas_sampler:
            from repro.kernels import ops as KOPS
            return np.asarray(KOPS.constrained_sample(
                jnp.asarray(logits), jnp.asarray(mask),
                None if noise is None else jnp.asarray(noise),
                temperature=max(temperature, 1e-6) if temperature > 0 else 1.0,
                block_v=256, interpret=True))
        x = logits / (temperature if temperature > 0 else 1.0)
        if noise is not None:
            x = x + noise
        x = np.where(mask != 0, x, NEG_INF)
        return np.argmax(x, axis=-1).astype(np.int32)
