"""In-process LLM serving engine — the in-database inference backend that
the PREDICT operator drives (DESIGN.md §2: iPDB's "local model executor" on
a TPU pod).

Features mapped from the paper's optimizations:
  * batched prefill + decode with jit-compiled bucketed steps
  * grammar-constrained decoding (per-step masks from serving.grammar,
    applied by the fused constrained_logits kernel or the jnp ref)
  * shared-prefix KV reuse: the instruction prefix of a marshaled prompt is
    prefilled once, broadcast across the row batch, and extended — the
    compute-side realization of multi-row prompt marshaling (§6.2)
  * continuous batching (scheduler.py) with per-row cache indices
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL
from repro.models.config import ModelConfig
from repro.serving import tokenizer as TOK
from repro.serving.grammar import JsonGrammar

NEG_INF = -1e30


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclasses.dataclass
class GenStats:
    calls: int = 0
    input_tokens: int = 0
    output_tokens: int = 0
    prefill_tokens: int = 0        # actually prefit through the model
    decode_steps: int = 0
    wall_s: float = 0.0
    prefix_hits: int = 0

    def add(self, other: "GenStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))


@dataclasses.dataclass
class GenResult:
    texts: List[str]
    stats: GenStats


class InferenceEngine:
    """Single-host engine around one model. Tiny configs run the real JAX
    forward on CPU; the same code drives full configs on a TPU mesh (the
    steps come from launch.steps builders in that path)."""

    def __init__(self, cfg: ModelConfig, params=None, *,
                 seed: int = 0, max_len: int = 1024,
                 use_pallas_sampler: bool = False):
        assert cfg.supports_decode, f"{cfg.name} cannot generate"
        self.cfg = cfg
        self.max_len = max_len
        self.params = params if params is not None else \
            MDL.init_params(cfg, jax.random.PRNGKey(seed))
        self.use_pallas_sampler = use_pallas_sampler
        self._prefill_cache: Dict[Tuple[int, int, int], object] = {}
        self._decode_fns: Dict[int, object] = {}
        self._prefix_kv: Dict[Tuple[str, int], Tuple[dict, int]] = {}
        self._rng = np.random.default_rng(seed)

    # ----------------------------- compiled steps -----------------------------
    def _prefill_fn(self, batch: int, length: int, offset: int):
        key = (batch, length, offset)
        if key not in self._prefill_cache:
            cfg = self.cfg

            def fn(params, tokens, positions, cache):
                logits, cache = MDL.forward(
                    cfg, params, {"tokens": tokens, "positions": positions},
                    mode="prefill", cache=cache, remat=False,
                    extend_offset=offset, last_only=True)
                return logits[:, -1], cache

            self._prefill_cache[key] = jax.jit(fn)
        return self._prefill_cache[key]

    def _decode_fn(self):
        if "fn" not in self._decode_fns:
            cfg = self.cfg

            def fn(params, tokens, positions, cache):
                logits, cache = MDL.forward(
                    cfg, params, {"tokens": tokens, "positions": positions},
                    mode="decode", cache=cache, remat=False)
                return logits[:, 0], cache

            self._decode_fns["fn"] = jax.jit(fn, donate_argnums=(3,))
        return self._decode_fns["fn"]

    # ------------------------------- prefill ----------------------------------
    def _prefill(self, token_lists: List[List[int]], *, offset: int = 0,
                 pos_offset: Optional[int] = None,
                 cache: Optional[dict] = None, row_idx_mode: bool = False):
        """offset = cache slot offset (bucketed prefix length);
        pos_offset = absolute position offset (REAL prefix length — RoPE
        positions must not jump over the prefix bucket padding)."""
        if pos_offset is None:
            pos_offset = offset
        B = len(token_lists)
        L = _bucket(max(len(t) for t in token_lists))
        toks = np.full((B, L), TOK.PAD_ID, np.int32)
        pos = np.zeros((B, L), np.int32)
        for i, t in enumerate(token_lists):
            pad = L - len(t)
            toks[i, pad:] = t                                # left padding
            pos[i] = np.arange(L) - pad + pos_offset
            pos[i, :pad] = -1      # pads masked (never overlap the prefix)
        if cache is None:
            cache = MDL.init_cache(self.cfg, B, self.max_len)
            if row_idx_mode:
                cache["row_idx"] = jnp.zeros((B,), jnp.int32)
        logits, cache = self._prefill_fn(B, L, offset)(
            self.params, jnp.asarray(toks), jnp.asarray(pos), cache)
        if "row_idx" in cache or row_idx_mode:
            cache = dict(cache)
            cache["row_idx"] = jnp.full((B,), offset + L, jnp.int32)
        lens = np.array([pos_offset + len(t) for t in token_lists], np.int32)
        return np.asarray(logits, np.float32), cache, lens, B * L

    # ----------------------------- shared prefix ------------------------------
    def prefix_cache_for(self, prefix_text: str, batch: int):
        """Prefill the shared instruction prefix ONCE (batch=1), memoize,
        broadcast to the row batch. Returns (cache, offset, stats_delta)."""
        ids = TOK.encode(prefix_text)
        key = (prefix_text, self.max_len)
        hit = key in self._prefix_kv
        if not hit:
            _, cache1, lens, pre_toks = self._prefill([ids])
            # keep the memoized prefix KV on host: downstream decode steps
            # donate their cache buffers, which must never alias this copy
            self._prefix_kv[key] = (
                jax.tree.map(lambda x: np.asarray(x), cache1),
                int(np.asarray(cache1["idx"])), len(ids))
        cache1, off, real_len = self._prefix_kv[key]

        def rep(x):
            x = jnp.asarray(x)
            if x.ndim >= 2 and x.shape[1] == 1:     # (L, 1, ...) layer caches
                return jnp.repeat(x, batch, axis=1)
            if x.ndim >= 1 and x.shape[0] == 1:     # (1, lc) slot_pos
                return jnp.repeat(x, batch, axis=0)
            return x
        cache = {k: (rep(v) if k not in ("idx",) else v)
                 for k, v in cache1.items()}
        return cache, off, real_len, (0 if hit else len(ids)), hit

    # ------------------------------- generate ---------------------------------
    def generate(self, prompts: Sequence[str], *,
                 grammar: Optional[JsonGrammar] = None,
                 grammars: Optional[List[JsonGrammar]] = None,
                 max_new_tokens: int = 256, temperature: float = 0.0,
                 shared_prefix: str = "") -> GenResult:
        """Generate for a batch of prompts. If shared_prefix is given it is
        prefilled once and KV-reused across rows (prompts are then the
        suffixes). Grammar-constrained when grammar(s) provided."""
        t0 = time.time()
        stats = GenStats(calls=1)
        B = len(prompts)
        gs = grammars or ([grammar] * B if grammar else [None] * B)
        states = [g.init_state() if g else None for g in gs]

        offset = 0
        pos_offset = None
        cache = None
        if shared_prefix:
            cache, offset, pos_offset, new_prefix_toks, hit = \
                self.prefix_cache_for(shared_prefix, B)
            stats.prefill_tokens += new_prefix_toks
            stats.prefix_hits += int(hit)
            stats.input_tokens += TOK.count_tokens(shared_prefix)

        token_lists = [TOK.encode(p, bos=not shared_prefix) for p in prompts]
        stats.input_tokens += sum(len(t) for t in token_lists)
        logits, cache, lens, pre = self._prefill(
            token_lists, offset=offset, pos_offset=pos_offset,
            cache=cache, row_idx_mode=True)
        stats.prefill_tokens += pre

        decode = self._decode_fn()
        out_tokens: List[List[int]] = [[] for _ in range(B)]
        done = np.zeros(B, bool)
        positions = lens.copy()

        for step in range(max_new_tokens):
            toks = self._sample(logits, gs, states, temperature)
            for i in range(B):
                if done[i]:
                    continue
                t = int(toks[i])
                if gs[i] is not None:
                    states[i] = gs[i].advance(states[i], t)
                    if t != TOK.EOS_ID:
                        out_tokens[i].append(t)
                    if gs[i].done(states[i]):
                        done[i] = True
                else:
                    if t == TOK.EOS_ID:
                        done[i] = True
                    else:
                        out_tokens[i].append(t)
            stats.decode_steps += 1
            stats.output_tokens += int((~done).sum())
            if done.all():
                break
            lg, cache = decode(self.params, jnp.asarray(toks[:, None]),
                               jnp.asarray(positions[:, None]), cache)
            logits = np.asarray(lg, np.float32)
            positions += 1

        stats.wall_s = time.time() - t0
        return GenResult([TOK.decode(t) for t in out_tokens], stats)

    # ------------------------------- sampling ---------------------------------
    def _sample(self, logits: np.ndarray, gs, states, temperature: float
                ) -> np.ndarray:
        B, V = logits.shape
        mask = np.ones((B, V), np.int8)
        for i, (g, st) in enumerate(zip(gs, states)):
            if g is not None:
                m = g.mask(st)
                mask[i, :] = 0
                mask[i, :len(m)] = m
        noise = None
        if temperature > 0:
            u = self._rng.uniform(1e-9, 1.0, size=(B, V))
            noise = -np.log(-np.log(u))
        if self.use_pallas_sampler:
            from repro.kernels import ops as KOPS
            return np.asarray(KOPS.constrained_sample(
                jnp.asarray(logits), jnp.asarray(mask),
                None if noise is None else jnp.asarray(noise),
                temperature=max(temperature, 1e-6) if temperature > 0 else 1.0,
                block_v=256, interpret=True))
        x = logits / (temperature if temperature > 0 else 1.0)
        if noise is not None:
            x = x + noise
        x = np.where(mask != 0, x, NEG_INF)
        return np.argmax(x, axis=-1).astype(np.int32)
