"""Refcounted radix (prefix) tree over token sequences owning page-granular
KV cache nodes.

Each node owns a span of *full* pages: ``key`` is a tuple of token ids whose
length is a multiple of the page size, and ``pages`` holds one page id per
``page_size`` tokens of the key.  Children are keyed by the first-page token
chunk of their key, which is sufficient because two children of the same node
must already differ somewhere within their first page (splits happen at page
granularity).

The tree holds exactly one allocator reference per owned page.  ``match``
retains every returned page on behalf of the caller (who must release them),
so a matched prefix can never be evicted or reallocated while a request is
prefilling/decoding against it.  ``insert`` adopts (retains) pages only for
nodes it actually creates and reports the adopted page ids back to the caller
so commit-time bookkeeping (e.g. quantize-on-commit) only touches pages that
are genuinely frozen into the tree.

Eviction is LRU over leaf nodes and never drops a node whose pages have live
outside readers (allocator refcount > 1, i.e. anything beyond the tree's own
reference).
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple


class RadixNode:
    __slots__ = ("key", "pages", "children", "parent", "last_used")

    def __init__(self, key: Tuple[int, ...], pages: List[int],
                 parent: Optional["RadixNode"]):
        self.key = key          # token span owned by this node (len % ps == 0)
        self.pages = pages      # one page id per page_size tokens of key
        self.children: Dict[Tuple[int, ...], RadixNode] = {}
        self.parent = parent
        self.last_used = 0


class RadixPrefixCache:
    """Page-granular radix tree over token ids, backed by a PageAllocator.

    The allocator only needs three methods: ``retain(ids)``, ``release(ids)``
    and ``refs(page_id)``.
    """

    def __init__(self, allocator, page_size: int):
        self._alloc = allocator
        self.page_size = int(page_size)
        self._root = RadixNode((), [], None)
        self._clock = itertools.count(1)
        # stats
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.inserted_tokens = 0
        self.evicted_pages = 0

    # ------------------------------------------------------------------ util
    def _chunk(self, key: Sequence[int]) -> Tuple[int, ...]:
        return tuple(key[: self.page_size])

    def _touch(self, node: RadixNode) -> None:
        t = next(self._clock)
        while node is not None:
            node.last_used = t
            node = node.parent

    @property
    def num_nodes(self) -> int:
        n = 0
        stack = [self._root]
        while stack:
            nd = stack.pop()
            n += 1
            stack.extend(nd.children.values())
        return n - 1  # exclude root

    @property
    def resident_pages(self) -> int:
        return len(self.resident_page_ids())

    def resident_page_ids(self) -> List[int]:
        out: List[int] = []
        stack = [self._root]
        while stack:
            nd = stack.pop()
            out.extend(nd.pages)
            stack.extend(nd.children.values())
        return out

    # ----------------------------------------------------------------- match
    def match(self, tokens: Sequence[int],
              limit: Optional[int] = None) -> Tuple[List[int], int]:
        """Longest page-aligned prefix of ``tokens`` present in the tree.

        Returns ``(pages, n_match)`` where ``pages`` are retained on behalf of
        the caller (caller must release).  The match is capped at
        ``((len(tokens) - 1) // page_size) * page_size`` so the caller always
        has at least one suffix token to prefill (last-token logits); an
        explicit ``limit`` (token count, floored to page alignment) overrides
        that default — callers use it when the suffix-token guarantee comes
        from context beyond ``tokens`` itself.
        """
        ps = self.page_size
        self.lookups += 1
        if limit is None:
            cap = max(0, (len(tokens) - 1) // ps) * ps
        else:
            cap = min(max(0, limit), len(tokens)) // ps * ps
        pages: List[int] = []
        node = self._root
        off = 0
        while off < cap:
            child = node.children.get(self._chunk(tokens[off:]))
            if child is None:
                break
            klen = len(child.key)
            if off + klen > cap or tuple(tokens[off:off + klen]) != child.key:
                # partial match inside this node's span
                n_ok = 0
                limit = min(klen, cap - off)
                for i in range(0, limit, ps):
                    if tuple(tokens[off + i:off + i + ps]) != child.key[i:i + ps]:
                        break
                    n_ok += ps
                if n_ok:
                    pages.extend(child.pages[: n_ok // ps])
                    self._touch(child)
                    off += n_ok
                break
            pages.extend(child.pages)
            off += klen
            node = child
            self._touch(node)
        if pages:
            self._alloc.retain(pages)
            self.hits += 1
            self.hit_tokens += off
        return pages, off

    # ---------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> List[int]:
        """Insert a fully page-aligned token span with its backing pages.

        ``len(tokens)`` must be a multiple of ``page_size`` and ``pages`` must
        hold exactly one page id per page.  Pages belonging to *newly created*
        nodes are retained (adopted) by the tree; page ids already present in
        the tree along this path are ignored.  Returns the list of page ids
        the tree adopted (useful for quantize-on-commit).
        """
        ps = self.page_size
        assert len(tokens) % ps == 0
        assert len(pages) == len(tokens) // ps
        adopted: List[int] = []
        node = self._root
        off = 0
        n = len(tokens)
        while off < n:
            chunk = self._chunk(tokens[off:])
            child = node.children.get(chunk)
            if child is None:
                key = tuple(tokens[off:])
                new_pages = list(pages[off // ps:])
                nd = RadixNode(key, new_pages, node)
                node.children[chunk] = nd
                self._alloc.retain(new_pages)
                adopted.extend(new_pages)
                self.inserted_tokens += len(key)
                self._touch(nd)
                return adopted
            klen = len(child.key)
            # common page-aligned prefix between child.key and tokens[off:]
            n_ok = 0
            limit = min(klen, n - off)
            for i in range(0, limit, ps):
                if tuple(tokens[off + i:off + i + ps]) != child.key[i:i + ps]:
                    break
                n_ok += ps
            if n_ok == klen:
                node = child
                off += klen
                self._touch(node)
                continue
            # split child at n_ok (> 0 since first chunk matched)
            self._split(node, child, n_ok)
            node = node.children[chunk]   # top half of the split
            off += n_ok
            self._touch(node)
        return adopted

    def _split(self, parent: RadixNode, child: RadixNode, at: int) -> None:
        """Split ``child`` so its first ``at`` tokens become a new top node."""
        ps = self.page_size
        top = RadixNode(child.key[:at], child.pages[: at // ps], parent)
        parent.children[self._chunk(child.key)] = top
        child.key = child.key[at:]
        child.pages = child.pages[at // ps:]
        child.parent = top
        top.children[self._chunk(child.key)] = child
        top.last_used = child.last_used

    # ----------------------------------------------------------------- evict
    def evict(self, need_pages: int) -> int:
        """Release up to ``need_pages`` pages by dropping LRU leaf nodes.

        Only drops leaves whose pages all have allocator refcount == 1 (the
        tree's own reference) — a node with live readers is never evicted.
        Returns the number of pages actually released.
        """
        freed = 0
        while freed < need_pages:
            victim = None
            stack = [self._root]
            while stack:
                nd = stack.pop()
                for c in nd.children.values():
                    if c.children:
                        stack.append(c)
                        continue
                    if any(self._alloc.refs(p) != 1 for p in c.pages):
                        continue
                    if victim is None or c.last_used < victim.last_used:
                        victim = c
            if victim is None:
                break
            parent = victim.parent
            del parent.children[self._chunk(victim.key)]
            self._alloc.release(victim.pages)
            freed += len(victim.pages)
            self.evicted_pages += len(victim.pages)
            # collapse chains: if parent became a pass-through with one child
            # we leave it (harmless); but drop empty non-root parents with no
            # pages of their own — cannot happen since every node owns >= 1
            # page, except the root.
        return freed

    def clear(self) -> None:
        """Release every page owned by the tree and reset it."""
        ids = self.resident_page_ids()
        if ids:
            self._alloc.release(ids)
        self._root = RadixNode((), [], None)
