"""Slot-based continuous batching on top of InferenceEngine.

A fixed decode batch of `num_slots` sequences runs lock-step decode ticks;
finished slots are immediately refilled by prefilling queued requests into
the slot's cache rows (per-row cache indices make ragged fill levels safe).
This is the serving analog of the paper's §6.3 parallel-call executor: the
"worker pool" is the decode batch, and slot eviction doubles as straggler
mitigation (a request exceeding its token budget is cut off and re-queued
or failed without stalling the batch).

Two KV layouts (engine.kv_layout):
  * dense — each slot owns a contiguous max_len cache row; memory is
    num_slots × max_len regardless of fill, and a shared instruction
    prefix is prefilled again for every slot.
  * paged — slots own block tables over the engine's global page pool;
    refill allocates pages, completion/eviction frees them (so num_slots
    is bounded by page-pool memory, not dense worst-case rows), and a
    shared prefix is prefilled ONCE into pool pages that every slot's
    table references zero-copy.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL
from repro.serving import tokenizer as TOK
from repro.serving.engine import GenStats, InferenceEngine, NEG_INF
from repro.serving.grammar import JsonGrammar


@dataclasses.dataclass
class Request:
    prompt: str
    grammar: Optional[JsonGrammar] = None
    max_new_tokens: int = 256
    n_samples: int = 1          # >1 ⇒ self-consistency: decode n streams,
    rid: int = -1               #      majority-vote the final text
    # filled on completion:
    text: Optional[str] = None
    error: Optional[str] = None
    samples: Optional[List[str]] = None   # per-stream texts when n_samples>1


class _Job:
    """One decode stream: a (request, sample-index) pair.

    Duck-types the Request fields the slot machinery reads (prompt, grammar,
    max_new_tokens) but carries its own text/error so n_samples streams of
    one request complete independently.  ``group`` ties sibling streams to a
    shared-prefill fork snapshot in the paged layout."""
    __slots__ = ("req", "sample", "group", "rid", "text", "error")

    def __init__(self, req: Request, sample: int,
                 group: Optional["_ForkGroup"] = None):
        self.req = req
        self.sample = sample
        self.group = group
        self.rid = req.rid
        self.text: Optional[str] = None
        self.error: Optional[str] = None

    @property
    def prompt(self) -> str:
        return self.req.prompt

    @property
    def grammar(self) -> Optional[JsonGrammar]:
        return self.req.grammar

    @property
    def max_new_tokens(self) -> int:
        return self.req.max_new_tokens


class _ForkGroup:
    """Copy-on-write fork point for one request's n_samples streams (paged
    layout).  The first stream to fill a slot prefills normally; right after
    its prefill we snapshot the block-table row, position, last-token logits
    and SSM state, and retain every page covering the prompt.  Sibling
    streams then "fork": they reference the same shared pages zero-copy and
    only allocate fresh pages for their own decode capacity — no prefill.
    Shared pages privatize lazily via the decode-loop COW guard on first
    write (which covers the sub-page tail every stream writes into)."""

    def __init__(self, fills_left: int):
        self.fills_left = fills_left   # siblings still waiting to fork
        self.snapshot: Optional[dict] = None
        self.retained: List[int] = []  # group's own leases on shared pages

    def snap(self, eng, row: np.ndarray, pos: int, logits_row: np.ndarray,
             extra_slice: Optional[dict]) -> None:
        nsh = -(-int(pos) // eng.page_size)      # pages covering the prompt
        shared = [int(p) for p in row[:nsh] if p >= 0]
        eng.retain_pages(shared)
        self.retained = shared
        self.snapshot = {"row": row[:nsh].copy(), "nsh": nsh, "pos": int(pos),
                         "logits": logits_row.copy(), "extra": extra_slice}

    def done_fill(self, eng) -> None:
        self.fills_left -= 1
        if self.fills_left <= 0:
            self.release(eng)

    def release(self, eng) -> None:
        if self.retained:
            eng.release_pages(self.retained)
            self.retained = []


def _vote(texts: Sequence[str]) -> str:
    """Majority text; ties break toward the earliest stream (Counter's
    most_common is insertion-stable)."""
    return Counter(texts).most_common(1)[0][0]


class ContinuousBatcher:
    def __init__(self, engine: InferenceEngine, num_slots: int = 8):
        self.engine = engine
        self.num_slots = num_slots
        self.stats = GenStats()

    def run(self, requests: Sequence[Request], *, temperature: float = 0.0,
            shared_prefix: str = "") -> List[Request]:
        """Process all requests to completion; returns them (order kept).
        `shared_prefix` is prepended to every prompt: the dense layout
        prefills it per slot (replication — the old behavior), the paged
        layout prefills it once into shared pool pages."""
        st = GenStats(calls=1)
        t0 = time.time()
        reqs = list(requests)
        paged = self.engine.kv_layout == "paged"
        jobs: List[_Job] = []
        for i, r in enumerate(reqs):
            r.rid = i
            ns = max(1, r.n_samples)
            grp = _ForkGroup(ns - 1) if (ns > 1 and paged) else None
            jobs.extend(_Job(r, k, grp) for k in range(ns))
        if paged:
            self._run_paged(jobs, temperature, shared_prefix, st)
        else:
            self._run_dense(jobs, temperature, shared_prefix, st)
        self._reduce(reqs, jobs)
        st.wall_s = time.time() - t0
        self.stats.add(st)
        self.engine.total.add(st)
        return reqs

    @staticmethod
    def _reduce(reqs: List[Request], jobs: List[_Job]) -> None:
        """Fold per-stream results back onto their requests: single-stream
        requests copy through; multi-sample requests keep every stream in
        `samples` and majority-vote the final text (self-consistency)."""
        by_req: Dict[int, List[_Job]] = {}
        for j in jobs:
            by_req.setdefault(j.rid, []).append(j)
        for r in reqs:
            js = sorted(by_req[r.rid], key=lambda j: j.sample)
            if len(js) == 1:
                r.text, r.error = js[0].text, js[0].error
                continue
            r.samples = [j.text for j in js]
            ok = [j.text for j in js if j.error is None and j.text is not None]
            if ok:
                r.text = _vote(ok)
                r.error = None
            else:
                r.text, r.error = js[0].text, js[0].error

    # ---------------------------- per-tick advance ----------------------------
    @staticmethod
    def _advance_live(live, active, states, outs, budgets, toks, st, logits,
                      on_finish) -> int:
        """Consume one sampled token per live slot: grammar advance, EOS,
        token-budget eviction, completion. Shared by both layouts so their
        tick semantics (and the pinned byte-equality) cannot drift.
        Returns the number of slots that finished."""
        done = 0
        for b in live:
            r = active[b]
            t = int(toks[b])
            if r.grammar is not None:
                states[b] = r.grammar.advance(states[b], t)
                if t != TOK.EOS_ID:
                    outs[b].append(t)
                finished = r.grammar.done(states[b])
            else:
                finished = t == TOK.EOS_ID
                if not finished:
                    outs[b].append(t)
            budgets[b] -= 1
            st.output_tokens += 1
            if budgets[b] <= 0 and not finished:
                r.error = "token budget exceeded (slot evicted)"
                finished = True
            if finished:
                r.text = TOK.decode(outs[b])
                active[b] = None
                done += 1
                logits[b] = NEG_INF
                on_finish(b)
        return done

    # ------------------------------- dense ------------------------------------
    def _run_dense(self, reqs: List[_Job], temperature: float,
                   shared_prefix: str, st: GenStats) -> None:
        eng = self.engine
        queue = list(reqs)
        B = self.num_slots

        cache = MDL.init_cache(eng.cfg, B, eng.max_len)
        cache["row_idx"] = jnp.zeros((B,), jnp.int32)
        st.kv_bytes = eng._dense_cache_bytes(cache)
        active: List[Optional[Request]] = [None] * B
        states = [None] * B
        outs: List[List[int]] = [[] for _ in range(B)]
        budgets = np.zeros(B, np.int64)
        positions = np.zeros(B, np.int32)
        logits = np.full((B, eng.cfg.padded_vocab), NEG_INF, np.float32)

        def fill_slot(b: int, req: Request, cache):
            ids = TOK.encode(shared_prefix + req.prompt)
            lg, c1, lens, pre = eng._prefill([ids], row_idx_mode=True)
            st.prefill_tokens += pre
            st.input_tokens += len(ids)
            # splice sequence 0 of c1 into slot b of the live cache
            new = dict(cache)
            for k, v in c1.items():
                if k == "idx":
                    continue
                tgt = jnp.asarray(cache[k])
                src = jnp.asarray(v)
                if k in ("k", "v", "conv", "h"):          # (L, B, ...)
                    new[k] = tgt.at[:, b].set(src[:, 0])
                elif k in ("slot_pos", "row_idx"):        # (B, ...)
                    new[k] = tgt.at[b].set(src[0])
            active[b] = req
            states[b] = req.grammar.init_state() if req.grammar else None
            outs[b] = []
            budgets[b] = req.max_new_tokens
            positions[b] = lens[0]
            logits[b] = lg[0][:logits.shape[1]]
            return new

        decode = eng._decode_fn()
        done_count = 0
        ticks = 0
        while done_count < len(reqs):
            # refill free slots
            for b in range(B):
                if active[b] is None and queue:
                    cache = fill_slot(b, queue.pop(0), cache)
            live = [b for b in range(B) if active[b] is not None]
            if not live:
                break

            gs = [active[b].grammar if active[b] else None for b in range(B)]
            toks = eng._sample(logits, gs, states, temperature)
            done_count += self._advance_live(live, active, states, outs,
                                             budgets, toks, st, logits,
                                             lambda b: None)

            if done_count >= len(reqs):
                break
            if not any(a is not None for a in active):
                continue           # all finished this tick; refill next
            lg, cache = decode(eng.params, jnp.asarray(toks[:, None]),
                               jnp.asarray(positions[:, None]), cache)
            lgn = np.asarray(lg, np.float32)
            for b in range(B):
                if active[b] is not None:
                    logits[b] = lgn[b]
            positions += 1
            ticks += 1

        st.decode_steps += ticks

    # ------------------------------- paged ------------------------------------
    def _run_paged(self, reqs: List[_Job], temperature: float,
                   shared_prefix: str, st: GenStats) -> None:
        eng = self.engine
        ps = eng.page_size
        NBf = eng.num_table_blocks
        cap = NBf * ps
        B = self.num_slots
        queue = list(reqs)
        radix = eng.prefix_cache_mode == "radix"
        groups = {id(j.group): j.group for j in reqs if j.group is not None}

        pages_pre: List[int] = []
        n_share = 0
        tail: List[int] = []
        if shared_prefix and not radix:
            # exact mode: resolve the prefix once up front.  radix mode
            # skips this — the first fill commits the prefix pages to the
            # tree and every later fill discovers them at match time.
            pages_pre, n_share, tail = eng.prefix_pages_for(shared_prefix, st)
            if pages_pre:
                eng.retain_pages(pages_pre)
        npre = len(pages_pre)

        table = np.full((B, NBf), -1, np.int32)
        slot_pages: List[List[int]] = [[] for _ in range(B)]   # owned (alloc)
        slot_shared: List[List[int]] = [[] for _ in range(B)]  # leased (retain)
        active: List[Optional[_Job]] = [None] * B
        states = [None] * B
        outs: List[List[int]] = [[] for _ in range(B)]
        budgets = np.zeros(B, np.int64)
        positions = np.zeros(B, np.int32)
        logits = np.full((B, eng.cfg.padded_vocab), NEG_INF, np.float32)
        extra = eng._ssm_state(B) or None

        def place(b: int, job: _Job, pos: int, lg_row: np.ndarray) -> None:
            active[b] = job
            states[b] = job.grammar.init_state() if job.grammar else None
            outs[b] = []
            budgets[b] = job.max_new_tokens
            positions[b] = pos
            logits[b] = lg_row[: logits.shape[1]]

        def fill_fork(b: int, job: _Job, grp: _ForkGroup) -> bool:
            """Fork a sibling stream off the group snapshot: share every
            page covering the prompt zero-copy, allocate only fresh decode
            capacity, skip prefill entirely."""
            nonlocal extra
            sn = grp.snapshot
            nsh, pos = sn["nsh"], sn["pos"]
            tot = min(pos + job.max_new_tokens, cap)
            need = max(0, -(-tot // ps) - nsh)
            if not eng._ensure_pool(need):
                return False
            pg = eng.alloc_pages(need)
            shared = [int(p) for p in sn["row"] if p >= 0]
            eng.retain_pages(shared)
            slot_pages[b] = pg
            slot_shared[b] = shared
            table[b, :nsh] = sn["row"]
            table[b, nsh:nsh + need] = pg
            table[b, nsh + need:] = -1
            if extra and sn["extra"]:
                extra = {k: extra[k].at[:, b:b + 1].set(sn["extra"][k])
                         for k in extra}
            st.input_tokens += pos
            place(b, job, pos, sn["logits"])
            grp.done_fill(eng)
            return True

        def fill_slot(b: int, job: _Job) -> bool:
            """Allocate pages + prefill the slot. False ⇒ the (pinned) pool
            cannot take the request right now — it stays queued until other
            slots free pages."""
            nonlocal extra
            grp = job.group
            if grp is not None and grp.snapshot is not None:
                return fill_fork(b, job, grp)
            if radix:
                ids = TOK.encode(shared_prefix + job.prompt)
                pre_pages, pre_len = eng.radix_match(ids, st)
                suffix = ids[pre_len:]
            else:
                ids = tail + TOK.encode(job.prompt, bos=not shared_prefix)
                pre_pages, pre_len = pages_pre, n_share
                suffix = ids
            nfixed = len(pre_pages)
            tot = min(pre_len + len(suffix) + job.max_new_tokens, cap)
            need = max(0, -(-tot // ps) - nfixed)
            if not eng._ensure_pool(need):
                if radix and pre_pages:
                    eng.release_pages(pre_pages)
                return False
            pg = eng.alloc_pages(need)
            slot_pages[b] = pg
            if radix:
                slot_shared[b] = pre_pages
            if nfixed:
                table[b, :nfixed] = pre_pages
            table[b, nfixed:nfixed + need] = pg
            table[b, nfixed + need:] = -1
            slot_extra = {k: v[:, b:b + 1] for k, v in (extra or {}).items()} \
                or None
            lg, lens, pre, ex1 = eng.paged_prefill(
                [suffix], table[b:b + 1], pre_pages, pre_len,
                extra=slot_extra)
            if extra:
                extra = {k: extra[k].at[:, b:b + 1].set(ex1[k])
                         for k in extra}
            if radix:
                # commit the full-page span of the prompt so later fills
                # (and later runs) reuse it at match time
                nfull = min(len(ids) // ps, nfixed + need)
                if nfull > pre_len // ps:
                    eng.radix_insert(ids[: nfull * ps],
                                     [int(p) for p in table[b, :nfull]])
            st.prefill_tokens += pre
            st.input_tokens += pre_len + len(suffix)
            place(b, job, int(lens[0]), lg[0])
            if grp is not None:
                sl = {k: extra[k][:, b:b + 1] for k in (extra or {})} or None
                grp.snap(eng, table[b], positions[b], logits[b], sl)
            return True

        def free_slot(b: int) -> None:
            eng.release_pages(slot_pages[b])
            slot_pages[b] = []
            if slot_shared[b]:
                eng.release_pages(slot_shared[b])
                slot_shared[b] = []
            table[b, :] = -1           # dead rows must never write pages

        def cow_guard(live: List[int]) -> None:
            """Privatize this tick's write page for any slot that shares it
            (refcount > 1): fork streams share the sub-page prompt tail, so
            the first decode write of each stream must land on a private
            copy.  Batched into one device copy per tick."""
            srcs: List[int] = []
            dsts: List[int] = []
            for b in live:
                w = int(positions[b]) // ps
                if w >= NBf:
                    continue
                pgid = int(table[b, w])
                if pgid < 0 or eng._alloc.refs(pgid) <= 1:
                    continue
                if not eng._ensure_pool(1):
                    raise RuntimeError(
                        "page pool exhausted during copy-on-write")
                new = eng.alloc_pages(1)[0]
                srcs.append(pgid)
                dsts.append(new)
                table[b, w] = new
                slot_pages[b].append(new)
                # the lease on the old page stays in slot_shared/slot_pages
                # and is released at free_slot — release here would race
                # siblings still reading it
            if srcs:
                eng.copy_pages(srcs, dsts)
                st.cow_copies += len(srcs)

        done_count = 0
        ticks = 0
        try:
            while done_count < len(reqs):
                stalled = False
                for b in range(B):
                    if active[b] is None and queue and not stalled:
                        if fill_slot(b, queue[0]):
                            queue.pop(0)
                        else:
                            stalled = True
                live = [b for b in range(B) if active[b] is not None]
                if not live:
                    if queue:
                        raise RuntimeError(
                            f"page pool ({eng.page_pool_pages} pages) too "
                            f"small for even one request")
                    break

                gs = [active[b].grammar if active[b] else None
                      for b in range(B)]
                toks = eng._sample(logits, gs, states, temperature)
                done_count += self._advance_live(live, active, states, outs,
                                                 budgets, toks, st, logits,
                                                 free_slot)

                if done_count >= len(reqs):
                    break
                live = [b for b in range(B) if active[b] is not None]
                if not live:
                    continue           # all finished this tick; refill next
                cow_guard(live)
                nb = eng.active_blocks(positions[live])
                lgn, extra_out = eng.paged_decode(toks, positions, table, nb,
                                                  extra=extra)
                if extra:
                    extra = extra_out
                for b in range(B):
                    if active[b] is not None:
                        logits[b] = lgn[b]
                positions += 1
                ticks += 1
        finally:
            # errors must not leak slot pages, fork-group leases, or the
            # prefix retain: a pinned pool would shrink permanently
            for b in range(B):
                if slot_pages[b]:
                    eng.release_pages(slot_pages[b])
                    slot_pages[b] = []
                if slot_shared[b]:
                    eng.release_pages(slot_shared[b])
                    slot_shared[b] = []
            for g in groups.values():
                g.release(eng)
            if pages_pre:
                eng.release_pages(pages_pre)
        st.decode_steps += ticks
        eng._note_kv()
        st.kv_bytes = eng.kv_peak_bytes
