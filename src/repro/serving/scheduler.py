"""Slot-based continuous batching on top of InferenceEngine.

A fixed decode batch of `num_slots` sequences runs lock-step decode ticks;
finished slots are immediately refilled by prefilling queued requests into
the slot's cache rows (per-row cache indices make ragged fill levels safe).
This is the serving analog of the paper's §6.3 parallel-call executor: the
"worker pool" is the decode batch, and slot eviction doubles as straggler
mitigation (a request exceeding its token budget is cut off and re-queued
or failed without stalling the batch).

Two KV layouts (engine.kv_layout):
  * dense — each slot owns a contiguous max_len cache row; memory is
    num_slots × max_len regardless of fill, and a shared instruction
    prefix is prefilled again for every slot.
  * paged — slots own block tables over the engine's global page pool;
    refill allocates pages, completion/eviction frees them (so num_slots
    is bounded by page-pool memory, not dense worst-case rows), and a
    shared prefix is prefilled ONCE into pool pages that every slot's
    table references zero-copy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL
from repro.serving import tokenizer as TOK
from repro.serving.engine import GenStats, InferenceEngine, NEG_INF
from repro.serving.grammar import JsonGrammar


@dataclasses.dataclass
class Request:
    prompt: str
    grammar: Optional[JsonGrammar] = None
    max_new_tokens: int = 256
    rid: int = -1
    # filled on completion:
    text: Optional[str] = None
    error: Optional[str] = None


class ContinuousBatcher:
    def __init__(self, engine: InferenceEngine, num_slots: int = 8):
        self.engine = engine
        self.num_slots = num_slots
        self.stats = GenStats()

    def run(self, requests: Sequence[Request], *, temperature: float = 0.0,
            shared_prefix: str = "") -> List[Request]:
        """Process all requests to completion; returns them (order kept).
        `shared_prefix` is prepended to every prompt: the dense layout
        prefills it per slot (replication — the old behavior), the paged
        layout prefills it once into shared pool pages."""
        st = GenStats(calls=1)
        t0 = time.time()
        reqs = list(requests)
        for i, r in enumerate(reqs):
            r.rid = i
        if self.engine.kv_layout == "paged":
            self._run_paged(reqs, temperature, shared_prefix, st)
        else:
            self._run_dense(reqs, temperature, shared_prefix, st)
        st.wall_s = time.time() - t0
        self.stats.add(st)
        self.engine.total.add(st)
        return reqs

    # ---------------------------- per-tick advance ----------------------------
    @staticmethod
    def _advance_live(live, active, states, outs, budgets, toks, st, logits,
                      on_finish) -> int:
        """Consume one sampled token per live slot: grammar advance, EOS,
        token-budget eviction, completion. Shared by both layouts so their
        tick semantics (and the pinned byte-equality) cannot drift.
        Returns the number of slots that finished."""
        done = 0
        for b in live:
            r = active[b]
            t = int(toks[b])
            if r.grammar is not None:
                states[b] = r.grammar.advance(states[b], t)
                if t != TOK.EOS_ID:
                    outs[b].append(t)
                finished = r.grammar.done(states[b])
            else:
                finished = t == TOK.EOS_ID
                if not finished:
                    outs[b].append(t)
            budgets[b] -= 1
            st.output_tokens += 1
            if budgets[b] <= 0 and not finished:
                r.error = "token budget exceeded (slot evicted)"
                finished = True
            if finished:
                r.text = TOK.decode(outs[b])
                active[b] = None
                done += 1
                logits[b] = NEG_INF
                on_finish(b)
        return done

    # ------------------------------- dense ------------------------------------
    def _run_dense(self, reqs: List[Request], temperature: float,
                   shared_prefix: str, st: GenStats) -> None:
        eng = self.engine
        queue = list(reqs)
        B = self.num_slots

        cache = MDL.init_cache(eng.cfg, B, eng.max_len)
        cache["row_idx"] = jnp.zeros((B,), jnp.int32)
        st.kv_bytes = eng._dense_cache_bytes(cache)
        active: List[Optional[Request]] = [None] * B
        states = [None] * B
        outs: List[List[int]] = [[] for _ in range(B)]
        budgets = np.zeros(B, np.int64)
        positions = np.zeros(B, np.int32)
        logits = np.full((B, eng.cfg.padded_vocab), NEG_INF, np.float32)

        def fill_slot(b: int, req: Request, cache):
            ids = TOK.encode(shared_prefix + req.prompt)
            lg, c1, lens, pre = eng._prefill([ids], row_idx_mode=True)
            st.prefill_tokens += pre
            st.input_tokens += len(ids)
            # splice sequence 0 of c1 into slot b of the live cache
            new = dict(cache)
            for k, v in c1.items():
                if k == "idx":
                    continue
                tgt = jnp.asarray(cache[k])
                src = jnp.asarray(v)
                if k in ("k", "v", "conv", "h"):          # (L, B, ...)
                    new[k] = tgt.at[:, b].set(src[:, 0])
                elif k in ("slot_pos", "row_idx"):        # (B, ...)
                    new[k] = tgt.at[b].set(src[0])
            active[b] = req
            states[b] = req.grammar.init_state() if req.grammar else None
            outs[b] = []
            budgets[b] = req.max_new_tokens
            positions[b] = lens[0]
            logits[b] = lg[0][:logits.shape[1]]
            return new

        decode = eng._decode_fn()
        done_count = 0
        ticks = 0
        while done_count < len(reqs):
            # refill free slots
            for b in range(B):
                if active[b] is None and queue:
                    cache = fill_slot(b, queue.pop(0), cache)
            live = [b for b in range(B) if active[b] is not None]
            if not live:
                break

            gs = [active[b].grammar if active[b] else None for b in range(B)]
            toks = eng._sample(logits, gs, states, temperature)
            done_count += self._advance_live(live, active, states, outs,
                                             budgets, toks, st, logits,
                                             lambda b: None)

            if done_count >= len(reqs):
                break
            if not any(a is not None for a in active):
                continue           # all finished this tick; refill next
            lg, cache = decode(eng.params, jnp.asarray(toks[:, None]),
                               jnp.asarray(positions[:, None]), cache)
            lgn = np.asarray(lg, np.float32)
            for b in range(B):
                if active[b] is not None:
                    logits[b] = lgn[b]
            positions += 1
            ticks += 1

        st.decode_steps += ticks

    # ------------------------------- paged ------------------------------------
    def _run_paged(self, reqs: List[Request], temperature: float,
                   shared_prefix: str, st: GenStats) -> None:
        eng = self.engine
        ps = eng.page_size
        NBf = eng.num_table_blocks
        cap = NBf * ps
        B = self.num_slots
        queue = list(reqs)

        pages_pre: List[int] = []
        n_share = 0
        tail: List[int] = []
        if shared_prefix:
            pages_pre, n_share, tail = eng.prefix_pages_for(shared_prefix, st)
            if pages_pre:
                eng._alloc.retain(pages_pre)
        npre = len(pages_pre)

        table = np.full((B, NBf), -1, np.int32)
        slot_pages: List[List[int]] = [[] for _ in range(B)]
        active: List[Optional[Request]] = [None] * B
        states = [None] * B
        outs: List[List[int]] = [[] for _ in range(B)]
        budgets = np.zeros(B, np.int64)
        positions = np.zeros(B, np.int32)
        logits = np.full((B, eng.cfg.padded_vocab), NEG_INF, np.float32)
        extra = eng._ssm_state(B) or None

        def fill_slot(b: int, req: Request) -> bool:
            """Allocate pages + prefill the slot. False ⇒ the (pinned) pool
            cannot take the request right now — it stays queued until other
            slots free pages."""
            nonlocal extra
            ids = tail + TOK.encode(req.prompt, bos=not shared_prefix)
            tot = min(n_share + len(ids) + req.max_new_tokens, cap)
            need = max(0, -(-tot // ps) - npre)
            if not eng._ensure_pool(need):
                return False
            pg = eng._alloc.alloc(need)
            slot_pages[b] = pg
            if npre:
                table[b, :npre] = pages_pre
            table[b, npre:npre + need] = pg
            table[b, npre + need:] = -1
            slot_extra = {k: v[:, b:b + 1] for k, v in (extra or {}).items()} \
                or None
            lg, lens, pre, ex1 = eng.paged_prefill(
                [ids], table[b:b + 1], pages_pre, n_share, extra=slot_extra)
            if extra:
                extra = {k: extra[k].at[:, b:b + 1].set(ex1[k])
                         for k in extra}
            st.prefill_tokens += pre
            st.input_tokens += n_share + len(ids)
            active[b] = req
            states[b] = req.grammar.init_state() if req.grammar else None
            outs[b] = []
            budgets[b] = req.max_new_tokens
            positions[b] = lens[0]
            logits[b] = lg[0][:logits.shape[1]]
            return True

        def free_slot(b: int) -> None:
            eng._alloc.release(slot_pages[b])
            slot_pages[b] = []
            table[b, :] = -1           # dead rows must never write pages

        done_count = 0
        ticks = 0
        try:
            while done_count < len(reqs):
                stalled = False
                for b in range(B):
                    if active[b] is None and queue and not stalled:
                        if fill_slot(b, queue[0]):
                            queue.pop(0)
                        else:
                            stalled = True
                live = [b for b in range(B) if active[b] is not None]
                if not live:
                    if queue:
                        raise RuntimeError(
                            f"page pool ({eng.page_pool_pages} pages) too "
                            f"small for even one request")
                    break

                gs = [active[b].grammar if active[b] else None
                      for b in range(B)]
                toks = eng._sample(logits, gs, states, temperature)
                done_count += self._advance_live(live, active, states, outs,
                                                 budgets, toks, st, logits,
                                                 free_slot)

                if done_count >= len(reqs):
                    break
                live = [b for b in range(B) if active[b] is not None]
                if not live:
                    continue           # all finished this tick; refill next
                nb = eng.active_blocks(positions[live])
                lgn, extra_out = eng.paged_decode(toks, positions, table, nb,
                                                  extra=extra)
                if extra:
                    extra = extra_out
                for b in range(B):
                    if active[b] is not None:
                        logits[b] = lgn[b]
                positions += 1
                ticks += 1
        finally:
            # errors must not leak slot pages or the prefix retain: a
            # pinned pool would shrink permanently
            for b in range(B):
                if slot_pages[b]:
                    eng._alloc.release(slot_pages[b])
                    slot_pages[b] = []
            if pages_pre:
                eng._alloc.release(pages_pre)
        st.decode_steps += ticks
        if eng._alloc is not None:
            st.kv_bytes = eng._alloc.peak_in_use * eng._page_bytes()
