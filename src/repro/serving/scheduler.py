"""Slot-based continuous batching on top of InferenceEngine.

A fixed decode batch of `num_slots` sequences runs lock-step decode ticks;
finished slots are immediately refilled by prefilling queued requests into
the slot's cache rows (per-row cache indices make ragged fill levels safe).
This is the serving analog of the paper's §6.3 parallel-call executor: the
"worker pool" is the decode batch, and slot eviction doubles as straggler
mitigation (a request exceeding its token budget is cut off and re-queued
or failed without stalling the batch).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as MDL
from repro.serving import tokenizer as TOK
from repro.serving.engine import GenStats, InferenceEngine, NEG_INF
from repro.serving.grammar import JsonGrammar


@dataclasses.dataclass
class Request:
    prompt: str
    grammar: Optional[JsonGrammar] = None
    max_new_tokens: int = 256
    rid: int = -1
    # filled on completion:
    text: Optional[str] = None
    error: Optional[str] = None


class ContinuousBatcher:
    def __init__(self, engine: InferenceEngine, num_slots: int = 8):
        self.engine = engine
        self.num_slots = num_slots
        self.stats = GenStats()

    def run(self, requests: Sequence[Request], *, temperature: float = 0.0
            ) -> List[Request]:
        """Process all requests to completion; returns them (order kept)."""
        t0 = time.time()
        eng = self.engine
        reqs = list(requests)
        for i, r in enumerate(reqs):
            r.rid = i
        queue = list(reqs)
        B = self.num_slots

        cache = MDL.init_cache(eng.cfg, B, eng.max_len)
        cache["row_idx"] = jnp.zeros((B,), jnp.int32)
        active: List[Optional[Request]] = [None] * B
        states = [None] * B
        outs: List[List[int]] = [[] for _ in range(B)]
        budgets = np.zeros(B, np.int64)
        positions = np.zeros(B, np.int32)
        logits = np.full((B, eng.cfg.padded_vocab), NEG_INF, np.float32)

        def fill_slot(b: int, req: Request, cache):
            ids = TOK.encode(req.prompt)
            lg, c1, lens, pre = eng._prefill([ids], row_idx_mode=True)
            self.stats.prefill_tokens += pre
            self.stats.input_tokens += len(ids)
            # splice sequence 0 of c1 into slot b of the live cache
            new = dict(cache)
            for k, v in c1.items():
                if k == "idx":
                    continue
                tgt = jnp.asarray(cache[k])
                src = jnp.asarray(v)
                if k in ("k", "v", "conv", "h"):          # (L, B, ...)
                    new[k] = tgt.at[:, b].set(src[:, 0])
                elif k in ("slot_pos", "row_idx"):        # (B, ...)
                    new[k] = tgt.at[b].set(src[0])
            active[b] = req
            states[b] = req.grammar.init_state() if req.grammar else None
            outs[b] = []
            budgets[b] = req.max_new_tokens
            positions[b] = lens[0]
            logits[b] = lg[0][:logits.shape[1]]
            return new

        decode = eng._decode_fn()
        done_count = 0
        ticks = 0
        while done_count < len(reqs):
            # refill free slots
            for b in range(B):
                if active[b] is None and queue:
                    cache = fill_slot(b, queue.pop(0), cache)
            live = [b for b in range(B) if active[b] is not None]
            if not live:
                break

            gs = [active[b].grammar if active[b] else None for b in range(B)]
            toks = eng._sample(logits, gs, states, temperature)
            for b in live:
                r = active[b]
                t = int(toks[b])
                if r.grammar is not None:
                    states[b] = r.grammar.advance(states[b], t)
                    if t != TOK.EOS_ID:
                        outs[b].append(t)
                    finished = r.grammar.done(states[b])
                else:
                    finished = t == TOK.EOS_ID
                    if not finished:
                        outs[b].append(t)
                budgets[b] -= 1
                self.stats.output_tokens += 1
                if budgets[b] <= 0 and not finished:
                    r.error = "token budget exceeded (slot evicted)"
                    finished = True
                if finished:
                    r.text = TOK.decode(outs[b])
                    active[b] = None
                    done_count += 1
                    logits[b] = NEG_INF

            if done_count >= len(reqs):
                break
            lg, cache = decode(eng.params, jnp.asarray(toks[:, None]),
                               jnp.asarray(positions[:, None]), cache)
            lgn = np.asarray(lg, np.float32)
            for b in range(B):
                if active[b] is not None:
                    logits[b] = lgn[b]
            positions += 1
            ticks += 1

        self.stats.decode_steps += ticks
        self.stats.calls += 1
        self.stats.wall_s += time.time() - t0
        return reqs
