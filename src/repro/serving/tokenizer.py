"""Byte-level tokenizer: vocab = 256 raw bytes + PAD/BOS/EOS.

Self-contained and loss-free — exactly what an in-database engine wants for
schema-compliant round-trips. All token counts reported by benchmarks use
this tokenizer consistently across every system emulation, so count RATIOS
are comparable with the paper's (which used OpenAI BPE)."""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


def encode(text: str, *, bos: bool = True) -> List[int]:
    ids = list(text.encode("utf-8", errors="replace"))
    return ([BOS_ID] if bos else []) + ids


def decode(ids: Sequence[int]) -> str:
    bs = bytes(i for i in ids if 0 <= i < 256)
    return bs.decode("utf-8", errors="replace")


def count_tokens(text: str) -> int:
    return len(text.encode("utf-8", errors="replace"))


def pad_batch(seqs: List[List[int]], length: int | None = None
              ) -> tuple[np.ndarray, np.ndarray]:
    """Right-pad to a common length. Returns (tokens (B, L) int32,
    lengths (B,) int32)."""
    length = length or max(len(s) for s in seqs)
    out = np.full((len(seqs), length), PAD_ID, np.int32)
    lens = np.zeros(len(seqs), np.int32)
    for i, s in enumerate(seqs):
        s = s[:length]
        out[i, :len(s)] = s
        lens[i] = len(s)
    return out, lens
