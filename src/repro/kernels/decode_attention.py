"""Pallas TPU decode attention: one query token per sequence against a long
(ring-buffered) KV cache. Memory-bound by the cache read — the kernel's job
is to stream k/v blocks through VMEM exactly once with the streamed-softmax
accumulator in scratch.

Grid = (B·KV, num_cache_blocks), cache axis innermost/sequential.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, spos_ref, q_ref, k_ref, v_ref,
            o_ref, m_ref, l_ref, acc_ref, *, scale: float, nl: int):
    lb = pl.program_id(1)

    @pl.when(lb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = qpos_ref[0]                                   # (1,) this sequence
    spos = spos_ref[0]                                   # (bl,) slot positions

    q = q_ref[0].astype(jnp.float32) * scale             # (G, D)
    k = k_ref[0].astype(jnp.float32)                     # (bl, D)
    v = v_ref[0].astype(jnp.float32)                     # (bl, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (G, bl)
    ok = (spos >= 0) & (spos <= qpos[0])
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(lb == nl - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, slot_positions, q_position,
                            *, block_l: int = 512, interpret: bool = False):
    """q (BK, G, D); k_cache, v_cache (BK, L, D); slot_positions (BK, L);
    q_position (BK, 1). Returns (BK, G, D)."""
    BK, G, D = q.shape
    L = k_cache.shape[1]
    assert L % block_l == 0, (L, block_l)
    nl = L // block_l
    scale = 1.0 / math.sqrt(D)

    kern = functools.partial(_kernel, scale=scale, nl=nl)
    out = pl.pallas_call(
        kern,
        grid=(BK, nl),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),             # q_position
            pl.BlockSpec((1, block_l), lambda b, j: (b, j)),       # slot pos
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_l, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_l, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_position, slot_positions, q, k_cache, v_cache)
    return out
