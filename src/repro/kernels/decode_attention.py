"""Pallas TPU decode attention: one query token per sequence against a long
KV cache. Memory-bound by the cache read — the kernel's job is to stream
k/v blocks through VMEM exactly once with the streamed-softmax accumulator
in scratch.

Two layouts:
  * dense — per-sequence contiguous (ring-buffered) caches,
    grid = (B·KV, num_cache_blocks), cache axis innermost/sequential.
  * paged — a global pool of fixed-size KV pages addressed through a
    per-sequence block table (scalar-prefetched so the BlockSpec index_map
    can chase page ids), grid = (B·KV, num_table_blocks) where the caller
    sizes num_table_blocks to the batch's ACTUAL fill, not max_len.
    Inactive trailing table entries are expected to repeat the last active
    page id (same index ⇒ the pipeline skips the re-fetch) and contribute
    nothing: compute is predicated off for them.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, spos_ref, q_ref, k_ref, v_ref,
            o_ref, m_ref, l_ref, acc_ref, *, scale: float, nl: int):
    lb = pl.program_id(1)

    @pl.when(lb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = qpos_ref[0]                                   # (1,) this sequence
    spos = spos_ref[0]                                   # (bl,) slot positions

    q = q_ref[0].astype(jnp.float32) * scale             # (G, D)
    k = k_ref[0].astype(jnp.float32)                     # (bl, D)
    v = v_ref[0].astype(jnp.float32)                     # (bl, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (G, bl)
    ok = (spos >= 0) & (spos <= qpos[0])
    s = jnp.where(ok[None, :], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + \
        jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(lb == nl - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, slot_positions, q_position,
                            *, block_l: int = 512, interpret: bool = False):
    """q (BK, G, D); k_cache, v_cache (BK, L, D); slot_positions (BK, L);
    q_position (BK, 1). Returns (BK, G, D)."""
    BK, G, D = q.shape
    L = k_cache.shape[1]
    assert L % block_l == 0, (L, block_l)
    nl = L // block_l
    scale = 1.0 / math.sqrt(D)

    kern = functools.partial(_kernel, scale=scale, nl=nl)
    out = pl.pallas_call(
        kern,
        grid=(BK, nl),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),             # q_position
            pl.BlockSpec((1, block_l), lambda b, j: (b, j)),       # slot pos
            pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_l, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_l, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_position, slot_positions, q, k_cache, v_cache)
    return out


# ------------------------------- paged layout ---------------------------------
def _paged_kernel(bt_ref, nact_ref, qpos_ref, q_ref, k_ref, v_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, scale: float, ps: int,
                  nb: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < nact_ref[b])
    def _block():
        qpos = qpos_ref[b]
        q = q_ref[0].astype(jnp.float32) * scale             # (G, D)
        k = k_ref[0].astype(jnp.float32)                     # (ps, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (G, ps)
        # paged layout invariant: logical slot index == absolute position,
        # so validity needs no per-slot position array — just the fill level
        tok = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(tok <= qpos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention_paged_pallas(q, k_pool, v_pool, block_tables,
                                  num_active, q_position, *,
                                  scale: float = None,
                                  interpret: bool = False):
    """q (BK, G, D); k_pool, v_pool (P, ps, D) global page pools;
    block_tables (BK, NB) int32 page ids (must be valid pool indices — the
    wrapper clamps); num_active (BK,) active blocks per sequence;
    q_position (BK,). Returns (BK, G, D).

    The block table, fill counts and query positions are scalar-prefetched
    so the k/v BlockSpec index_map dereferences the table: block j of
    sequence b is fetched from pool page block_tables[b, j] — the kernel
    reads shared (e.g. instruction-prefix) pages in place, no gather.

    scale overrides the softmax scale (default 1/sqrt(D)): when D is the
    zero-padded lane width the caller passes 1/sqrt(true head_dim) — the
    padded lanes contribute 0 to the dot so no q-side compensation is
    needed."""
    BK, G, D = q.shape
    P, ps, _ = k_pool.shape
    NB = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    kern = functools.partial(_paged_kernel, scale=scale, ps=ps, nb=NB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BK, NB),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, bt, na, qp: (b, 0, 0)),
            pl.BlockSpec((1, ps, D), lambda b, j, bt, na, qp: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, ps, D), lambda b, j, bt, na, qp: (bt[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j, bt, na, qp: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BK, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, num_active, q_position, q, k_pool, v_pool)
    return out


# --------------------------- paged layout, int8 pages --------------------------
def _paged_quant_kernel(bt_ref, nact_ref, qpos_ref, q_ref, k_ref, v_ref,
                        kq_ref, vq_ref, ks_ref, vs_ref, fl_ref,
                        o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                        ps: int, nb: int):
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < nact_ref[b])
    def _block():
        qpos = qpos_ref[b]
        q = q_ref[0].astype(jnp.float32) * scale             # (G, D)
        # frozen pages read the int8 shadow × per-page scale; live pages
        # read the fp pool — both blocks arrive via the same table-chased
        # index_map, the select is pure VPU work
        frozen = fl_ref[0, 0] > 0
        k = jnp.where(frozen,
                      kq_ref[0].astype(jnp.float32) * ks_ref[0, 0],
                      k_ref[0].astype(jnp.float32))          # (ps, D)
        v = jnp.where(frozen,
                      vq_ref[0].astype(jnp.float32) * vs_ref[0, 0],
                      v_ref[0].astype(jnp.float32))
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (G, ps)
        tok = j * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(tok <= qpos, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(j == nb - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def decode_attention_paged_quant_pallas(q, k_pool, v_pool, kq_pool, vq_pool,
                                        kscale, vscale, quant_flags,
                                        block_tables, num_active, q_position,
                                        *, scale: float = None,
                                        interpret: bool = False):
    """Quant-aware twin of decode_attention_paged_pallas: kq_pool/vq_pool
    (P, ps, D) int8 shadow pools; kscale/vscale (P, 1) float32 per-page
    scales; quant_flags (P, 1) int32 (>0 ⇒ page is frozen/quantized).
    Remaining arguments and the streamed-softmax structure are identical —
    the only delta is a per-page dequant select on the fetched block."""
    BK, G, D = q.shape
    P, ps, _ = k_pool.shape
    NB = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    kern = functools.partial(_paged_quant_kernel, scale=scale, ps=ps, nb=NB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(BK, NB),
        in_specs=[
            pl.BlockSpec((1, G, D), lambda b, j, bt, na, qp: (b, 0, 0)),
            pl.BlockSpec((1, ps, D), lambda b, j, bt, na, qp: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, ps, D), lambda b, j, bt, na, qp: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, ps, D), lambda b, j, bt, na, qp: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, ps, D), lambda b, j, bt, na, qp: (bt[b, j], 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j, bt, na, qp: (bt[b, j], 0)),
            pl.BlockSpec((1, 1), lambda b, j, bt, na, qp: (bt[b, j], 0)),
            pl.BlockSpec((1, 1), lambda b, j, bt, na, qp: (bt[b, j], 0)),
        ],
        out_specs=pl.BlockSpec((1, G, D), lambda b, j, bt, na, qp: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((BK, G, D), q.dtype),
        interpret=interpret,
    )(block_tables, num_active, q_position, q, k_pool, v_pool,
      kq_pool, vq_pool, kscale, vscale, quant_flags)
    return out
