"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, q_positions, kv_positions, *,
                        causal=True, window=0, prefix_len=0):
    """Same folded layout as the kernel: q (BK, G, Sq, D); k, v (BK, Skv, D);
    positions (BK, S)."""
    BK, G, Sq, D = q.shape
    s = jnp.einsum("bgqd,bjd->bgqj", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qp, kp = q_positions, kv_positions
    valid = kp[:, None, None, :] >= 0
    if causal:
        ok = valid & (kp[:, None, None, :] <= qp[:, None, :, None])
        if window > 0:
            ok &= kp[:, None, None, :] > qp[:, None, :, None] - window
        if prefix_len > 0:
            ok |= valid & (kp[:, None, None, :] < prefix_len)
    else:
        ok = valid
    s = jnp.where(ok, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgqj,bjd->bgqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(q, k_cache, v_cache, slot_positions, q_position):
    """q (BK, G, D); caches (BK, L, D); slot_positions (BK, L);
    q_position (BK, 1)."""
    BK, G, D = q.shape
    s = jnp.einsum("bgd,bld->bgl", q.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) / math.sqrt(D)
    ok = (slot_positions >= 0) & (slot_positions <= q_position)
    s = jnp.where(ok[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgl,bld->bgd", p,
                      v_cache.astype(jnp.float32)).astype(q.dtype)


def decode_attention_paged_ref(q, k_pool, v_pool, block_tables, num_active,
                               q_position):
    """q (BK, G, D); pools (P, ps, D); block_tables (BK, NB) page ids;
    num_active (BK,) active blocks; q_position (BK, 1). Gathers the pages
    into a dense view, then reuses the dense oracle with the paged-layout
    position invariant (logical slot index == absolute position)."""
    BK = q.shape[0]
    P, ps, _ = k_pool.shape
    NB = block_tables.shape[1]
    safe = jnp.clip(block_tables, 0, P - 1)
    k = k_pool[safe].reshape(BK, NB * ps, -1)
    v = v_pool[safe].reshape(BK, NB * ps, -1)
    pos = jnp.broadcast_to(jnp.arange(NB * ps, dtype=jnp.int32)[None],
                           (BK, NB * ps))
    active = jnp.repeat(
        jnp.arange(NB)[None, :] < num_active[:, None], ps, axis=1)
    pos = jnp.where(active, pos, -1)
    return decode_attention_ref(q, k, v, pos, q_position)


def decode_attention_paged_quant_ref(q, k_pool, v_pool, kq_pool, vq_pool,
                                     kscale, vscale, quant_flags,
                                     block_tables, num_active, q_position):
    """Quant-aware oracle: kq_pool/vq_pool (P, ps, D) int8 shadows;
    kscale/vscale (P, 1) per-page scales; quant_flags (P, 1) int32 (>0 ⇒
    frozen/quantized page). Dequantizes frozen pages, then reuses the
    paged oracle."""
    frozen = (quant_flags[:, 0] > 0)[:, None, None]
    k = jnp.where(frozen, kq_pool.astype(jnp.float32) * kscale[..., None],
                  k_pool.astype(jnp.float32)).astype(k_pool.dtype)
    v = jnp.where(frozen, vq_pool.astype(jnp.float32) * vscale[..., None],
                  v_pool.astype(jnp.float32)).astype(v_pool.dtype)
    return decode_attention_paged_ref(q, k, v, block_tables, num_active,
                                      q_position)


def gmm_ref(x, w, group_sizes):
    """x (T, M) rows sorted by expert; w (E, M, N); group_sizes (E,).
    Dense oracle via per-row expert ids."""
    T = x.shape[0]
    ids = jnp.repeat(jnp.arange(w.shape[0]), group_sizes,
                     total_repeat_length=T)
    wr = w[ids]                                        # (T, M, N)
    return jnp.einsum("tm,tmn->tn", x.astype(jnp.float32),
                      wr.astype(jnp.float32)).astype(x.dtype)


def selective_scan_ref(u, dt, A, B, C, D):
    """Sequential scan oracle (h0 = 0). Shapes as the kernel."""
    from repro.models.mamba import selective_scan_ref as _ref
    Bz, _, Di = u.shape
    h0 = jnp.zeros((Bz, Di, A.shape[1]), jnp.float32)
    y, h = _ref(u, dt, A, B, C, D, h0)
    return y, h


def constrained_sample_ref(logits, mask, noise, *, temperature=1.0):
    x = logits.astype(jnp.float32) / max(temperature, 1e-6)
    x = x + noise.astype(jnp.float32)
    x = jnp.where(mask != 0, x, NEG_INF)
    return jnp.argmax(x, axis=-1).astype(jnp.int32)
