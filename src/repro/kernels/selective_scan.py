"""Pallas TPU selective scan (mamba-1): chunked recurrence with the hidden
state carried in VMEM scratch across sequential chunk grid steps.

Grid = (B, Di/bd, S/chunk); the chunk axis is innermost and sequential —
scratch persists across it, so the state h (bd, N) never round-trips to
HBM between chunks. Inside a chunk the recurrence is a fori_loop over time
steps on (bd, N) vectors (VPU work; bd·N sized to fill lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
            y_ref, hout_ref, h_ref, *, chunk: int, nc: int):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = a_ref[...].astype(jnp.float32)                    # (bd, N)
    D = d_ref[...].astype(jnp.float32)                    # (bd,)

    def step(t, h):
        u_t = u_ref[0, t].astype(jnp.float32)             # (bd,)
        dt_t = dt_ref[0, t].astype(jnp.float32)           # (bd,)
        b_t = b_ref[0, t].astype(jnp.float32)             # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)             # (N,)
        a = jnp.exp(dt_t[:, None] * A)                    # (bd, N)
        h = a * h + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=-1) + D * u_t  # (bd,)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(cb == nc - 1)
    def _finalize():
        hout_ref[0] = h.astype(hout_ref.dtype)


def selective_scan_pallas(u, dt, A, B, C, D, *, chunk: int = 256,
                          block_d: int = 128, interpret: bool = False):
    """u, dt (Bz, S, Di); A (Di, N); B, C (Bz, S, N); D (Di,).
    Returns (y (Bz, S, Di), h_final (Bz, Di, N)). h0 = 0 (prefill-from-start;
    the engine's continued-decode path uses the jnp recurrence)."""
    Bz, S, Di = u.shape
    N = A.shape[1]
    assert S % chunk == 0 and Di % block_d == 0, (S, Di)
    nc, nd = S // chunk, Di // block_d

    kern = functools.partial(_kernel, chunk=chunk, nc=nc)
    y, h_final = pl.pallas_call(
        kern,
        grid=(Bz, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # u
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),  # dt
            pl.BlockSpec((block_d, N), lambda b, d, c: (d, 0)),            # A
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # B
            pl.BlockSpec((1, chunk, N), lambda b, d, c: (b, c, 0)),        # C
            pl.BlockSpec((block_d,), lambda b, d, c: (d,)),                # D
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, d, c: (b, c, d)),
            pl.BlockSpec((1, block_d, N), lambda b, d, c: (b, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bz, S, Di), jnp.float32),
            jax.ShapeDtypeStruct((Bz, Di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, A, B, C, D)
    return y, h_final
