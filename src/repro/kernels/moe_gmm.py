"""Pallas TPU grouped matmul (MegaBlocks-style) for MoE expert FFNs.

Input rows are pre-sorted by expert and each expert's rows padded to a
multiple of the row-block size (done in ops.py), so every (bm × bn) output
tile reads exactly ONE expert's weight tile — the per-row-block expert id
arrives via scalar prefetch and drives the weight BlockSpec index_map.

Grid = (num_row_blocks, N/bn, M/bk) with the contraction axis innermost,
accumulating into fp32 VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(block_expert_ref,          # scalar-prefetch: (num_row_blocks,)
            x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)          # (bm, bk)
    w = w_ref[0].astype(jnp.float32)            # (bk, bn)
    acc_ref[...] += jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())))

    @pl.when(kb == nk - 1)
    def _finalize():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gmm_pallas(x, w, block_expert, *, block_m: int = 128, block_n: int = 128,
               block_k: int = 512, interpret: bool = False):
    """x (Tp, M) rows sorted+padded by expert; w (E, M, N);
    block_expert (Tp/block_m,) int32 — expert id per row block.
    Returns (Tp, N)."""
    Tp, M = x.shape
    E, _, N = w.shape
    assert Tp % block_m == 0 and N % block_n == 0 and M % block_k == 0
    nm, nn, nk = Tp // block_m, N // block_n, M // block_k

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda i, j, k, be: (i, k)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda i, j, k, be: (be[i], k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, k, be: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((Tp, N), x.dtype),
        interpret=interpret,
    )(block_expert, x, w)
    return out
