"""Pallas TPU fused constrained-sampling kernel (iPDB §5.2 grammar-forced
generation, TPU-adapted).

The grammar automaton (host-side, O(bytes)) produces a per-step vocab mask.
Naively applying it costs 3–4 HBM sweeps over (B, V): mask-select,
temperature-scale, add Gumbel noise, argmax. This kernel fuses all four
into ONE streamed pass: grid = (B, V/bv) with the vocab axis sequential and
a running (best value, best index) pair in VMEM scratch.

Greedy decoding = zero Gumbel noise. Temperature is folded into the
comparison key. This is the per-decode-step hot path of the PREDICT
operator when structured output is enforced.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(logits_ref, mask_ref, noise_ref, out_ref,
            best_ref, idx_ref, *, inv_temp: float, block_v: int, nv: int):
    vb = pl.program_id(1)

    @pl.when(vb == 0)
    def _init():
        best_ref[0] = NEG_INF
        idx_ref[0] = 0

    x = logits_ref[0].astype(jnp.float32) * inv_temp      # (bv,)
    x = x + noise_ref[0].astype(jnp.float32)
    ok = mask_ref[0] != 0
    x = jnp.where(ok, x, NEG_INF)

    local_i = jnp.argmax(x)
    local_v = x[local_i]

    @pl.when(local_v > best_ref[0])
    def _update():
        best_ref[0] = local_v
        idx_ref[0] = (vb * block_v + local_i).astype(jnp.int32)

    @pl.when(vb == nv - 1)
    def _finalize():
        out_ref[0, 0] = idx_ref[0]


def constrained_sample_pallas(logits, mask, noise, *, temperature: float = 1.0,
                              block_v: int = 2048, interpret: bool = False):
    """logits (B, V) fp; mask (B, V) int8/bool (1 = allowed); noise (B, V)
    fp32 Gumbel noise (zeros → greedy). Returns sampled token ids (B,) int32
    = argmax(mask ? logits/T + noise : -inf)."""
    B, V = logits.shape
    assert V % block_v == 0, (V, block_v)
    nv = V // block_v
    inv_temp = 1.0 / max(temperature, 1e-6)

    kern = functools.partial(_kernel, inv_temp=inv_temp, block_v=block_v,
                             nv=nv)
    out = pl.pallas_call(
        kern,
        grid=(B, nv),
        in_specs=[
            pl.BlockSpec((1, block_v), lambda b, j: (b, j)),
            pl.BlockSpec((1, block_v), lambda b, j: (b, j)),
            pl.BlockSpec((1, block_v), lambda b, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        scratch_shapes=[
            pltpu.SMEM((1,), jnp.float32),
            pltpu.SMEM((1,), jnp.int32),
        ],
        interpret=interpret,
    )(logits, mask, noise)
    return out[:, 0]
