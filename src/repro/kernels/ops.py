"""Jit'd public wrappers around the Pallas kernels.

These own the layout plumbing (GQA head folding, D-padding to 128, expert
sort + group padding) so model code can call them with natural shapes. On
this CPU container they run with interpret=True; on TPU, interpret=False
compiles the real Mosaic kernels. `use_interpret()` resolves the default
from the backend.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.constrained_logits import constrained_sample_pallas
from repro.kernels.decode_attention import (
    decode_attention_paged_pallas, decode_attention_paged_quant_pallas,
    decode_attention_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gmm import gmm_pallas
from repro.kernels.selective_scan import selective_scan_pallas


def use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad_axis(x, axis, to, value=0):
    pad = to - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ------------------------------ flash attention -------------------------------
@functools.partial(jax.jit, static_argnames=("causal", "window", "prefix_len",
                                             "block_q", "block_kv", "interpret"))
def flash_attention(q, k, v, q_positions, kv_positions, *, causal=True,
                    window=0, prefix_len=0, block_q=256, block_kv=512,
                    interpret: Optional[bool] = None):
    """Natural shapes: q (B, Sq, H, D); k, v (B, Skv, KV, D); positions
    (B, S). Folds GQA into (B·KV) kernel batches, pads Sq/Skv to block
    multiples and D to 128."""
    interpret = use_interpret() if interpret is None else interpret
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    Dp = _round_up(D, 128)
    Sqp, Skvp = _round_up(Sq, block_q), _round_up(Skv, block_kv)

    qf = _pad_axis(_pad_axis(q, 3, Dp), 1, Sqp)
    kf = _pad_axis(_pad_axis(k, 3, Dp), 1, Skvp)
    vf = _pad_axis(_pad_axis(v, 3, Dp), 1, Skvp)
    qp = _pad_axis(q_positions, 1, Sqp, value=-1)
    kp = _pad_axis(kv_positions, 1, Skvp, value=-1)

    # (B, S, KV, G, D) → (B, KV, G, S, D) → (B·KV, G, S, D)
    qr = qf.reshape(B, Sqp, KV, G, Dp).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV, G, Sqp, Dp)
    kr = kf.transpose(0, 2, 1, 3).reshape(B * KV, Skvp, Dp)
    vr = vf.transpose(0, 2, 1, 3).reshape(B * KV, Skvp, Dp)
    qpr = jnp.repeat(qp, KV, axis=0)
    kpr = jnp.repeat(kp, KV, axis=0)

    # scale correction: kernel scales by 1/sqrt(Dp); compensate to 1/sqrt(D)
    qr = qr * jnp.asarray((Dp / D) ** 0.5, qr.dtype)

    o = flash_attention_pallas(qr, kr, vr, qpr, kpr, causal=causal,
                               window=window, prefix_len=prefix_len,
                               block_q=block_q, block_kv=block_kv,
                               interpret=interpret)
    o = o.reshape(B, KV, G, Sqp, Dp).transpose(0, 3, 1, 2, 4) \
        .reshape(B, Sqp, H, Dp)
    return o[:, :Sq, :, :D]


# ------------------------------ decode attention ------------------------------
@functools.partial(jax.jit, static_argnames=("block_l", "interpret"))
def decode_attention(q, k_cache, v_cache, slot_positions, q_position, *,
                     block_l=512, interpret: Optional[bool] = None):
    """q (B, H, D); caches (B, L, KV, D); slot_positions (B, L);
    q_position (B,). Returns (B, H, D)."""
    interpret = use_interpret() if interpret is None else interpret
    B, H, D = q.shape
    _, L, KV, _ = k_cache.shape
    G = H // KV
    Dp = _round_up(D, 128)
    Lp = _round_up(L, block_l)

    qf = _pad_axis(q, 2, Dp).reshape(B, KV, G, Dp).reshape(B * KV, G, Dp)
    kf = _pad_axis(_pad_axis(k_cache, 3, Dp), 1, Lp) \
        .transpose(0, 2, 1, 3).reshape(B * KV, Lp, Dp)
    vf = _pad_axis(_pad_axis(v_cache, 3, Dp), 1, Lp) \
        .transpose(0, 2, 1, 3).reshape(B * KV, Lp, Dp)
    sp = jnp.repeat(_pad_axis(slot_positions, 1, Lp, value=-1), KV, axis=0)
    qpos = jnp.repeat(q_position[:, None], KV, axis=0).reshape(B * KV, 1)

    qf = qf * jnp.asarray((Dp / D) ** 0.5, qf.dtype)
    o = decode_attention_pallas(qf, kf, vf, sp, qpos, block_l=block_l,
                                interpret=interpret)
    return o.reshape(B, KV, G, Dp).reshape(B, H, Dp)[..., :D]


@functools.partial(jax.jit, static_argnames=("head_dim", "interpret"))
def decode_attention_paged(q, k_pool, v_pool, block_tables, q_position, *,
                           head_dim: Optional[int] = None, quant=None,
                           interpret: Optional[bool] = None):
    """Paged decode attention over the pre-folded pool layout: q (B, H, D);
    pools (KV, P, ps, Dp) with Dp = head_dim already zero-padded to the
    128-lane width, so the kernel's (KV·P, ps, Dp) view is a FREE reshape —
    no per-step transpose or pad.  block_tables (B, NB) int32 page ids
    (-1 = invalid); q_position (B,). Returns (B, H, D).

    GQA folding duplicates only the tiny block table — the pool itself is
    addressed per kv-head slice, not per batch row.  Inactive/invalid table
    entries are rewritten to the row's last active page so the kernel
    pipeline revisits an already-resident page (no extra DMA) while the
    predicated body skips the compute.  The softmax scale is 1/sqrt(true
    head_dim): padded lanes are zero on both q and k, so they drop out of
    the dot with no q-side compensation.

    quant (dict or None): int8 shadow pools "kq"/"vq" (KV, P, ps, Dp),
    per-page scales "kscale"/"vscale" (KV, P) and frozen flags "flags"
    (P,) — dispatches to the dequantizing kernel twin."""
    interpret = use_interpret() if interpret is None else interpret
    B, H, D = q.shape
    KV, P, ps, Dp = k_pool.shape
    D = head_dim or D
    NB = block_tables.shape[1]
    G = H // KV

    qf = _pad_axis(q, 2, Dp).reshape(B, KV, G, Dp).reshape(B * KV, G, Dp)
    kf = k_pool.reshape(KV * P, ps, Dp)
    vf = v_pool.reshape(KV * P, ps, Dp)

    qpos = q_position.astype(jnp.int32)
    nact = jnp.clip(jnp.clip(qpos, 0, None) // ps + 1, 1, NB)       # (B,)
    last = jnp.take_along_axis(block_tables, (nact - 1)[:, None], axis=1)
    idxs = jnp.arange(NB, dtype=jnp.int32)[None, :]
    bt = jnp.where((idxs < nact[:, None]) & (block_tables >= 0),
                   block_tables, last)
    bt = jnp.clip(bt, 0, P - 1)
    btf = (bt[:, None, :] +
           jnp.arange(KV, dtype=jnp.int32)[None, :, None] * P
           ).reshape(B * KV, NB)
    nactf = jnp.repeat(nact, KV)
    qposf = jnp.repeat(qpos, KV)

    scale = 1.0 / (D ** 0.5)
    if quant is not None:
        kqf = quant["kq"].reshape(KV * P, ps, Dp)
        vqf = quant["vq"].reshape(KV * P, ps, Dp)
        ksf = quant["kscale"].reshape(KV * P, 1).astype(jnp.float32)
        vsf = quant["vscale"].reshape(KV * P, 1).astype(jnp.float32)
        flf = jnp.tile(quant["flags"].astype(jnp.int32)[None, :],
                       (KV, 1)).reshape(KV * P, 1)
        o = decode_attention_paged_quant_pallas(
            qf, kf, vf, kqf, vqf, ksf, vsf, flf, btf, nactf, qposf,
            scale=scale, interpret=interpret)
    else:
        o = decode_attention_paged_pallas(qf, kf, vf, btf, nactf, qposf,
                                          scale=scale, interpret=interpret)
    return o.reshape(B, KV, G, Dp).reshape(B, H, Dp)[..., :D]


# --------------------------------- MoE gmm ------------------------------------
@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def gmm(x, w, group_sizes, *, block_m=128, block_n=128, block_k=256,
        interpret: Optional[bool] = None):
    """Grouped matmul: x (T, M) rows sorted by expert; w (E, M, N);
    group_sizes (E,) sums to T. Pads each group to a block_m multiple via a
    scatter, runs the kernel, gathers back. Returns (T, N)."""
    interpret = use_interpret() if interpret is None else interpret
    T, M = x.shape
    E, _, N = w.shape
    Mp, Np = _round_up(M, block_k), _round_up(N, block_n)

    gs = group_sizes.astype(jnp.int32)
    padded_sizes = ((gs + block_m - 1) // block_m) * block_m
    # worst case every expert pads to a full extra block
    Tp = _round_up(T, block_m) + E * block_m
    src_start = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(gs)[:-1]])
    dst_start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                 jnp.cumsum(padded_sizes)[:-1]])
    # destination row for each source row
    eid = jnp.repeat(jnp.arange(E, dtype=jnp.int32), gs, total_repeat_length=T)
    offset_in_group = jnp.arange(T, dtype=jnp.int32) - src_start[eid]
    dst = dst_start[eid] + offset_in_group
    xp = jnp.zeros((Tp, Mp), x.dtype).at[dst].set(_pad_axis(x, 1, Mp))

    # per-row-block expert ids
    nblocks = Tp // block_m
    block_starts = jnp.arange(nblocks, dtype=jnp.int32) * block_m
    dst_end = dst_start + padded_sizes
    block_eid = jnp.clip(jnp.searchsorted(dst_end, block_starts, side="right"),
                         0, E - 1).astype(jnp.int32)

    wp = _pad_axis(_pad_axis(w, 1, Mp), 2, Np)
    out = gmm_pallas(xp, wp, block_eid, block_m=block_m, block_n=block_n,
                     block_k=block_k, interpret=interpret)
    return out[dst][:, :N]


# --------------------------- constrained sampling -----------------------------
@functools.partial(jax.jit, static_argnames=("temperature", "block_v",
                                             "interpret"))
def constrained_sample(logits, mask, noise=None, *, temperature=1.0,
                       block_v=2048, interpret: Optional[bool] = None):
    """argmax(mask ? logits/T + noise : -inf) over the vocab, one HBM pass.
    noise=None → greedy."""
    interpret = use_interpret() if interpret is None else interpret
    B, V = logits.shape
    Vp = _round_up(V, block_v)
    lf = _pad_axis(logits, 1, Vp, value=-1e30)
    mf = _pad_axis(mask.astype(jnp.int8), 1, Vp)
    nf = jnp.zeros((B, Vp), jnp.float32) if noise is None \
        else _pad_axis(noise.astype(jnp.float32), 1, Vp)
    return constrained_sample_pallas(lf, mf, nf, temperature=temperature,
                                     block_v=block_v, interpret=interpret)


# ------------------------------ selective scan --------------------------------
@functools.partial(jax.jit, static_argnames=("chunk", "block_d", "interpret"))
def selective_scan(u, dt, A, B, C, D, *, chunk=256, block_d=128,
                   interpret: Optional[bool] = None):
    """Shapes as repro.models.mamba.selective_scan with h0=0. Pads S to a
    chunk multiple and Di to block_d."""
    interpret = use_interpret() if interpret is None else interpret
    Bz, S, Di = u.shape
    Sp = _round_up(S, chunk)
    Dp = _round_up(Di, block_d)
    uf = _pad_axis(_pad_axis(u, 1, Sp), 2, Dp)
    dtf = _pad_axis(_pad_axis(dt, 1, Sp), 2, Dp)
    Af = _pad_axis(A, 0, Dp)
    Bf = _pad_axis(B, 1, Sp)
    Cf = _pad_axis(C, 1, Sp)
    Df = _pad_axis(D, 0, Dp)
    y, h = selective_scan_pallas(uf, dtf, Af, Bf, Cf, Df, chunk=chunk,
                                 block_d=block_d, interpret=interpret)
    return y[:, :S, :Di], h[:, :Di]
