"""Pallas TPU flash attention (prefill): causal / sliding-window / prefix-LM,
GQA-native.

Grid = (B·KV, num_q_blocks, num_kv_blocks); the kv axis is innermost and
sequential ("arbitrary"), accumulating the streamed softmax in VMEM scratch
(m, l, acc). Block shapes are explicit BlockSpecs sized for ~16 MiB VMEM:
q (G, bq, D), k/v (bk, D) with bq/bk multiples of 128 and D padded to a
multiple of 128 in ops.py (hubert's D=80 → 128).

Fully-masked (q-block, kv-block) pairs (beyond the causal frontier or
outside the sliding window) are skipped with pl.when — on hardware that
saves the MXU work; the HBM fetch is already minimized by the BlockSpec.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref,   # inputs
            o_ref,                                     # outputs
            m_ref, l_ref, acc_ref,                     # scratch
            *, scale: float, causal: bool, window: int, prefix_len: int,
            nk: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos = qpos_ref[0]                                  # (bq,)
    kpos = kpos_ref[0]                                  # (bk,)

    # block-level skip: whole block beyond causal frontier / outside window
    qmax = jnp.max(qpos)
    qmin = jnp.min(qpos)
    kmin = jnp.min(jnp.where(kpos >= 0, kpos, jnp.iinfo(jnp.int32).max))
    kmax = jnp.max(kpos)
    live = kmax >= 0
    if causal:
        live &= kmin <= qmax
        if window > 0:
            live &= kmax > qmin - window
        if prefix_len > 0:
            live |= (kmax >= 0) & (kmin < prefix_len)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale        # (G, bq, D)
        k = k_ref[0].astype(jnp.float32)                # (bk, D)
        v = v_ref[0].astype(jnp.float32)                # (bk, D)
        s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())))  # (G,bq,bk)

        valid = kpos[None, :] >= 0
        if causal:
            ok = valid & (kpos[None, :] <= qpos[:, None])
            if window > 0:
                ok &= kpos[None, :] > qpos[:, None] - window
            if prefix_len > 0:
                ok |= valid & (kpos[None, :] < prefix_len)
        else:
            ok = valid
        s = jnp.where(ok[None], s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + \
            jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(kb == nk - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, q_positions, kv_positions, *,
                           causal: bool = True, window: int = 0,
                           prefix_len: int = 0, block_q: int = 256,
                           block_kv: int = 512, interpret: bool = False):
    """q (BK, G, Sq, D); k, v (BK, Skv, D); q_positions (BK, Sq);
    kv_positions (BK, Skv). BK = batch × kv_heads (folded in ops.py).
    Returns (BK, G, Sq, D)."""
    BK, G, Sq, D = q.shape
    Skv = k.shape[1]
    assert Sq % block_q == 0 and Skv % block_kv == 0, (Sq, Skv)
    nq, nk = Sq // block_q, Skv // block_kv
    scale = 1.0 / math.sqrt(D)

    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, prefix_len=prefix_len, nk=nk)
    grid = (BK, nq, nk)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),        # qpos
            pl.BlockSpec((1, block_kv), lambda b, i, j: (b, j)),       # kpos
            pl.BlockSpec((1, G, block_q, D), lambda b, i, j: (b, 0, i, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, G, block_q, D), lambda b, i, j: (b, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BK, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q), jnp.float32),
            pltpu.VMEM((G, block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q_positions, kv_positions, q, k, v)
    return out
