"""Physical operator pipeline (chunked, streaming).

The logical plan (`repro.relational.plan.Node`) is *lowered* into a tree of
physical operators that produce/consume `Table` chunks through an
`open() / next_chunk() / close()` protocol (a `chunks()` generator wrapper
is provided for convenience).  The executor drains the root operator; no
operator other than the declared pipeline breakers (sort, group-by, hash
join build side) materializes its whole input.

Operator inventory:

  ScanOp                    chunk_size slices of a catalog table
  FilterOp / ProjectOp      chunk-at-a-time, vectorized expression eval
  LimitOp                   early exit: stops pulling its child once
                            `n` rows have been emitted
  OrderByOp                 pipeline breaker (stable multi-key sort)
  HashJoinOp                vectorized build+probe via numpy factorization
                            (argsort + searchsorted, no per-row dict loops)
  CrossJoinOp               index-arithmetic windows over |L|x|R|
  GroupByOp                 pipeline breaker; grouping via stable argsort +
                            ufunc.reduceat (no per-row Python loops)
  PredictOp                 one PredictOperator instance fed one chunk at a
                            time (the operator batches/dedups internally);
                            keeps up to `inflight_windows` chunks submitted
                            to the inference service ahead of resolution
  PredictScanOp             table generation (rho^s, LLM-as-scan)
  SemanticSelectStackOp     >=2 reorderable semantic selects executed as
                            one operator: after every chunk the remaining
                            units are re-ranked on the pass rates observed
                            *inside this query*, so drifting data cannot
                            pin the optimizer's stale static order
  SemanticJoinOp            STREAMING block-nested-loop semantic join: the
                            cross product is produced window-by-window
                            (peak intermediate <= window rows, never
                            |L|x|R|), each window fed to one shared predict
                            operator so dedup/caching span all windows

Stats flow: operators that own a PredictOperator report it exactly once to
the `absorber` (the PlanExecutor) when they are closed.
"""
from __future__ import annotations

import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cancel import CancelScope
from repro.relational.plan import (Filter, GroupBy, Join, Limit, Node,
                                   OrderBy, Predict, PredictInfo, Project,
                                   Scan, SemanticJoin)
from repro.relational.table import Table, _coerce, _np_for

#: chunk-size floor applied under a streaming LIMIT: small enough that an
#: early exit wastes at most ~a window of inference, large enough that
#: multi-row marshaling (batch_size rows per call) still fills batches
LIMIT_CHUNK_FLOOR = 64


def empty_table(schema: Dict[str, str]) -> Table:
    return Table.from_rows([], dict(schema))


# ---------------------------------------------------------------------------
# factorization helpers (shared by HashJoinOp and GroupByOp)
# ---------------------------------------------------------------------------
def _needs_object_codes(*arrs: np.ndarray) -> bool:
    for a in arrs:
        if a.dtype == object:
            return True
        # NaN keys must keep the dict semantics of the row-at-a-time engine
        # (NaN never equals NaN), which np.unique(equal_nan=True) would break
        if np.issubdtype(a.dtype, np.floating) and np.isnan(a).any():
            return True
    return False


def _dict_codes(arrs: Sequence[np.ndarray]) -> Tuple[List[np.ndarray], int]:
    mapping: Dict[object, int] = {}
    out = []
    for a in arrs:
        codes = np.empty(len(a), np.int64)
        for i, v in enumerate(a.tolist()):
            codes[i] = mapping.setdefault(v, len(mapping))
        out.append(codes)
    return out, max(1, len(mapping))


def joint_codes(column_sets: Sequence[Sequence[np.ndarray]]
                ) -> List[np.ndarray]:
    """Factorize rows of one or more aligned column lists into shared int64
    codes: rows (across all sets) with equal key tuples get equal codes.
    `column_sets[s][c]` is key column c of input s."""
    n_cols = len(column_sets[0])
    lens = [len(cs[0]) if cs else 0 for cs in column_sets]
    combined = [np.zeros(l, np.int64) for l in lens]
    for c in range(n_cols):
        cols = [cs[c] for cs in column_sets]
        if _needs_object_codes(*cols):
            codes, card = _dict_codes(cols)
        else:
            allv = np.concatenate(cols) if sum(lens) else \
                np.array([], np.int64)
            uniq, inv = np.unique(allv, return_inverse=True)
            codes, off = [], 0
            for l in lens:
                codes.append(inv[off:off + l].astype(np.int64))
                off += l
            card = max(1, len(uniq))
        combined = [cmb * card + cd for cmb, cd in zip(combined, codes)]
        # re-compress so the running product can never overflow int64
        allc = np.concatenate(combined) if sum(lens) else \
            np.array([], np.int64)
        _, inv = np.unique(allc, return_inverse=True)
        out, off = [], 0
        for l in lens:
            out.append(inv[off:off + l].astype(np.int64))
            off += l
        combined = out
    return combined


# ---------------------------------------------------------------------------
class PhysicalOp:
    """Base chunk producer. Subclasses implement `_produce()` (a generator
    of Tables); the base class provides the open/next_chunk/close protocol
    and guarantees at least one (possibly empty) chunk so downstream
    operators always see the output schema."""

    name = "op"
    children: List["PhysicalOp"] = []
    #: per-session CancelScope (None outside front-door streams).  Checked
    #: at EVERY chunk boundary at every level — pipeline breakers drain
    #: their children through next_chunk too, so a cancel lands mid-sort /
    #: mid-build as fast as mid-stream.  The raised QueryCancelled unwinds
    #: the generator stack, running each operator's `finally:` (pipelined
    #: predicts cancel their pending chunks → still-queued service
    #: requests are dropped).
    scope: Optional[CancelScope] = None

    def __init__(self, out_schema: Dict[str, str]):
        self.out_schema = dict(out_schema)
        self._gen = None
        self._emitted = False

    # -- protocol ----------------------------------------------------------
    def open(self) -> None:
        self._gen = self._produce()
        self._emitted = False

    def next_chunk(self) -> Optional[Table]:
        if self.scope is not None:
            self.scope.raise_if_cancelled()
        if self._gen is None:
            self.open()
        chunk = next(self._gen, None)
        if chunk is None:
            if not self._emitted:
                self._emitted = True
                return empty_table(self.out_schema)
            return None
        self._emitted = True
        return chunk

    def close(self) -> None:
        if self._gen is not None:
            self._gen.close()
            self._gen = None

    def chunks(self):
        self.open()
        try:
            while True:
                c = self.next_chunk()
                if c is None:
                    break
                yield c
        finally:
            self.close()

    # -- impl --------------------------------------------------------------
    def _produce(self):
        raise NotImplementedError

    def _drain(self, child: "PhysicalOp") -> Table:
        """Materialize a child (pipeline breakers only)."""
        parts = list(child.chunks())
        out = parts[0]
        for p in parts[1:]:
            out = out.concat(p)
        return out

    def describe(self) -> str:
        return self.name


# ---------------------------------------------------------------------------
class ScanOp(PhysicalOp):
    name = "Scan"

    def __init__(self, table: Table, table_name: str, chunk_size: int,
                 out_schema):
        super().__init__(out_schema)
        self.table = table
        self.table_name = table_name
        self.chunk_size = max(1, int(chunk_size))
        self.children = []

    def _produce(self):
        for s in range(0, len(self.table), self.chunk_size):
            yield self.table.slice(s, min(s + self.chunk_size,
                                          len(self.table)))

    def describe(self):
        return (f"Scan[{self.table_name}] rows={len(self.table)} "
                f"chunk={self.chunk_size}")


class FilterOp(PhysicalOp):
    name = "Filter"

    def __init__(self, child: PhysicalOp, predicate, out_schema,
                 stats_probe=None):
        super().__init__(out_schema)
        self.child = child
        self.predicate = predicate
        # (StatisticsStore, key) when this filter realizes a semantic
        # select: observed pass rates feed the adaptive cost model
        self.stats_probe = stats_probe
        self.children = [child]

    def _produce(self):
        for c in self.child.chunks():
            mask = np.asarray(self.predicate.evaluate(c), bool)
            if self.stats_probe is not None and len(c):
                store, key = self.stats_probe
                store.record_predicate(key, len(c), int(mask.sum()))
            out = c.mask(mask)
            if len(out):
                yield out

    def describe(self):
        return f"Filter {self.predicate!r}"


class ProjectOp(PhysicalOp):
    name = "Project"

    def __init__(self, child: PhysicalOp, exprs, out_schema):
        super().__init__(out_schema)
        self.child = child
        self.exprs = exprs
        self.children = [child]

    def _produce(self):
        for c in self.child.chunks():
            cols, sch = {}, {}
            for name, e in self.exprs:
                cols[name] = e.evaluate(c)
                sch[name] = e.sql_type(c.schema)
            yield Table(cols, sch)

    def describe(self):
        return f"Project {[n for n, _ in self.exprs]}"


class LimitOp(PhysicalOp):
    name = "Limit"

    def __init__(self, child: PhysicalOp, n: int, out_schema):
        super().__init__(out_schema)
        self.child = child
        self.n = n
        self.children = [child]

    def _produce(self):
        remaining = self.n
        self.child.open()
        try:
            while remaining > 0:           # early exit: never over-pull
                c = self.child.next_chunk()
                if c is None:
                    break
                if len(c) > remaining:
                    c = c.slice(0, remaining)
                remaining -= len(c)
                if len(c):
                    yield c
        finally:
            self.child.close()

    def describe(self):
        return f"Limit {self.n} (early-exit)"


class OrderByOp(PhysicalOp):
    name = "OrderBy"

    def __init__(self, child: PhysicalOp, keys, chunk_size: int, out_schema):
        super().__init__(out_schema)
        self.child = child
        self.keys = keys
        self.chunk_size = max(1, int(chunk_size))
        self.children = [child]

    def _produce(self):
        t = self._drain(self.child)
        if len(t) == 0:
            return
        order = np.arange(len(t))
        for e, asc in reversed(self.keys):
            v = e.evaluate(t)[order]
            if v.dtype == object:
                v = np.array([("" if x is None else str(x)) for x in v])
            idx = np.argsort(v, kind="stable")
            if not asc:
                idx = idx[::-1]
            order = order[idx]
        t = t.take(order)
        for s in range(0, len(t), self.chunk_size):
            yield t.slice(s, min(s + self.chunk_size, len(t)))

    def describe(self):
        return f"OrderBy [{len(self.keys)} keys] (blocking sort)"


# ---------------------------------------------------------------------------
def _merge_sides(lt: Table, rt: Table) -> Table:
    cols = dict(lt.cols)
    sch = dict(lt.schema)
    for k, v in rt.cols.items():
        if k in cols:                      # drop duplicate right columns
            continue
        cols[k] = v
        sch[k] = rt.schema[k]
    return Table(cols, sch)


class HashJoinOp(PhysicalOp):
    """Equi-join via numpy factorization: both key sides are mapped into a
    shared code space, the probe is one argsort + two searchsorted calls.
    Output order matches the row-at-a-time reference (left order, matching
    right rows ascending)."""
    name = "HashJoin"

    def __init__(self, left: PhysicalOp, right: PhysicalOp,
                 left_keys, right_keys, extra, chunk_size: int, out_schema):
        super().__init__(out_schema)
        self.left = left
        self.right = right
        self.left_keys = left_keys
        self.right_keys = right_keys
        self.extra = extra
        self.chunk_size = max(1, int(chunk_size))
        self.children = [left, right]

    def _produce(self):
        l = self._drain(self.left)
        r = self._drain(self.right)
        if len(l) == 0 or len(r) == 0:
            return
        lk = [l.column(k) for k in self.left_keys]
        rk = [r.column(k) for k in self.right_keys]
        code_l, code_r = joint_codes([lk, rk])
        order = np.argsort(code_r, kind="stable")
        rs = code_r[order]
        starts = np.searchsorted(rs, code_l, "left")
        ends = np.searchsorted(rs, code_l, "right")
        counts = ends - starts
        total = int(counts.sum())
        if total == 0:
            return
        li = np.repeat(np.arange(len(l)), counts)
        offs = np.concatenate([[0], np.cumsum(counts)[:-1]])
        within = np.arange(total) - np.repeat(offs, counts)
        ri = order[np.repeat(starts, counts) + within]
        for s in range(0, total, self.chunk_size):
            e = min(s + self.chunk_size, total)
            out = _merge_sides(l.take(li[s:e]), r.take(ri[s:e]))
            if self.extra is not None:
                out = out.mask(np.asarray(self.extra.evaluate(out), bool))
            if len(out):
                yield out

    def describe(self):
        return (f"HashJoin keys={list(zip(self.left_keys, self.right_keys))} "
                f"(vectorized build+probe)")


class CrossJoinOp(PhysicalOp):
    name = "CrossJoin"

    def __init__(self, left: PhysicalOp, right: PhysicalOp, extra,
                 chunk_size: int, out_schema):
        super().__init__(out_schema)
        self.left = left
        self.right = right
        self.extra = extra
        self.chunk_size = max(1, int(chunk_size))
        self.children = [left, right]

    def _produce(self):
        l = self._drain(self.left)
        r = self._drain(self.right)
        total = len(l) * len(r)
        for s in range(0, total, self.chunk_size):
            idx = np.arange(s, min(s + self.chunk_size, total))
            out = _merge_sides(l.take(idx // len(r)), r.take(idx % len(r)))
            if self.extra is not None:
                out = out.mask(np.asarray(self.extra.evaluate(out), bool))
            if len(out):
                yield out

    def describe(self):
        return f"CrossJoin window={self.chunk_size}"


# ---------------------------------------------------------------------------
class GroupByOp(PhysicalOp):
    """Pipeline breaker. Groups via stable argsort over factorized key codes
    and aggregates with ufunc.reduceat; groups are emitted in first-
    occurrence order (matching the dict-based reference)."""
    name = "GroupBy"

    def __init__(self, child: PhysicalOp, keys, aggs, llm_agg_infos,
                 predict_factory, absorber, out_schema):
        super().__init__(out_schema)
        self.child = child
        self.keys = keys
        self.aggs = aggs
        self.llm_agg_infos = llm_agg_infos or {}
        self.predict_factory = predict_factory
        self.absorber = absorber
        self.children = [child]

    def _group_layout(self, t: Table):
        """Returns (order, starts, sizes, emit) — rows of group g (in emit
        order) are order[starts[emit[g]] : starts[emit[g]] + sizes[emit[g]]]."""
        n = len(t)
        if not self.keys:
            return (np.arange(n), np.array([0], np.int64),
                    np.array([n], np.int64), np.array([0], np.int64))
        if n == 0:
            z = np.array([], np.int64)
            return z, z, z, z
        cols = [t.column(k) for k in self.keys]
        codes = joint_codes([cols])[0]
        order = np.argsort(codes, kind="stable")
        sc = codes[order]
        starts = np.nonzero(np.r_[True, sc[1:] != sc[:-1]])[0]
        sizes = np.diff(np.r_[starts, n])
        first_pos = order[starts]          # stable → first occurrence
        emit = np.argsort(first_pos, kind="stable")
        return order, starts, sizes, emit

    def _produce(self):
        t = self._drain(self.child)
        order, starts, sizes, emit = self._group_layout(t)
        n_groups = len(emit)

        cols: Dict[str, np.ndarray] = {}
        sch: Dict[str, str] = {}
        if self.keys and n_groups:
            first_pos = order[starts][emit]
            for k in self.keys:
                cols[k] = t.column(k)[first_pos]
                sch[k] = t.schema[k]
        else:
            for k in self.keys:
                cols[k] = np.array([], dtype=_np_for(t.schema[k]))
                sch[k] = t.schema[k]

        for name, fn, arg in self.aggs:
            typ = "INTEGER" if fn == "count" else (
                "VARCHAR" if fn == "llm_agg" else "DOUBLE")
            if fn == "llm_agg":
                cols[name] = _coerce(self._llm_agg(name, t, order, starts,
                                                   sizes, emit), typ)
            elif fn == "count":
                cols[name] = _coerce([int(x) for x in sizes[emit]], typ)
            else:
                cols[name] = _coerce(self._numeric_agg(fn, arg, t, order,
                                                       starts, sizes, emit),
                                     typ)
            sch[name] = typ
        yield Table(cols, sch)

    def _numeric_agg(self, fn, arg, t, order, starts, sizes, emit):
        n_groups = len(emit)
        if n_groups == 0:
            return []
        v = np.asarray(arg.evaluate(t) if arg is not None
                       else np.ones(len(t)), np.float64)
        vs = v[order]
        if len(vs) == 0:                   # single keyless group, no rows
            return [0.0 if fn == "sum" else float("nan")] * n_groups
        nanm = np.isnan(vs)
        nn = np.add.reduceat(np.where(nanm, 0.0, 1.0), starts)
        if fn in ("sum", "avg"):
            s = np.add.reduceat(np.where(nanm, 0.0, vs), starts)
            with np.errstate(invalid="ignore", divide="ignore"):
                res = s if fn == "sum" else s / nn
        elif fn == "min":
            res = np.minimum.reduceat(np.where(nanm, np.inf, vs), starts)
            res = np.where(nn == 0, np.nan, res)
        else:                              # max
            res = np.maximum.reduceat(np.where(nanm, -np.inf, vs), starts)
            res = np.where(nn == 0, np.nan, res)
        return [float(x) for x in res[emit]]

    def _llm_agg(self, name, t, order, starts, sizes, emit):
        info = self.llm_agg_infos[name]
        op = self.predict_factory(info)
        group_rows = []
        for g in emit:
            idx = order[starts[g]:starts[g] + sizes[g]]
            group_rows.append([{c: t.row(int(i))[c] for c in info.inputs}
                               for i in idx])
        out = op.aggregate(group_rows)
        if self.absorber is not None:
            self.absorber._absorb(op)
        return out

    def describe(self):
        fns = [fn for _, fn, _ in self.aggs]
        return (f"GroupBy keys={self.keys} aggs={fns} "
                f"(vectorized argsort+reduceat)")


# ---------------------------------------------------------------------------
def _inflight_windows(op) -> int:
    return max(1, int(op.opts.get("inflight_windows", 1)))


def _cascade_note(info: PredictInfo) -> str:
    """Physical-plan annotation for a cascaded predict: the route the
    optimizer chose and the proxy it scores with."""
    route = info.options.get("cascade_route")
    if not route:
        return ""
    proxy = info.options.get("cascade_proxy", "?")
    return f" cascade={route}(proxy={proxy})"


class PredictOp(PhysicalOp):
    """Scalar/table inference: one shared PredictOperator consumes upstream
    chunks as they arrive, so batching/dedup/prompt-cache state spans the
    whole input stream.

    With `inflight_windows` > 1 the op keeps that many chunks *submitted*
    to the inference service before resolving the oldest, so chunk N+1's
    requests dispatch in the same service batch as chunk N's and overlap
    its downstream processing.  The default of 1 is the synchronous
    degenerate case (submit, resolve, emit)."""
    name = "Predict"

    def __init__(self, child: PhysicalOp, info: PredictInfo, predict_factory,
                 absorber, out_schema):
        super().__init__(out_schema)
        self.child = child
        self.info = info
        self.predict_factory = predict_factory
        self.absorber = absorber
        self.children = [child]

    def _produce(self):
        op = self.predict_factory(self.info)
        inflight = _inflight_windows(op)
        pending = []
        try:
            for c in self.child.chunks():
                pending.append(op.submit(c))
                # speculative background dispatch of hot queues (any
                # backend's): inference overlaps pulling the next chunk
                # instead of waiting for the first resolve
                op.kick()
                while len(pending) >= inflight:
                    yield op.resolve(pending.pop(0))
            while pending:
                yield op.resolve(pending.pop(0))
        finally:
            for pc in pending:             # closed early (e.g. Limit)
                op.cancel(pc)
            if self.absorber is not None:
                self.absorber._absorb(op)

    def describe(self):
        est = self.info.options.get("est_in_rows")
        e = f" est_in={est:.0f}" if est is not None else ""
        e += _cascade_note(self.info)
        return f"Predict[{self.info.model_name}] out={self.info.out_cols}{e}"


#: chunks of per-unit (rows_in, rows_passed) records the stack operator
#: ranks from — a recency window, not lifetime sums, so a selectivity that
#: DRIFTS mid-stream overturns the stale order within a few chunks
#: (mirrors PredicateStats.recent in the shared store)
_REOPT_WINDOW = 4


class _StackUnit:
    """One semantic-select unit inside a SemanticSelectStackOp."""

    __slots__ = ("info", "predicate", "key", "cost", "init_sel", "recent")

    def __init__(self, info: PredictInfo, predicate, key):
        self.info = info
        self.predicate = predicate
        self.key = key                  # stats-store key (None = no store)
        self.cost = float(info.options.get("reopt_cost", 1.0))
        self.init_sel = float(info.options.get("reopt_sel", 0.5))
        self.recent: List[Tuple[int, int]] = []   # (rows_in, rows_passed)

    def label(self) -> str:
        return f"{self.info.model_name}:{self.info.out_cols[0]}"

    def observe(self, rows_in: int, rows_passed: int) -> None:
        self.recent.append((rows_in, rows_passed))
        if len(self.recent) > _REOPT_WINDOW:
            del self.recent[0]

    def observed_sel(self) -> float:
        rin = sum(r for r, _ in self.recent)
        if rin > 0:
            return sum(p for _, p in self.recent) / rin
        return self.init_sel


class SemanticSelectStackOp(PhysicalOp):
    """Mid-query re-optimization of a commuting semantic-select stack.

    The optimizer stamps stacks whose legality it has proven (every unit's
    predicate depends only on its own predict outputs plus base columns)
    with `reopt` markers; lowering collapses such a stack into this single
    operator.  Each input chunk flows through the units in the CURRENT
    order; after the chunk, the order is re-ranked by cost/(1 - sel) using
    the pass rates observed over the last `_REOPT_WINDOW` chunks (falling
    back to the planner's estimate for units with no local observations
    yet) — windowed, not cumulative, so a drift mid-stream overturns the
    stale order within a few chunks.  Only local observations feed the
    ranking — shared-store reads mid-query would make results depend on
    concurrent queries.

    Reordering commutes (conjunctive selects over the same base rows) and
    output columns are re-projected to the declared schema, so emitted
    rows are byte-identical to any fixed order.  Units run synchronously
    per chunk: a unit's pass mask must resolve before the next unit sees
    its survivors, and the chunk's observations feed the next re-rank."""
    name = "SemanticSelectStack"

    def __init__(self, child: PhysicalOp, units: List[_StackUnit],
                 predict_factory, absorber, stats_store, out_schema):
        super().__init__(out_schema)
        self.child = child
        self.units = units              # execution order: innermost first
        self.predict_factory = predict_factory
        self.absorber = absorber
        self.stats_store = stats_store
        self.children = [child]
        self.reranks = 0
        self.rerank_log: List[str] = []

    def _rank_order(self) -> List[int]:
        from repro.core.stats import order_rank
        return sorted(
            range(len(self.units)),
            key=lambda i: (order_rank(self.units[i].cost,
                                      self.units[i].observed_sel()), i))

    def _produce(self):
        ops = [self.predict_factory(u.info) for u in self.units]
        order = list(range(len(self.units)))
        chunk_no = 0
        try:
            for c in self.child.chunks():
                chunk_no += 1
                cur = c
                for i in order:
                    if len(cur) == 0:
                        break
                    u, op = self.units[i], ops[i]
                    out = op.resolve(op.submit(cur))
                    mask = np.asarray(u.predicate.evaluate(out), bool)
                    passed = int(mask.sum())
                    if self.stats_store is not None and u.key is not None \
                            and len(out):
                        self.stats_store.record_predicate(
                            u.key, len(out), passed)
                    if len(out):
                        u.observe(len(out), passed)
                    cur = out.mask(mask)
                if len(cur):
                    # later units append their columns in execution order;
                    # re-project to the declared schema so emitted rows are
                    # identical no matter how the stack was ranked
                    yield cur.select(list(self.out_schema))
                new_order = self._rank_order()
                if new_order != order:
                    self.reranks += 1
                    sels = ", ".join(
                        f"{self.units[i].label()}="
                        f"{self.units[i].observed_sel():.3f}"
                        for i in new_order)
                    self.rerank_log.append(
                        f"chunk {chunk_no}: re-ranked to "
                        f"[{' -> '.join(self.units[i].label() for i in new_order)}]"
                        f" (observed {sels})")
                    order = new_order
        finally:
            if self.absorber is not None:
                for op in ops:
                    self.absorber._absorb(op)
                note = getattr(self.absorber, "_note_reranks", None)
                if note is not None:
                    note(self.reranks, list(self.rerank_log))

    def describe(self):
        labels = ", ".join(u.label() for u in self.units)
        return (f"SemanticSelectStack[{labels}] "
                f"(chunk-level re-rank on observed selectivity)")


class PredictScanOp(PhysicalOp):
    """Table generation (rho^s): the model IS the scan."""
    name = "PredictScan"

    def __init__(self, info: PredictInfo, predict_factory, absorber,
                 out_schema):
        super().__init__(out_schema)
        self.info = info
        self.predict_factory = predict_factory
        self.absorber = absorber
        self.children = []

    def _produce(self):
        op = self.predict_factory(self.info)
        try:
            yield op.scan()
        finally:
            if self.absorber is not None:
                self.absorber._absorb(op)

    def describe(self):
        return f"PredictScan[{self.info.model_name}] out={self.info.out_cols}"


class SemanticJoinOp(PhysicalOp):
    """Streaming block-nested-loop semantic join (R x S -> predicate).

    The inputs are drained (O(|L| + |R|)), but the cross product never
    exists: windows of at most `window` cross rows are constructed by index
    arithmetic, pushed through the shared predict operator, filtered on the
    boolean output, and emitted. Peak intermediate size is `window`, not
    |L| x |R|."""
    name = "SemanticJoin"

    def __init__(self, left: PhysicalOp, right: PhysicalOp,
                 info: PredictInfo, predict_factory, absorber,
                 window: int, out_schema, stats_probe=None):
        super().__init__(out_schema)
        self.left = left
        self.right = right
        self.info = info
        self.predict_factory = predict_factory
        self.absorber = absorber
        self.window = max(1, int(window))
        self.stats_probe = stats_probe
        self.children = [left, right]

    def _produce(self):
        l = self._drain(self.left)
        r = self._drain(self.right)
        total = len(l) * len(r)
        if total == 0:
            return
        op = self.predict_factory(self.info)
        inflight = _inflight_windows(op)
        drop = set(self.info.out_cols)

        def emit(pc):
            out = op.resolve(pc)
            flag = out.column(self.info.out_cols[0])
            kept = out.mask(np.array([bool(x) for x in flag]))
            if self.stats_probe is not None and len(out):
                store, key = self.stats_probe
                store.record_predicate(key, len(out), len(kept))
            # semantic-join output schema = input schemas only (§3.3)
            return kept.select([c for c in kept.column_names
                                if c not in drop])

        pending = []
        try:
            # window N+1's inference is submitted (and batch-dispatched)
            # while window N's survivors flow downstream
            for s in range(0, total, self.window):
                idx = np.arange(s, min(s + self.window, total))
                chunk = _merge_sides(l.take(idx // len(r)),
                                     r.take(idx % len(r)))
                pending.append(op.submit(chunk))
                op.kick()              # overlap dispatch with window build
                while len(pending) >= inflight:
                    kept = emit(pending.pop(0))
                    if len(kept):
                        yield kept
            while pending:
                kept = emit(pending.pop(0))
                if len(kept):
                    yield kept
        finally:
            for pc in pending:             # closed early (e.g. Limit)
                op.cancel(pc)
            if self.absorber is not None:
                self.absorber._absorb(op)

    def describe(self):
        est = self.info.options.get("est_cross_rows")
        e = f" est_cross={est:.0f}" if est is not None else ""
        e += _cascade_note(self.info)
        return (f"StreamingSemanticJoin[{self.info.model_name}] "
                f"window={self.window}{e}")


# ---------------------------------------------------------------------------
# lowering: logical Node -> PhysicalOp tree
# ---------------------------------------------------------------------------
def lower(node: Node, cat, predict_factory: Callable, chunk_size: int,
          absorber=None, stats_store=None,
          cancel_scope: Optional[CancelScope] = None) -> PhysicalOp:
    """Lowering pass. `absorber` (usually the PlanExecutor) receives every
    PredictOperator's stats exactly once, when its owning op closes.
    Chunk/window sizes are capped by the optimizer's cardinality
    annotations (est_* in PredictInfo.options) where available.  When a
    `stats_store` is given, semantic-select filters and semantic joins get
    probes that record observed predicate selectivity into it.

    Early-exit Limit: a Limit caps the chunk/window size of its STREAMING
    subtree (Scan chunks, SemanticJoin windows) to
    `min(chunk_size, max(LIMIT_CHUNK_FLOOR, n))`, so the pipeline under a
    `LIMIT n` produces (and the predict operators dispatch) work in
    limit-sized windows instead of full 2048-row chunks — once the limit
    is satisfied the close() unwinds and the still-queued windows are
    cancelled before any flush dispatches them.  The cap stops at
    pipeline breakers (sort/group-by/join build sides drain their input
    regardless, so fragmenting their children would only shrink dispatch
    batches without saving a call).

    `cancel_scope` (front-door sessions) is stamped on every operator so
    a cancel is observed at the next chunk boundary anywhere in the
    tree."""
    from repro.core.stats import stats_key
    from repro.relational.expr import find_predicts

    def _semantic_probe(n: Filter):
        """(store, key) when this Filter realizes the semantic select of
        the Predict directly below it."""
        if stats_store is None or not isinstance(n.child, Predict):
            return None
        cols = set()
        for e in [n.predicate]:
            cols |= set(e.columns())
            cols |= {p.resolved_col for p in find_predicts(e)
                     if p.resolved_col}
        if cols & set(n.child.info.out_cols):
            return (stats_store, stats_key(n.child.info))
        return None

    def _reopt_stack(n: Filter):
        """([(Filter, Predict), ...] outermost-first, base) when `n` heads
        a stack of >=2 semantic-select units the optimizer stamped as
        reorderable (`reopt` marker); None otherwise."""
        units = []
        cur: Node = n
        while (isinstance(cur, Filter) and isinstance(cur.child, Predict)
               and cur.child.child is not None
               and bool(cur.child.info.options.get("reopt"))):
            units.append((cur, cur.child))
            cur = cur.child.child
        if len(units) < 2:
            return None
        return units, cur

    def _eff_chunk(cap: Optional[int]) -> int:
        if cap is None:
            return chunk_size
        return max(1, min(chunk_size, max(LIMIT_CHUNK_FLOOR, cap)))

    def rec(n: Node, cap: Optional[int] = None) -> PhysicalOp:
        op = build(n, cap)
        op.scope = cancel_scope
        return op

    def build(n: Node, cap: Optional[int]) -> PhysicalOp:
        sch = n.schema(cat)
        if isinstance(n, Scan):
            return ScanOp(cat.table(n.table), n.table, _eff_chunk(cap), sch)
        if isinstance(n, Filter):
            stack = _reopt_stack(n)
            if stack is not None:
                units, base = stack
                return SemanticSelectStackOp(
                    rec(base, cap),
                    [_StackUnit(p.info, f.predicate, stats_key(p.info))
                     for f, p in reversed(units)],   # innermost runs first
                    predict_factory, absorber, stats_store, sch)
            return FilterOp(rec(n.child, cap), n.predicate, sch,
                            stats_probe=_semantic_probe(n))
        if isinstance(n, Project):
            return ProjectOp(rec(n.child, cap), n.exprs, sch)
        if isinstance(n, Join):
            if n.kind == "cross" or not n.left_keys:
                return CrossJoinOp(rec(n.left), rec(n.right), n.extra,
                                   chunk_size, sch)
            return HashJoinOp(rec(n.left), rec(n.right), n.left_keys,
                              n.right_keys, n.extra, chunk_size, sch)
        if isinstance(n, GroupBy):
            return GroupByOp(rec(n.child), n.keys, n.aggs,
                             getattr(n, "llm_agg_infos", {}),
                             predict_factory, absorber, sch)
        if isinstance(n, OrderBy):
            return OrderByOp(rec(n.child), n.keys, chunk_size, sch)
        if isinstance(n, Limit):
            tighter = n.n if cap is None else min(cap, n.n)
            return LimitOp(rec(n.child, tighter), n.n, sch)
        if isinstance(n, Predict):
            if n.child is None:
                return PredictScanOp(n.info, predict_factory, absorber, sch)
            return PredictOp(rec(n.child, cap), n.info, predict_factory,
                             absorber, sch)
        if isinstance(n, SemanticJoin):
            window = _eff_chunk(cap)
            est = n.info.options.get("est_cross_rows")
            if est is not None and np.isfinite(est):
                # never fragment below a useful floor; only shrink the
                # window when the estimate says the cross product is small
                window = min(window, max(256, int(math.ceil(est))))
            probe = (stats_store, stats_key(n.info)) \
                if stats_store is not None else None
            return SemanticJoinOp(rec(n.left), rec(n.right), n.info,
                                  predict_factory, absorber, window, sch,
                                  stats_probe=probe)
        raise TypeError(f"cannot lower {type(n).__name__}")
    return rec(node)


def physical_repr(op: PhysicalOp, indent: int = 0) -> str:
    lines = ["  " * indent + op.describe()]
    for c in op.children:
        lines.append(physical_repr(c, indent + 1))
    return "\n".join(lines)
