"""Recursive-descent parser for iPDB's extended SQL (paper §3).

Supported statements:
  CREATE LLM MODEL name PATH 'id' [ON PROMPT] [API 'url'] [OPTIONS {..}]
  CREATE TABULAR MODEL name PATH 'p' ON TABLE t FEATURES (c,..) OUTPUT (c T,..)
  CREATE TABLE name AS <select>
  SET key = value
  SELECT <exprs> FROM <relation> [JOIN <relation> ON <cond>]*
      [WHERE <cond>] [GROUP BY cols] [ORDER BY expr [ASC|DESC],..] [LIMIT n]

Relations: table [AS alias] | LLM model (PROMPT '...'[, table]) [AS alias]
           | PREDICT model (table) [AS alias]
Expressions may contain LLM model (PROMPT '...') scalar-inference calls and
LLM AGG model (PROMPT '...') semantic aggregates (§3.2).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.relational.expr import (BinOp, Col, Expr, Lit, Not, PredictExpr,
                                   PromptTemplate)

_TOKEN_RE = re.compile(r"""
    \s*(?:
      (?P<str>'(?:[^']|'')*')
    | (?P<num>-?\d+\.\d+|-?\d+)
    | (?P<op><=|>=|!=|<>|=|<|>|\{|\}|\(|\)|,|\.|\*|\+|-|/|;|:)
    | (?P<word>[A-Za-z_][A-Za-z_0-9]*)
    )""", re.VERBOSE)

KEYWORDS = {"SELECT", "FROM", "WHERE", "GROUP", "ORDER", "BY", "LIMIT", "AS",
            "JOIN", "ON", "AND", "OR", "NOT", "LIKE", "CREATE", "TABLE",
            "MODEL", "LLM", "TABULAR", "PREDICT", "PROMPT", "PATH", "API",
            "OPTIONS", "FEATURES", "OUTPUT", "SET", "ASC", "DESC", "NATURAL",
            "AGG", "TRUE", "FALSE", "DISTINCT", "DROP", "EMBED", "INSERT",
            "WITH"}


@dataclasses.dataclass
class Tok:
    kind: str      # str | num | op | word
    text: str


def tokenize(sql: str) -> List[Tok]:
    out, i = [], 0
    while i < len(sql):
        m = _TOKEN_RE.match(sql, i)
        if not m:
            if sql[i:].strip() == "":
                break
            raise SyntaxError(f"cannot tokenize at: {sql[i:i+30]!r}")
        i = m.end()
        for kind in ("str", "num", "op", "word"):
            t = m.group(kind)
            if t is not None:
                out.append(Tok(kind, t))
                break
    return out


# -------------------------------- AST ----------------------------------------
@dataclasses.dataclass
class RelRef:
    kind: str                         # table | llm | predict
    name: str = ""                    # table name or model name
    alias: Optional[str] = None
    prompt: Optional[str] = None
    source: Optional["RelRef"] = None  # input relation for llm/predict
    # per-expression options (WITH (k=v, ...)); merged over model OPTIONS
    options: Dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class JoinClause:
    rel: RelRef
    natural: bool = False
    on: Optional[Expr] = None


@dataclasses.dataclass
class SelectStmt:
    select: List[Tuple[Optional[str], Expr]]   # (alias, expr); ('*', None)
    star: bool = False
    from_rel: Optional[RelRef] = None
    joins: List[JoinClause] = dataclasses.field(default_factory=list)
    where: Optional[Expr] = None
    group_by: List[str] = dataclasses.field(default_factory=list)
    order_by: List[Tuple[Expr, bool]] = dataclasses.field(default_factory=list)
    limit: Optional[int] = None


@dataclasses.dataclass
class CreateModel:
    name: str
    model_type: str                   # LLM | TABULAR
    path: str
    on_prompt: bool = True
    api: Optional[str] = None
    relation: Optional[str] = None
    features: Optional[List[str]] = None
    output: Optional[List[Tuple[str, str]]] = None
    options: Dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class CreateTableAs:
    name: str
    select: SelectStmt


@dataclasses.dataclass
class SetStmt:
    key: str
    value: object


class Parser:
    def __init__(self, sql: str):
        self.toks = tokenize(sql)
        self.i = 0

    # -- helpers ------------------------------------------------------------
    def peek(self, k: int = 0) -> Optional[Tok]:
        return self.toks[self.i + k] if self.i + k < len(self.toks) else None

    def at_word(self, *words: str) -> bool:
        t = self.peek()
        return t is not None and t.kind == "word" and t.text.upper() in words

    def eat(self) -> Tok:
        t = self.toks[self.i]
        self.i += 1
        return t

    def expect_word(self, w: str) -> None:
        t = self.eat()
        if t.kind != "word" or t.text.upper() != w:
            raise SyntaxError(f"expected {w}, got {t.text!r}")

    def expect_op(self, op: str) -> None:
        t = self.eat()
        if t.kind != "op" or t.text != op:
            raise SyntaxError(f"expected {op!r}, got {t.text!r}")

    def try_op(self, op: str) -> bool:
        t = self.peek()
        if t and t.kind == "op" and t.text == op:
            self.i += 1
            return True
        return False

    def ident(self) -> str:
        t = self.eat()
        if t.kind != "word":
            raise SyntaxError(f"expected identifier, got {t.text!r}")
        name = t.text
        while self.try_op("."):
            name += "." + self.eat().text
        return name

    def string(self) -> str:
        t = self.eat()
        if t.kind != "str":
            raise SyntaxError(f"expected string, got {t.text!r}")
        return t.text[1:-1].replace("''", "'")

    def _with_options(self) -> Dict[str, object]:
        """Optional per-expression options: WITH (k = v, ...).  Values:
        numbers, strings, TRUE/FALSE, bare identifiers (e.g. model
        names).  Used for e.g. PREDICT ... WITH
        (cascade_target_precision = 0.95)."""
        opts: Dict[str, object] = {}
        if not self.at_word("WITH"):
            return opts
        self.eat()
        self.expect_op("(")
        while not self.try_op(")"):
            k = self.ident()
            self.expect_op("=")
            t = self.eat()
            v: object
            if t.kind == "num":
                v = float(t.text) if "." in t.text else int(t.text)
            elif t.kind == "str":
                v = t.text[1:-1]
            elif t.kind == "word" and t.text.upper() in ("TRUE", "FALSE"):
                v = t.text.upper() == "TRUE"
            else:
                v = t.text
            opts[k] = v
            self.try_op(",")
        return opts

    # -- statements ----------------------------------------------------------
    def parse(self):
        if self.at_word("CREATE"):
            return self._create()
        if self.at_word("SET"):
            return self._set()
        if self.at_word("SELECT"):
            return self._select()
        raise SyntaxError(f"unsupported statement start: {self.peek().text!r}")

    def _set(self) -> SetStmt:
        self.expect_word("SET")
        key = self.ident()
        self.expect_op("=")
        t = self.eat()
        val: object
        if t.kind == "num":
            val = float(t.text) if "." in t.text else int(t.text)
        elif t.kind == "str":
            val = t.text[1:-1]
        else:
            val = t.text
        return SetStmt(key, val)

    def _create(self):
        self.expect_word("CREATE")
        if self.at_word("TABLE"):
            self.eat()
            name = self.ident()
            self.expect_word("AS")
            return CreateTableAs(name, self._select())
        mtype = self.eat().text.upper()           # LLM | TABULAR | EMBED
        self.expect_word("MODEL")
        name = self.ident()
        cm = CreateModel(name=name, model_type=mtype, path="")
        while self.peek() is not None and not (self.peek().kind == "op"
                                               and self.peek().text == ";"):
            if self.at_word("PATH"):
                self.eat()
                cm.path = self.string()
            elif self.at_word("ON"):
                self.eat()
                if self.at_word("PROMPT"):
                    self.eat()
                    cm.on_prompt = True
                    if self.at_word("API"):
                        self.eat()
                        cm.api = self.string()
                elif self.at_word("TABLE"):
                    self.eat()
                    cm.relation = self.ident()
                    cm.on_prompt = False
            elif self.at_word("API"):
                self.eat()
                cm.api = self.string()
            elif self.at_word("FEATURES"):
                self.eat()
                self.expect_op("(")
                cm.features = []
                while True:
                    cm.features.append(self.ident())
                    if not self.try_op(","):
                        break
                self.expect_op(")")
                cm.on_prompt = False
            elif self.at_word("OUTPUT"):
                self.eat()
                self.expect_op("(")
                cm.output = []
                while True:
                    n = self.ident()
                    ty = self.eat().text.upper()
                    cm.output.append((n, ty))
                    if not self.try_op(","):
                        break
                self.expect_op(")")
            elif self.at_word("OPTIONS"):
                self.eat()
                self.expect_op("{")
                while not self.try_op("}"):
                    k = self.string() if self.peek().kind == "str" else self.ident()
                    self.expect_op(":")
                    t = self.eat()
                    v: object
                    if t.kind == "num":
                        v = float(t.text) if "." in t.text else int(t.text)
                    elif t.kind == "str":
                        v = t.text[1:-1]
                    else:
                        v = t.text
                    cm.options[k] = v
                    self.try_op(",")
            else:
                raise SyntaxError(f"unexpected token {self.peek().text!r} in CREATE MODEL")
        return cm

    # -- SELECT ----------------------------------------------------------------
    def _select(self) -> SelectStmt:
        self.expect_word("SELECT")
        stmt = SelectStmt(select=[])
        if self.try_op("*"):
            stmt.star = True
        else:
            while True:
                e = self._expr()
                alias = None
                if self.at_word("AS"):
                    self.eat()
                    alias = self.ident()
                stmt.select.append((alias, e))
                if not self.try_op(","):
                    break
        if self.at_word("FROM"):
            self.eat()
            stmt.from_rel = self._relref()
            while self.at_word("JOIN", "NATURAL"):
                natural = False
                if self.at_word("NATURAL"):
                    self.eat()
                    natural = True
                self.expect_word("JOIN")
                rel = self._relref()
                on = None
                if self.at_word("ON"):
                    self.eat()
                    on = self._expr()
                stmt.joins.append(JoinClause(rel, natural, on))
        if self.at_word("WHERE"):
            self.eat()
            stmt.where = self._expr()
        if self.at_word("GROUP"):
            self.eat()
            self.expect_word("BY")
            while True:
                stmt.group_by.append(self.ident())
                if not self.try_op(","):
                    break
        if self.at_word("ORDER"):
            self.eat()
            self.expect_word("BY")
            while True:
                e = self._expr()
                asc = True
                if self.at_word("ASC", "DESC"):
                    asc = self.eat().text.upper() == "ASC"
                stmt.order_by.append((e, asc))
                if not self.try_op(","):
                    break
        if self.at_word("LIMIT"):
            self.eat()
            stmt.limit = int(self.eat().text)
        return stmt

    def _relref(self) -> RelRef:
        if self.at_word("LLM", "PREDICT"):
            kind = self.eat().text.lower()
            model = self.ident()
            self.expect_op("(")
            prompt = None
            source = None
            if self.at_word("PROMPT"):
                self.eat()
                prompt = self.string()
                if self.try_op(","):
                    source = self._relref()
            else:
                source = self._relref()
            self.expect_op(")")
            opts = self._with_options()
            alias = None
            if self.at_word("AS"):
                self.eat()
                alias = self.ident()
            return RelRef(kind="llm" if kind == "llm" else "predict",
                          name=model, alias=alias, prompt=prompt,
                          source=source, options=opts)
        name = self.ident()
        alias = None
        if self.at_word("AS"):
            self.eat()
            alias = self.ident()
        elif self.peek() and self.peek().kind == "word" and \
                self.peek().text.upper() not in KEYWORDS:
            alias = self.eat().text
        return RelRef(kind="table", name=name, alias=alias)

    # -- expressions -------------------------------------------------------------
    def _expr(self) -> Expr:
        return self._or()

    def _or(self) -> Expr:
        e = self._and()
        while self.at_word("OR"):
            self.eat()
            e = BinOp("OR", e, self._and())
        return e

    def _and(self) -> Expr:
        e = self._not()
        while self.at_word("AND"):
            self.eat()
            e = BinOp("AND", e, self._not())
        return e

    def _not(self) -> Expr:
        if self.at_word("NOT"):
            self.eat()
            return Not(self._not())
        return self._cmp()

    def _cmp(self) -> Expr:
        e = self._add()
        t = self.peek()
        if t and t.kind == "op" and t.text in ("=", "!=", "<>", "<", ">", "<=", ">="):
            op = self.eat().text
            if op == "<>":
                op = "!="
            return BinOp(op, e, self._add())
        if self.at_word("LIKE"):
            self.eat()
            return BinOp("LIKE", e, self._add())
        return e

    def _add(self) -> Expr:
        e = self._mul()
        while True:
            t = self.peek()
            if t and t.kind == "op" and t.text in ("+", "-"):
                op = self.eat().text
                e = BinOp(op, e, self._mul())
            else:
                return e

    def _mul(self) -> Expr:
        e = self._atom()
        while True:
            t = self.peek()
            if t and t.kind == "op" and t.text in ("*", "/"):
                op = self.eat().text
                e = BinOp(op, e, self._atom())
            else:
                return e

    def _atom(self) -> Expr:
        t = self.peek()
        if t is None:
            raise SyntaxError("unexpected end of input")
        if t.kind == "op" and t.text == "(":
            self.eat()
            e = self._expr()
            self.expect_op(")")
            return e
        if t.kind == "str":
            return Lit(self.string())
        if t.kind == "num":
            self.eat()
            return Lit(float(t.text) if "." in t.text else int(t.text))
        if self.at_word("TRUE"):
            self.eat()
            return Lit(True)
        if self.at_word("FALSE"):
            self.eat()
            return Lit(False)
        if self.at_word("LLM", "PREDICT"):
            self.eat()
            agg = False
            if self.at_word("AGG"):
                self.eat()
                agg = True
            model = self.ident()
            self.expect_op("(")
            prompt = None
            if self.at_word("PROMPT"):
                self.eat()
                prompt = self.string()
            self.expect_op(")")
            opts = self._with_options()
            pt = PromptTemplate.parse(prompt) if prompt else None
            return PredictExpr(model_name=model, prompt=pt, agg=agg,
                               options=opts)
        # function call or column
        name = self.ident()
        if self.try_op("("):
            args = []
            if not self.try_op(")"):
                if self.try_op("*"):
                    args.append(Lit("*"))
                else:
                    while True:
                        args.append(self._expr())
                        if not self.try_op(","):
                            break
                self.expect_op(")")
            return FuncCall(name.lower(), args)
        return Col(name)


@dataclasses.dataclass
class FuncCall(Expr):
    """Aggregate or scalar function reference (resolved by the planner)."""
    name: str
    args: List[Expr]

    def columns(self):
        out = []
        for a in self.args:
            out.extend(a.columns())
        return out

    def evaluate(self, t):
        raise RuntimeError(f"unresolved function {self.name} at execution")

    def sql_type(self, schema):
        if self.name in ("count",):
            return "INTEGER"
        if self.name in ("sum", "avg"):
            return "DOUBLE"
        if self.args:
            return self.args[0].sql_type(schema)
        return "VARCHAR"


def parse_sql(sql: str):
    """Parse one statement (trailing ';' tolerated)."""
    p = Parser(sql)
    stmt = p.parse()
    if p.peek() is not None and not (p.peek().kind == "op"
                                     and p.peek().text == ";"):
        raise SyntaxError(f"trailing tokens: {p.peek().text!r}")
    return stmt
