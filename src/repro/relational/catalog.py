"""System catalogs: tables, models (paper Table 2), secrets."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.relational.table import Table


@dataclasses.dataclass
class ModelEntry:
    """One row of the model catalog (paper §4.1, Table 2)."""
    name: str
    path: str
    type: str                       # LLM | TABULAR | EMBED
    on_prompt: bool = True
    base_api: Optional[str] = None
    secret: Optional[str] = None
    relation: Optional[str] = None
    input_set: Optional[List[str]] = None
    output_set: Optional[List[Tuple[str, str]]] = None
    options: Dict[str, object] = dataclasses.field(default_factory=dict)


class Catalog:
    def __init__(self):
        self._tables: Dict[str, Table] = {}
        self._models: Dict[str, ModelEntry] = {}
        self._secrets: Dict[str, str] = {}

    # -- tables -------------------------------------------------------------
    def register_table(self, name: str, t: Table) -> None:
        self._tables[name.lower()] = t

    def table(self, name: str) -> Table:
        key = name.lower()
        if key not in self._tables:
            raise KeyError(f"unknown table {name!r}")
        return self._tables[key]

    def has_table(self, name: str) -> bool:
        return name.lower() in self._tables

    def drop_table(self, name: str) -> None:
        self._tables.pop(name.lower(), None)

    @property
    def tables(self) -> List[str]:
        return list(self._tables)

    # -- models -------------------------------------------------------------
    def register_model(self, entry: ModelEntry) -> None:
        self._models[entry.name.lower()] = entry

    def model(self, name: str) -> ModelEntry:
        key = name.lower()
        if key not in self._models:
            raise KeyError(
                f"unknown model {name!r} — run CREATE LLM MODEL first")
        return self._models[key]

    def has_model(self, name: str) -> bool:
        return name.lower() in self._models

    @property
    def models(self) -> List[str]:
        return list(self._models)

    # -- secrets ------------------------------------------------------------
    def register_secret(self, name: str, value: str) -> None:
        self._secrets[name.lower()] = value

    def secret(self, name: str) -> Optional[str]:
        return self._secrets.get(name.lower())
