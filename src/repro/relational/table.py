"""Columnar in-memory tables (DuckDB-analog storage layer).

Columns are numpy arrays: numeric dtypes for INTEGER/DOUBLE/BOOLEAN, object
arrays of python str (or None) for VARCHAR/DATETIME. NULL = None (object
cols) / np.nan (DOUBLE) / sentinel-masked (INTEGER uses a parallel validity
convention: NULL stored as the masked `None` in object form only when the
column was produced by a failed prediction — predict outputs promote
INTEGER→float with nan for missing).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

SQL_TYPES = ("VARCHAR", "INTEGER", "DOUBLE", "BOOLEAN", "DATETIME")


def _np_for(sql_type: str):
    t = sql_type.upper()
    if t == "INTEGER":
        return np.int64
    if t == "DOUBLE":
        return np.float64
    if t == "BOOLEAN":
        return np.bool_
    return object              # VARCHAR / DATETIME


@dataclasses.dataclass
class Column:
    name: str
    type: str


class Table:
    def __init__(self, columns: Dict[str, np.ndarray],
                 schema: Optional[Dict[str, str]] = None):
        self.cols: Dict[str, np.ndarray] = {}
        self.schema: Dict[str, str] = {}
        n = None
        for k, v in columns.items():
            a = np.asarray(v)
            if n is None:
                n = len(a)
            assert len(a) == n, f"ragged column {k}"
            self.cols[k] = a
            if schema and k in schema:
                self.schema[k] = schema[k].upper()
            else:
                self.schema[k] = _infer_type(a)
        self._n = n or 0

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_rows(rows: Sequence[dict], schema: Optional[Dict[str, str]] = None
                  ) -> "Table":
        if not rows:
            return Table({k: np.array([], dtype=_np_for(t))
                          for k, t in (schema or {}).items()}, schema)
        keys = list(rows[0].keys())
        cols = {}
        for k in keys:
            vals = [r.get(k) for r in rows]
            t = (schema or {}).get(k) or _infer_type_vals(vals)
            cols[k] = _coerce(vals, t)
        return Table(cols, schema or {k: _infer_type_vals([r.get(k) for r in rows])
                                      for k in keys})

    # -- basics -------------------------------------------------------------
    def __len__(self) -> int:
        return self._n

    @property
    def num_rows(self) -> int:
        return self._n

    @property
    def column_names(self) -> List[str]:
        return list(self.cols.keys())

    def column(self, name: str) -> np.ndarray:
        return self.cols[name]

    def row(self, i: int) -> dict:
        return {k: _pyval(v[i]) for k, v in self.cols.items()}

    def rows(self) -> List[dict]:
        return [self.row(i) for i in range(self._n)]

    def select(self, names: Sequence[str]) -> "Table":
        return Table({n: self.cols[n] for n in names},
                     {n: self.schema[n] for n in names})

    def rename(self, mapping: Dict[str, str]) -> "Table":
        return Table({mapping.get(k, k): v for k, v in self.cols.items()},
                     {mapping.get(k, k): t for k, t in self.schema.items()})

    def take(self, idx: np.ndarray) -> "Table":
        return Table({k: v[idx] for k, v in self.cols.items()}, dict(self.schema))

    def mask(self, m: np.ndarray) -> "Table":
        return self.take(np.nonzero(np.asarray(m, bool))[0])

    def with_column(self, name: str, values: np.ndarray, sql_type: str) -> "Table":
        cols = dict(self.cols)
        sch = dict(self.schema)
        cols[name] = _coerce(list(values), sql_type) \
            if not isinstance(values, np.ndarray) else values
        sch[name] = sql_type.upper()
        return Table(cols, sch)

    def concat(self, other: "Table") -> "Table":
        assert self.column_names == other.column_names
        return Table({k: np.concatenate([self.cols[k], other.cols[k]])
                      for k in self.cols}, dict(self.schema))

    def slice(self, start: int, stop: int) -> "Table":
        return Table({k: v[start:stop] for k, v in self.cols.items()},
                     dict(self.schema))

    def head_repr(self, n: int = 8) -> str:
        names = self.column_names
        lines = [" | ".join(names)]
        for i in range(min(n, self._n)):
            lines.append(" | ".join(str(_pyval(self.cols[c][i]))[:40]
                                    for c in names))
        lines.append(f"({self._n} rows)")
        return "\n".join(lines)

    def __repr__(self):
        return f"Table({self.column_names}, rows={self._n})"


def _pyval(x):
    if isinstance(x, np.generic):
        return x.item()
    return x


def _infer_type(a: np.ndarray) -> str:
    if a.dtype == np.bool_:
        return "BOOLEAN"
    if np.issubdtype(a.dtype, np.integer):
        return "INTEGER"
    if np.issubdtype(a.dtype, np.floating):
        return "DOUBLE"
    return "VARCHAR"


def _infer_type_vals(vals) -> str:
    for v in vals:
        if v is None:
            continue
        if isinstance(v, bool):
            return "BOOLEAN"
        if isinstance(v, int):
            return "INTEGER"
        if isinstance(v, float):
            return "DOUBLE"
        return "VARCHAR"
    return "VARCHAR"


def _coerce(vals: list, sql_type: str) -> np.ndarray:
    t = sql_type.upper()
    if t == "INTEGER":
        if any(v is None for v in vals):
            return np.array([np.nan if v is None else float(v) for v in vals])
        return np.array([int(v) for v in vals], np.int64)
    if t == "DOUBLE":
        return np.array([np.nan if v is None else float(v) for v in vals],
                        np.float64)
    if t == "BOOLEAN":
        return np.array([bool(v) for v in vals], np.bool_)
    return np.array([None if v is None else str(v) for v in vals], object)
