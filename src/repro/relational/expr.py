"""Expression AST + vectorized evaluation over Tables.

`PredictExpr` is the unified PGPredictExpr node of the paper (§4.2): it
appears wherever an expression may (WHERE / SELECT / GROUP BY / ORDER BY /
JOIN ON) and wherever a relation may (FROM → table inference / generation).
Its evaluation is NOT done here — the planner turns it into a
Logical/Physical Predict operator; by execution time the predicted column
already exists and the expression has been rewritten to a Col reference.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.relational.table import Table


class Expr:
    def columns(self) -> List[str]:
        raise NotImplementedError

    def evaluate(self, t: Table) -> np.ndarray:
        raise NotImplementedError

    def sql_type(self, schema: Dict[str, str]) -> str:
        return "VARCHAR"


@dataclasses.dataclass
class Col(Expr):
    name: str

    def columns(self):
        return [self.name]

    def evaluate(self, t: Table):
        return t.column(self.name)

    def sql_type(self, schema):
        return schema.get(self.name, "VARCHAR")

    def __repr__(self):
        return f"Col({self.name})"


@dataclasses.dataclass
class Lit(Expr):
    value: object

    def columns(self):
        return []

    def evaluate(self, t: Table):
        return np.full(len(t), self.value,
                       dtype=object if isinstance(self.value, str) else None)

    def sql_type(self, schema):
        if isinstance(self.value, bool):
            return "BOOLEAN"
        if isinstance(self.value, int):
            return "INTEGER"
        if isinstance(self.value, float):
            return "DOUBLE"
        return "VARCHAR"

    def __repr__(self):
        return f"Lit({self.value!r})"


@dataclasses.dataclass
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr

    def columns(self):
        return self.left.columns() + self.right.columns()

    def evaluate(self, t: Table):
        l = self.left.evaluate(t)
        r = self.right.evaluate(t)
        op = self.op
        if op in ("AND", "OR"):
            l = np.asarray(l, bool)
            r = np.asarray(r, bool)
            return l & r if op == "AND" else l | r
        if op == "LIKE":
            pat = re.escape(str(self.right.value)).replace("%", ".*") \
                .replace(r"\%", ".*").replace("_", ".")
            rx = re.compile(f"^{pat}$", re.IGNORECASE)
            return np.array([bool(rx.match(str(x))) if x is not None else False
                             for x in l])
        if l.dtype == object or (hasattr(r, "dtype") and r.dtype == object):
            lc = np.array([None if x is None else str(x) for x in l], object)
            rc = np.array([None if x is None else str(x) for x in
                           (r if hasattr(r, "__len__") else [r] * len(l))],
                          object)
            cmp = {"=": lambda a, b: a == b, "!=": lambda a, b: a != b,
                   "<": lambda a, b: a < b, ">": lambda a, b: a > b,
                   "<=": lambda a, b: a <= b, ">=": lambda a, b: a >= b}[op]
            return np.array([False if (a is None or b is None) else cmp(a, b)
                             for a, b in zip(lc, rc)])
        fn = {"=": np.equal, "!=": np.not_equal, "<": np.less,
              ">": np.greater, "<=": np.less_equal, ">=": np.greater_equal,
              "+": np.add, "-": np.subtract, "*": np.multiply,
              "/": np.divide}[op]
        return fn(l, r)

    def sql_type(self, schema):
        if self.op in ("AND", "OR", "=", "!=", "<", ">", "<=", ">=", "LIKE"):
            return "BOOLEAN"
        lt = self.left.sql_type(schema)
        rt = self.right.sql_type(schema)
        return "DOUBLE" if "DOUBLE" in (lt, rt) or self.op == "/" else lt

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"


@dataclasses.dataclass
class Not(Expr):
    child: Expr

    def columns(self):
        return self.child.columns()

    def evaluate(self, t: Table):
        return ~np.asarray(self.child.evaluate(t), bool)

    def sql_type(self, schema):
        return "BOOLEAN"


# ------------------------------ prompts --------------------------------------
_IN_RE = re.compile(r"\{\{\s*([\w.]+)\s*\}\}")
_OUT_RE = re.compile(r"\{\s*(\w+)\s+(VARCHAR|INTEGER|INT|DOUBLE|FLOAT|BOOLEAN|"
                     r"BOOL|DATETIME|DATE)\s*\}", re.IGNORECASE)


@dataclasses.dataclass
class PromptTemplate:
    """Parsed prompt: instruction text + {{input cols}} + {out TYPE} cols
    (paper §3.2/§4.2 placeholder resolution)."""
    raw: str
    instruction: str
    inputs: List[str]
    outputs: List[Tuple[str, str]]        # (name, SQL type)

    @staticmethod
    def parse(raw: str) -> "PromptTemplate":
        inputs = _IN_RE.findall(raw)
        outputs = [(m.group(1), _norm_type(m.group(2)))
                   for m in _OUT_RE.finditer(raw)]
        instr = _IN_RE.sub(lambda m: f"<{m.group(1)}>", raw)
        instr = _OUT_RE.sub(lambda m: m.group(1), instr)
        return PromptTemplate(raw, instr, inputs, outputs)


def _norm_type(t: str) -> str:
    t = t.upper()
    return {"INT": "INTEGER", "FLOAT": "DOUBLE", "BOOL": "BOOLEAN",
            "DATE": "DATETIME"}.get(t, t)


@dataclasses.dataclass
class PredictExpr(Expr):
    """Unified inference node (paper's PGPredictExpr): resolved into a
    Predict plan operator during planning. model_name references the model
    catalog; source is the optional input relation (table inference);
    agg marks LLM AGG."""
    model_name: str
    prompt: Optional[PromptTemplate]
    source: Optional[str] = None
    agg: bool = False
    # name assigned by the planner once materialized into a column:
    resolved_col: Optional[str] = None
    # per-expression options (WITH (k=v, ...)); highest precedence in the
    # §5.3 chain: defaults < session SET < model OPTIONS < expression WITH
    options: Dict[str, object] = dataclasses.field(default_factory=dict)

    def columns(self):
        # input columns needed from the child relation
        return list(self.prompt.inputs) if self.prompt else []

    def evaluate(self, t: Table):
        if self.resolved_col is None:
            raise RuntimeError(
                "PredictExpr evaluated before planning resolved it into a "
                "predict operator — planner bug")
        return t.column(self.resolved_col)

    def sql_type(self, schema):
        if self.prompt and len(self.prompt.outputs) == 1:
            return self.prompt.outputs[0][1]
        return "VARCHAR"

    def __repr__(self):
        outs = [o for o, _ in self.prompt.outputs] if self.prompt else []
        return f"PredictExpr({self.model_name}, in={self.prompt.inputs if self.prompt else []}, out={outs})"


def walk(e: Expr):
    yield e
    for f in dataclasses.fields(e) if dataclasses.is_dataclass(e) else []:
        v = getattr(e, f.name)
        if isinstance(v, Expr):
            yield from walk(v)


def find_predicts(e: Expr) -> List[PredictExpr]:
    return [x for x in walk(e) if isinstance(x, PredictExpr)]


def replace_expr(e: Expr, old: Expr, new: Expr) -> Expr:
    if e is old:
        return new
    if dataclasses.is_dataclass(e):
        kw = {}
        changed = False
        for f in dataclasses.fields(e):
            v = getattr(e, f.name)
            if isinstance(v, Expr):
                nv = replace_expr(v, old, new)
                changed |= nv is not v
                kw[f.name] = nv
            else:
                kw[f.name] = v
        if changed:
            return type(e)(**kw)
    return e
