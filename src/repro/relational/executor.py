"""Plan executor: lowers the logical plan to the chunked physical pipeline
(`repro.relational.physical`) and drains it.

All per-operator execution logic (streaming semantic joins, vectorized
relational operators, chunk-at-a-time predict) lives in the physical layer;
this module owns lowering, result assembly and stats aggregation.
Predict/SemanticJoin nodes run through core.predict operators created by a
factory (so the database layer controls executor resolution, the
cross-query prompt cache, and stats collection).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.relational.catalog import Catalog
from repro.relational.physical import PhysicalOp, lower, physical_repr
from repro.relational.plan import Node, PredictInfo
from repro.relational.table import Table


@dataclasses.dataclass
class ExecStats:
    llm_calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    sim_latency_s: float = 0.0
    serial_latency_s: float = 0.0
    wall_s: float = 0.0
    cache_hits: int = 0
    retries: int = 0
    batch_fallbacks: int = 0
    rows_predicted: int = 0
    prompt_cache_hits: int = 0      # cross-query cache (database-owned)
    prompt_cache_misses: int = 0
    # inference-service dispatch accounting (filled per-query by IPDB from
    # the shared service's counters)
    dispatch_batches: int = 0       # complete_many executor invocations
    mean_batch_occupancy: float = 0.0   # dispatched calls / dispatch batch
    inflight_dedup_hits: int = 0    # submits that joined a pending handle
    # optimize-time pilot-sampling calls (selectivity calibration); their
    # tokens/latency are folded into the totals above, the call count is
    # kept separate so llm_calls stays the pure execution count
    pilot_calls: int = 0
    # engine-side serving accounting (jax backend): how much prefill vs
    # decode work the query actually pushed through the model, and how
    # often the shared-prefix KV memo answered instead of a prefill
    prefill_tokens: int = 0
    decode_tokens: int = 0
    prefix_hits: int = 0
    radix_hit_tokens: int = 0       # prompt tokens served from the radix tree
    # cascade accounting (CascadePredictor routes; zero for direct plans)
    proxy_calls: int = 0            # proxy-stage prompts scored
    escalated_calls: int = 0        # expensive-stage calls actually made
    cascade_rows: int = 0           # rows routed through a cascade
    escalated_rows: int = 0         # rows escalated to the expensive stage
    # front-door session accounting (zero for the plain Python API)
    cancelled: bool = False         # query ended by its CancelScope
    cancelled_requests: int = 0     # queued service requests dropped
    # mid-query re-optimization: times a SemanticSelectStackOp re-ranked
    # its remaining units on observed chunk selectivities
    reranks: int = 0
    # resilience accounting: operator-side retry/drop/degradation counts
    # come from the predict operators via _absorb; timeout/breaker shed
    # counts are service-side and filled per-query by IPDB
    transient_retries: int = 0      # resubmits after a TransientError
    deadline_drops: int = 0         # batches/retries dropped past deadline
    degraded_calls: int = 0         # cascade calls served proxy-only
    backend_timeouts: int = 0       # dispatch batches killed by call timeout
    breaker_rejections: int = 0     # requests shed by an open breaker

    @property
    def tokens(self) -> int:
        return self.in_tokens + self.out_tokens


class PlanExecutor:
    def __init__(self, catalog: Catalog,
                 predict_factory: Callable[[PredictInfo], "PredictOperator"],
                 chunk_size: int = 2048, stats_store=None,
                 cancel_scope=None):
        self.cat = catalog
        self.predict_factory = predict_factory
        self.chunk_size = chunk_size
        self.stats_store = stats_store
        self.cancel_scope = cancel_scope
        self.stats = ExecStats()
        # human-readable re-rank decisions (one line each) from stack
        # operators; EXPLAIN's `-- rewrites --` section appends them
        self.rerank_log = []

    # ------------------------------------------------------------------
    def run(self, plan: Node) -> Table:
        parts = list(self.run_chunks(plan))
        out = parts[0]
        for p in parts[1:]:
            out = out.concat(p)
        return out

    def run_chunks(self, plan: Node):
        """Streaming drain: yield result chunks as the pipeline produces
        them (the front door's entry point; `run` materializes them).
        A fired CancelScope raises QueryCancelled out of `next_chunk`; the
        `finally:` closes the tree, which cancels every pending predict
        chunk on the way down — the caller decides whether cancellation
        is an error (sql()) or a session outcome (streams)."""
        root = self.lower(plan)
        root.open()
        try:
            while True:
                chunk = root.next_chunk()
                if chunk is None:
                    break
                yield chunk
        finally:
            root.close()

    def lower(self, plan: Node) -> PhysicalOp:
        return lower(plan, self.cat, self.predict_factory, self.chunk_size,
                     absorber=self, stats_store=self.stats_store,
                     cancel_scope=self.cancel_scope)

    def physical_plan(self, plan: Node) -> str:
        """Lowered pipeline as text (operators are created lazily, so no
        model executors are loaded)."""
        return physical_repr(self.lower(plan))

    # ------------------------------------------------------------------
    def _absorb(self, op) -> None:
        s = op.stats
        self.stats.llm_calls += s.calls
        self.stats.in_tokens += s.in_tokens
        self.stats.out_tokens += s.out_tokens
        self.stats.sim_latency_s += s.sim_latency_s
        self.stats.serial_latency_s += s.serial_latency_s
        self.stats.wall_s += s.wall_s
        self.stats.cache_hits += s.cache_hits
        self.stats.retries += s.retries
        self.stats.batch_fallbacks += s.batch_fallbacks
        self.stats.rows_predicted += s.rows_in
        self.stats.prompt_cache_hits += s.pc_hits
        self.stats.prompt_cache_misses += s.pc_misses
        self.stats.prefill_tokens += s.prefill_tokens
        self.stats.decode_tokens += s.decode_tokens
        self.stats.prefix_hits += s.prefix_hits
        self.stats.radix_hit_tokens += s.radix_hit_tokens
        self.stats.proxy_calls += s.proxy_calls
        self.stats.escalated_calls += s.escalated_calls
        self.stats.cascade_rows += s.cascade_rows
        self.stats.escalated_rows += s.escalated_rows
        self.stats.transient_retries += s.transient_retries
        self.stats.deadline_drops += s.deadline_drops
        self.stats.degraded_calls += s.degraded_calls

    def _note_reranks(self, count: int, lines) -> None:
        """Called once per SemanticSelectStackOp when it closes."""
        self.stats.reranks += int(count)
        self.rerank_log.extend(lines)
