"""Physical execution of logical plans (vectorized, chunked).

Predict/SemanticJoin nodes are executed through core.predict operators
created by a factory (so the database layer controls executor resolution
and stats collection).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.relational.catalog import Catalog
from repro.relational.expr import Col, Expr, PredictExpr
from repro.relational.plan import (Filter, GroupBy, Join, Limit, Node,
                                   OrderBy, Predict, PredictInfo, Project,
                                   Scan, SemanticJoin)
from repro.relational.table import Table, _coerce


@dataclasses.dataclass
class ExecStats:
    llm_calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    sim_latency_s: float = 0.0
    serial_latency_s: float = 0.0
    wall_s: float = 0.0
    cache_hits: int = 0
    retries: int = 0
    batch_fallbacks: int = 0
    rows_predicted: int = 0

    @property
    def tokens(self) -> int:
        return self.in_tokens + self.out_tokens


class PlanExecutor:
    def __init__(self, catalog: Catalog,
                 predict_factory: Callable[[PredictInfo], "PredictOperator"],
                 chunk_size: int = 2048):
        self.cat = catalog
        self.predict_factory = predict_factory
        self.chunk_size = chunk_size
        self.stats = ExecStats()

    # ------------------------------------------------------------------
    def run(self, plan: Node) -> Table:
        return self._exec(plan)

    def _absorb(self, op) -> None:
        s = op.stats
        self.stats.llm_calls += s.calls
        self.stats.in_tokens += s.in_tokens
        self.stats.out_tokens += s.out_tokens
        self.stats.sim_latency_s += s.sim_latency_s
        self.stats.serial_latency_s += s.serial_latency_s
        self.stats.wall_s += s.wall_s
        self.stats.cache_hits += s.cache_hits
        self.stats.retries += s.retries
        self.stats.batch_fallbacks += s.batch_fallbacks
        self.stats.rows_predicted += s.rows_in

    # ------------------------------------------------------------------
    def _exec(self, n: Node) -> Table:
        if isinstance(n, Scan):
            return self.cat.table(n.table)
        if isinstance(n, Filter):
            t = self._exec(n.child)
            return t.mask(np.asarray(n.predicate.evaluate(t), bool))
        if isinstance(n, Project):
            t = self._exec(n.child)
            cols = {}
            sch = {}
            for name, e in n.exprs:
                v = e.evaluate(t)
                cols[name] = v
                sch[name] = e.sql_type(t.schema)
            return Table(cols, sch)
        if isinstance(n, Join):
            return self._join(n)
        if isinstance(n, GroupBy):
            return self._groupby(n)
        if isinstance(n, OrderBy):
            t = self._exec(n.child)
            if len(t) == 0:
                return t
            order = np.arange(len(t))
            for e, asc in reversed(n.keys):
                v = e.evaluate(t)[order]
                kind = "stable"
                if v.dtype == object:
                    v = np.array([("" if x is None else str(x)) for x in v])
                idx = np.argsort(v, kind=kind)
                if not asc:
                    idx = idx[::-1]
                order = order[idx]
            return t.take(order)
        if isinstance(n, Limit):
            t = self._exec(n.child)
            return t.slice(0, n.n)
        if isinstance(n, Predict):
            op = self.predict_factory(n.info)
            if n.child is None:
                out = op.scan()
            else:
                t = self._exec(n.child)
                parts = []
                for s in range(0, max(len(t), 1), self.chunk_size):
                    chunk = t.slice(s, min(s + self.chunk_size, len(t)))
                    parts.append(op(chunk))
                out = parts[0]
                for p in parts[1:]:
                    out = out.concat(p)
            self._absorb(op)
            return out
        if isinstance(n, SemanticJoin):
            return self._semantic_join(n)
        raise TypeError(f"cannot execute {type(n).__name__}")

    # ------------------------------------------------------------------
    def _join(self, n: Join) -> Table:
        l = self._exec(n.left)
        r = self._exec(n.right)
        if n.kind == "cross" or not n.left_keys:
            li = np.repeat(np.arange(len(l)), len(r))
            ri = np.tile(np.arange(len(r)), len(l))
        else:
            index: Dict[tuple, List[int]] = {}
            rk = [r.column(k) for k in n.right_keys]
            for i in range(len(r)):
                index.setdefault(tuple(c[i] for c in rk), []).append(i)
            lk = [l.column(k) for k in n.left_keys]
            li_list, ri_list = [], []
            for i in range(len(l)):
                for j in index.get(tuple(c[i] for c in lk), ()):
                    li_list.append(i)
                    ri_list.append(j)
            li = np.array(li_list, np.int64)
            ri = np.array(ri_list, np.int64)
        lt = l.take(li)
        rt = r.take(ri)
        cols = dict(lt.cols)
        sch = dict(lt.schema)
        for k, v in rt.cols.items():
            if k in cols:          # drop duplicate right key columns
                continue
            cols[k] = v
            sch[k] = rt.schema[k]
        out = Table(cols, sch)
        if n.extra is not None:
            out = out.mask(np.asarray(n.extra.evaluate(out), bool))
        return out

    def _semantic_join(self, n: SemanticJoin) -> Table:
        l = self._exec(n.left)
        r = self._exec(n.right)
        li = np.repeat(np.arange(len(l)), len(r))
        ri = np.tile(np.arange(len(r)), len(l))
        lt = l.take(li)
        rt = r.take(ri)
        cols = dict(lt.cols)
        sch = dict(lt.schema)
        for k, v in rt.cols.items():
            if k not in cols:
                cols[k] = v
                sch[k] = rt.schema[k]
        cross = Table(cols, sch)
        op = self.predict_factory(n.info)
        parts = []
        for s in range(0, max(len(cross), 1), self.chunk_size):
            parts.append(op(cross.slice(s, min(s + self.chunk_size,
                                               len(cross)))))
        out = parts[0]
        for p in parts[1:]:
            out = out.concat(p)
        self._absorb(op)
        flag = out.column(n.info.out_cols[0])
        keep = np.array([bool(x) for x in flag])
        kept = out.mask(keep)
        # semantic-join output schema = input schemas only (§3.3)
        drop = set(n.info.out_cols)
        return kept.select([c for c in kept.column_names if c not in drop])

    # ------------------------------------------------------------------
    def _groupby(self, n: GroupBy) -> Table:
        t = self._exec(n.child)
        if n.keys:
            keys = [t.column(k) for k in n.keys]
            groups: Dict[tuple, List[int]] = {}
            for i in range(len(t)):
                groups.setdefault(tuple(k[i] for k in keys), []).append(i)
            items = list(groups.items())
        else:
            items = [((), list(range(len(t))))]

        out_cols: Dict[str, list] = {k: [] for k in n.keys}
        agg_out: Dict[str, list] = {name: [] for name, _, _ in n.aggs}
        llm_groups: Dict[str, List[List[dict]]] = {}

        for key, idx in items:
            for k, kv in zip(n.keys, key):
                out_cols[k].append(kv)
            for name, fn, arg in n.aggs:
                if fn == "llm_agg":
                    continue
                if fn == "count":
                    agg_out[name].append(len(idx))
                    continue
                v = arg.evaluate(t)[idx] if arg is not None else \
                    np.ones(len(idx))
                v = np.asarray(v, np.float64)
                agg_out[name].append({"sum": np.nansum, "avg": np.nanmean,
                                      "min": np.nanmin, "max": np.nanmax}[fn](v))

        infos = getattr(n, "llm_agg_infos", {})
        for name, fn, arg in n.aggs:
            if fn != "llm_agg":
                continue
            info = infos[name]
            op = self.predict_factory(info)
            group_rows = []
            for key, idx in items:
                group_rows.append([{c: t.row(i)[c] for c in info.inputs}
                                   for i in idx])
            agg_out[name] = op.aggregate(group_rows)
            self._absorb(op)

        cols = {}
        sch = {}
        for k in n.keys:
            cols[k] = _coerce(out_cols[k], t.schema[k])
            sch[k] = t.schema[k]
        gb_schema = n.schema(self.cat) if False else {}
        for name, fn, arg in n.aggs:
            typ = "INTEGER" if fn == "count" else (
                "VARCHAR" if fn == "llm_agg" else "DOUBLE")
            cols[name] = _coerce(agg_out[name], typ)
            sch[name] = typ
        return Table(cols, sch)
