"""Logical plan nodes + schema/cardinality propagation.

`Predict` is the paper's LogicalPredict: one node regardless of where the
inference clause appeared (FROM table-inference, scalar WHERE/SELECT/etc.,
table generation, semantic join condition, LLM AGG). PredictInfo carries
everything the physical operator needs (§4.3).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.relational.expr import Expr, PredictExpr, PromptTemplate

_counter = itertools.count()


def fresh_col(prefix: str) -> str:
    return f"__{prefix}_{next(_counter)}"


@dataclasses.dataclass
class PredictInfo:
    model_name: str
    prompt: Optional[PromptTemplate]
    inputs: List[str]
    outputs: List[Tuple[str, str]]          # (column, SQL type)
    out_prefix: str = ""                    # disambiguation prefix
    agg: bool = False
    options: Dict[str, object] = dataclasses.field(default_factory=dict)
    out_cols_override: Optional[List[str]] = None   # set by predicate merging

    @property
    def out_cols(self) -> List[str]:
        if self.out_cols_override is not None:
            return list(self.out_cols_override)
        return [self.out_prefix + n for n, _ in self.outputs]


class Node:
    children: List["Node"] = []

    def schema(self, cat) -> Dict[str, str]:
        raise NotImplementedError

    def est_rows(self, cat) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class Scan(Node):
    table: str
    alias: Optional[str] = None

    @property
    def children(self):
        return []

    def schema(self, cat):
        return dict(cat.table(self.table).schema)

    def est_rows(self, cat):
        return float(len(cat.table(self.table)))


@dataclasses.dataclass
class Filter(Node):
    child: Node
    predicate: Expr
    selectivity: float = 0.5               # planner estimate

    @property
    def children(self):
        return [self.child]

    def schema(self, cat):
        return self.child.schema(cat)

    def est_rows(self, cat):
        return self.child.est_rows(cat) * self.selectivity


@dataclasses.dataclass
class Project(Node):
    child: Node
    exprs: List[Tuple[str, Expr]]          # (output name, expression)

    @property
    def children(self):
        return [self.child]

    def schema(self, cat):
        base = self.child.schema(cat)
        return {n: e.sql_type(base) for n, e in self.exprs}

    def est_rows(self, cat):
        return self.child.est_rows(cat)


@dataclasses.dataclass
class Join(Node):
    left: Node
    right: Node
    kind: str = "inner"                    # inner | cross
    left_keys: List[str] = dataclasses.field(default_factory=list)
    right_keys: List[str] = dataclasses.field(default_factory=list)
    extra: Optional[Expr] = None           # residual non-equi condition

    @property
    def children(self):
        return [self.left, self.right]

    def schema(self, cat):
        s = dict(self.left.schema(cat))
        s.update(self.right.schema(cat))
        return s

    def est_rows(self, cat):
        l, r = self.left.est_rows(cat), self.right.est_rows(cat)
        if self.kind == "cross" or not self.left_keys:
            return l * r
        return max(l, r)                   # FK-join heuristic


@dataclasses.dataclass
class GroupBy(Node):
    child: Node
    keys: List[str]
    aggs: List[Tuple[str, str, Optional[Expr]]]   # (out, fn, arg)

    @property
    def children(self):
        return [self.child]

    def schema(self, cat):
        base = self.child.schema(cat)
        out = {k: base[k] for k in self.keys}
        for name, fn, arg in self.aggs:
            if fn in ("count",):
                out[name] = "INTEGER"
            elif fn in ("avg", "sum", "min", "max"):
                out[name] = "DOUBLE" if fn in ("avg", "sum") else \
                    (arg.sql_type(base) if arg else "DOUBLE")
            else:
                out[name] = "VARCHAR"      # llm_agg
        return out

    def est_rows(self, cat):
        return max(1.0, self.child.est_rows(cat) / 10.0)


@dataclasses.dataclass
class OrderBy(Node):
    child: Node
    keys: List[Tuple[Expr, bool]]          # (expr, ascending)

    @property
    def children(self):
        return [self.child]

    def schema(self, cat):
        return self.child.schema(cat)

    def est_rows(self, cat):
        return self.child.est_rows(cat)


@dataclasses.dataclass
class Limit(Node):
    child: Node
    n: int

    @property
    def children(self):
        return [self.child]

    def schema(self, cat):
        return self.child.schema(cat)

    def est_rows(self, cat):
        return min(self.n, self.child.est_rows(cat))


@dataclasses.dataclass
class Predict(Node):
    """LogicalPredict: adds info.out_cols to the child's schema.
    child=None → table generation (ρ^s, LLM-as-scan)."""
    child: Optional[Node]
    info: PredictInfo

    @property
    def children(self):
        return [self.child] if self.child else []

    def schema(self, cat):
        base = dict(self.child.schema(cat)) if self.child else {}
        for (n, t), c in zip(self.info.outputs, self.info.out_cols):
            base[c] = t
        return base

    def est_rows(self, cat):
        return self.child.est_rows(cat) if self.child else 32.0


@dataclasses.dataclass
class SemanticJoin(Node):
    """R ⋈^s_P S — boolean LLM predicate over the cross product (§3.3).
    Physically: cross join (chunked) → Predict(BOOLEAN) → Filter."""
    left: Node
    right: Node
    info: PredictInfo

    @property
    def children(self):
        return [self.left, self.right]

    def schema(self, cat):
        s = dict(self.left.schema(cat))
        s.update(self.right.schema(cat))
        return s

    def est_rows(self, cat):
        return self.left.est_rows(cat) * self.right.est_rows(cat) * 0.1


def walk_plan(n: Node):
    yield n
    for c in n.children:
        yield from walk_plan(c)


def plan_repr(n: Node, indent: int = 0) -> str:
    pad = "  " * indent
    label = type(n).__name__
    extra = ""
    if isinstance(n, Scan):
        extra = f" {n.table}" + (f" as {n.alias}" if n.alias else "")
    if isinstance(n, Filter):
        extra = f" {n.predicate!r}"
    if isinstance(n, Predict):
        extra = f" {n.info.model_name} out={n.info.out_cols}"
    if isinstance(n, SemanticJoin):
        extra = f" {n.info.model_name}"
    if isinstance(n, Join):
        extra = f" {n.kind} {list(zip(n.left_keys, n.right_keys))}"
    lines = [f"{pad}{label}{extra}"]
    for c in n.children:
        lines.append(plan_repr(c, indent + 1))
    return "\n".join(lines)
