"""Binder/planner: AST → logical plan (paper Fig. 2 pipeline stages).

Responsibilities (paper §4.2/§4.3):
  * resolve relations and aliases; qualify `alias.col` references into the
    flat column namespace (alias prefixes are materialized as renames)
  * validate model references against the model catalog; resolve prompt
    placeholders into typed inputs/outputs
  * turn every PredictExpr into a LogicalPredict at the right place:
      - FROM LLM(...)            → Predict over source (table inference)
        or Predict over nothing  → table generation
      - scalar inference in WHERE/SELECT/ORDER/GROUP → Predict inserted
        above the current plan, expression rewritten to the predicted col
      - JOIN ... ON LLM(...)     → SemanticJoin
      - LLM AGG                  → GroupBy llm_agg aggregate
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.relational import parser as PS
from repro.relational.catalog import Catalog
from repro.relational.expr import (BinOp, Col, Expr, Lit, Not, PredictExpr,
                                   PromptTemplate, find_predicts, replace_expr)
from repro.relational.parser import FuncCall
from repro.relational.plan import (Filter, GroupBy, Join, Limit, Node,
                                   OrderBy, Predict, PredictInfo, Project,
                                   Scan, SemanticJoin, fresh_col)


class BindError(Exception):
    pass


def _qualify(e: Expr, scope: Dict[str, str]) -> Expr:
    """Rewrite alias.col / bare col via the scope map (alias.col → concrete)."""
    if isinstance(e, Col):
        if e.name in scope:
            return Col(scope[e.name])
        base = e.name.split(".")[-1]
        if base in scope:
            return Col(scope[base])
        return Col(base)
    if isinstance(e, PredictExpr) and e.prompt:
        new_inputs = []
        for c in e.prompt.inputs:
            new_inputs.append(scope.get(c, scope.get(c.split(".")[-1],
                                                     c.split(".")[-1])))
        pt = PromptTemplate(e.prompt.raw, e.prompt.instruction, new_inputs,
                            e.prompt.outputs)
        return PredictExpr(e.model_name, pt, e.source, e.agg, e.resolved_col,
                           e.options)
    if dataclasses.is_dataclass(e) and isinstance(e, Expr):
        kw = {}
        for f in dataclasses.fields(e):
            v = getattr(e, f.name)
            if isinstance(v, Expr):
                kw[f.name] = _qualify(v, scope)
            elif isinstance(v, list) and v and isinstance(v[0], Expr):
                kw[f.name] = [_qualify(x, scope) for x in v]
            else:
                kw[f.name] = v
        return type(e)(**kw)
    return e


class Binder:
    def __init__(self, catalog: Catalog, session_options: Dict[str, object]):
        self.cat = catalog
        self.opts = session_options

    # ------------------------------------------------------------------
    def bind_select(self, stmt: PS.SelectStmt) -> Node:
        plan: Optional[Node] = None
        scope: Dict[str, str] = {}

        if stmt.from_rel is not None:
            plan, scope = self._bind_rel(stmt.from_rel)
            for jc in stmt.joins:
                rplan, rscope = self._bind_rel(jc.rel)
                plan, scope = self._bind_join(plan, scope, rplan, rscope, jc)

        # WHERE
        if stmt.where is not None:
            pred = _qualify(stmt.where, scope)
            plan = self._plant_scalar_predicts(plan, pred, scope)
            pred = self._rewrite_resolved(pred)
            plan = Filter(plan, pred)

        # GROUP BY + aggregates (incl. LLM AGG)
        sel_exprs: List[Tuple[str, Expr]] = []
        agg_specs: List[Tuple[str, str, Optional[Expr]]] = []
        has_agg = False
        for alias, e in stmt.select:
            eq = _qualify(e, scope)
            if isinstance(eq, FuncCall) and eq.name in ("count", "sum", "avg",
                                                        "min", "max"):
                has_agg = True
            if isinstance(eq, PredictExpr) and eq.agg:
                has_agg = True
            sel_exprs.append((alias, eq))

        if stmt.group_by or has_agg:
            plan = self._bind_groupby(plan, scope, stmt, sel_exprs)
            if stmt.order_by:
                keys = []
                for e, asc in stmt.order_by:
                    eq = _qualify(e, scope)
                    plan = self._plant_scalar_predicts(plan, eq, scope)
                    keys.append((eq, asc))
                plan = OrderBy(plan, keys)
        else:
            # scalar predicts in the projection list
            for i, (alias, e) in enumerate(sel_exprs):
                plan = self._plant_scalar_predicts(plan, e, scope)
                sel_exprs[i] = (alias, self._rewrite_resolved(e))
            # ORDER BY binds BEFORE projection: its (possibly semantic)
            # keys may need input columns the projection drops
            if stmt.order_by:
                keys = []
                for e, asc in stmt.order_by:
                    eq = _qualify(e, scope)
                    plan = self._plant_scalar_predicts(plan, eq, scope)
                    keys.append((eq, asc))
                plan = OrderBy(plan, keys)
            if not stmt.star:
                named = []
                used = set()
                for alias, e in sel_exprs:
                    name = alias or (e.name if isinstance(e, Col)
                                     else fresh_col("expr"))
                    base = name.split(".")[-1].split("__")[-1]
                    out_name = base if base not in used else \
                        name.split(".")[-1]
                    used.add(out_name)
                    named.append((out_name, e))
                plan = Project(plan, named)

        if stmt.limit is not None:
            plan = Limit(plan, stmt.limit)
        return plan

    # ------------------------------------------------------------------
    def _bind_rel(self, rel: PS.RelRef) -> Tuple[Node, Dict[str, str]]:
        if rel.kind == "table":
            t = self.cat.table(rel.name)
            scope = {}
            if rel.alias:
                # alias-qualified internal names make self-joins sound
                ren = {c: f"{rel.alias}__{c}" for c in t.column_names}
                plan: Node = Project(Scan(rel.name, rel.alias),
                                     [(ren[c], Col(c))
                                      for c in t.column_names])
                for c in t.column_names:
                    scope[f"{rel.alias}.{c}"] = ren[c]
                    scope.setdefault(c, ren[c])
                return plan, scope
            alias = rel.name
            for c in t.column_names:
                scope[f"{alias}.{c}"] = c
                scope.setdefault(c, c)
            return Scan(rel.name, rel.alias), scope

        # LLM / PREDICT relation (table inference or generation)
        entry = self.cat.model(rel.name)
        if rel.prompt is not None:
            pt = PromptTemplate.parse(rel.prompt)
        elif entry.output_set:
            pt = None
        else:
            raise BindError(f"model {rel.name} needs a PROMPT or catalog outputs")

        child: Optional[Node] = None
        scope: Dict[str, str] = {}
        if rel.source is not None:
            child, scope = self._bind_rel(rel.source)
        elif entry.relation:
            child, scope = self._bind_rel(PS.RelRef("table", entry.relation))

        if pt is not None:
            inputs = [scope.get(c, scope.get(c.split(".")[-1],
                                             c.split(".")[-1]))
                      for c in pt.inputs]
            outputs = pt.outputs
        else:
            inputs = entry.input_set or []
            outputs = entry.output_set or []

        # §5.3 precedence: per-expression WITH options over model OPTIONS
        info = PredictInfo(model_name=rel.name, prompt=pt, inputs=inputs,
                           outputs=outputs,
                           options={**entry.options, **(rel.options or {})})
        plan = Predict(child, info)
        out_scope = dict(scope)
        alias = rel.alias
        for (n, _), c in zip(outputs, info.out_cols):
            out_scope[n] = c
            if alias:
                out_scope[f"{alias}.{n}"] = c
        return plan, out_scope

    # ------------------------------------------------------------------
    def _bind_join(self, lplan, lscope, rplan, rscope, jc: PS.JoinClause):
        scope = dict(lscope)
        scope.update(rscope)
        if jc.natural:
            shared = sorted((set(lscope) & set(rscope)) -
                            {k for k in lscope if "." in k})
            shared = [c for c in shared if "." not in c]
            if not shared:
                raise BindError("NATURAL JOIN with no shared columns")
            # rename right-side shared columns to avoid collision
            ren = {rscope[c]: fresh_col(c) for c in shared}
            rplan = Project(rplan, [(ren.get(v, v), Col(v)) for k, v in
                                    sorted(set((k, v) for k, v in rscope.items()
                                               if "." not in k))])
            join = Join(lplan, rplan, "inner",
                        [lscope[c] for c in shared],
                        [ren[rscope[c]] for c in shared])
            return join, scope
        if jc.on is None:
            return Join(lplan, rplan, "cross"), scope

        on = _qualify(jc.on, scope)
        preds = find_predicts(on)
        if preds:
            if len(preds) == 1 and on is preds[0]:
                # pure semantic join
                p = preds[0]
                info = self._predict_info(p, boolean=True)
                return SemanticJoin(lplan, rplan, info), scope
            # mixed condition: cross join + predicts + residual filter
            plan = Join(lplan, rplan, "cross")
            plan = self._plant_scalar_predicts(plan, on, scope)
            return Filter(plan, self._rewrite_resolved(on)), scope

        lk, rk, residual = self._split_equi(on, lscope, rscope)
        if lk:
            return Join(lplan, rplan, "inner", lk, rk, residual), scope
        return Filter(Join(lplan, rplan, "cross"), on), scope

    def _split_equi(self, on: Expr, lscope, rscope):
        lcols = set(lscope.values())
        rcols = set(rscope.values())
        lk, rk, residual = [], [], []

        def collect(e):
            if isinstance(e, BinOp) and e.op == "AND":
                collect(e.left)
                collect(e.right)
                return
            if (isinstance(e, BinOp) and e.op == "=" and
                    isinstance(e.left, Col) and isinstance(e.right, Col)):
                l, r = e.left.name, e.right.name
                if l in lcols and r in rcols:
                    lk.append(l)
                    rk.append(r)
                    return
                if l in rcols and r in lcols:
                    lk.append(r)
                    rk.append(l)
                    return
            residual.append(e)

        collect(on)
        res = None
        for e in residual:
            res = e if res is None else BinOp("AND", res, e)
        return lk, rk, res

    # ------------------------------------------------------------------
    def _predict_info(self, p: PredictExpr, *, boolean: bool = False
                      ) -> PredictInfo:
        entry = self.cat.model(p.model_name)
        outputs = list(p.prompt.outputs) if p.prompt else \
            list(entry.output_set or [])
        if boolean and not outputs:
            outputs = [("match", "BOOLEAN")]
        if not outputs:
            raise BindError(f"predict on {p.model_name} has no output columns")
        # §5.3 precedence: per-expression WITH options over model OPTIONS
        info = PredictInfo(model_name=p.model_name, prompt=p.prompt,
                           inputs=list(p.prompt.inputs) if p.prompt
                           else list(entry.input_set or []),
                           outputs=outputs, out_prefix=fresh_col("p") + "_",
                           agg=p.agg,
                           options={**entry.options, **(p.options or {})})
        return info

    def _plant_scalar_predicts(self, plan: Node, e: Expr, scope) -> Node:
        """Insert a Predict node for every unresolved PredictExpr inside e;
        mutates the PredictExpr.resolved_col in place (the expr objects are
        shared with the caller's tree)."""
        for p in find_predicts(e):
            if p.resolved_col is not None or p.agg:
                continue
            info = self._predict_info(p)
            plan = Predict(plan, info)
            # scalar inference exposes its FIRST output column
            p.resolved_col = info.out_cols[0]
        return plan

    def _rewrite_resolved(self, e: Expr) -> Expr:
        """PredictExpr(resolved) compares like its predicted column; handled
        by PredictExpr.evaluate via resolved_col, so nothing to do — kept
        for symmetry/clarity."""
        return e

    # ------------------------------------------------------------------
    def _bind_groupby(self, plan, scope, stmt: PS.SelectStmt, sel_exprs):
        keys = [scope.get(k, scope.get(k.split(".")[-1], k.split(".")[-1]))
                for k in stmt.group_by]
        aggs: List[Tuple[str, str, Optional[Expr]]] = []
        out_names: List[Tuple[str, Expr]] = []
        for alias, e in sel_exprs:
            if isinstance(e, FuncCall) and e.name in ("count", "sum", "avg",
                                                      "min", "max"):
                name = alias or fresh_col(e.name)
                arg = e.args[0] if e.args and not isinstance(e.args[0], Lit) \
                    else (None if not e.args or isinstance(e.args[0], Lit)
                          else e.args[0])
                aggs.append((name, e.name, arg))
                out_names.append((name, Col(name)))
            elif isinstance(e, PredictExpr) and e.agg:
                name = alias or fresh_col("llm_agg")
                plan_info = self._predict_info(e)
                aggs.append((name, "llm_agg", None))
                # stash info on the agg tuple via closure-side table
                aggs[-1] = (name, "llm_agg", None)
                self._llm_agg_infos = getattr(self, "_llm_agg_infos", {})
                self._llm_agg_infos[name] = plan_info
                out_names.append((name, Col(name)))
            elif isinstance(e, Col):
                out_names.append((alias or e.name.split(".")[-1], e))
            else:
                # scalar predicts before grouping
                plan = self._plant_scalar_predicts(plan, e, scope)
                name = alias or fresh_col("expr")
                out_names.append((name, e))
        gb = GroupBy(plan, keys, aggs)
        gb.llm_agg_infos = getattr(self, "_llm_agg_infos", {})
        self._llm_agg_infos = {}
        return Project(gb, out_names)
