import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# This flag is set ONLY here (never in conftest/pyproject): smoke tests and
# benches see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell and
record memory_analysis / cost_analysis / collective bytes for §Dry-run and
§Roofline.

Measurement notes (see EXPERIMENTS.md §Dry-run/Methodology):
  * XLA's HloCostAnalysis counts a while-loop body ONCE, so a production
    step built on scan-over-layers under-reports FLOPs/bytes/collectives.
    We therefore run two extra *cost-calibration* compiles per cell with
    num_layers ∈ {2, 4}, all loops unrolled (layer scan, flash-attention
    block scans, SSM chunk scan) and num_micro=1, then extrapolate
    linearly in L (exact: layers are homogeneous; L=1 is avoided because
    XLA's optimization pipeline is noisy at trivial depth — observed
    non-monotonic op counts):
        cost(L) = fixed + per_layer · L,   per_layer = (c4 − c2) / 2
    The production compile (rolled loops, real microbatching) is what must
    COMPILE — it provides memory_analysis and the collective schedule.
  * Collective bytes use ring-cost factors on the instruction result shape
    (post-SPMD per-device program): all-gather ≈ out·(n-1)/n,
    all-reduce ≈ 2·out·(n-1)/n, reduce-scatter ≈ out·(n-1),
    all-to-all ≈ out·(n-1)/n, collective-permute ≈ out.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun]
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

# TPU v5e hardware constants (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s effective per-chip collective bandwidth

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8}

_RESULT_RE = re.compile(
    r"=\s*\(?\s*(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
    r"\[([0-9,]*)\][^a-z]*([a-z][a-z0-9\-]*)\(")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _nbytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def collective_stats(hlo_text: str) -> dict:
    """Per-device bytes moved by collectives (ring-cost model), parsed from
    the post-SPMD HLO text."""
    out = {op: {"count": 0, "bytes": 0} for op in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if not any(op in ls for op in COLLECTIVE_OPS):
            continue
        m = _RESULT_RE.search(ls)
        if not m:
            continue
        dtype, dims, op = m.groups()
        if op.endswith("-start"):
            op = op[:-6]
        if op not in COLLECTIVE_OPS:
            continue
        res = _nbytes(dtype, dims)
        n = max(2, _group_size(ls))
        if op == "all-gather":
            b = res * (n - 1) // n
        elif op == "all-reduce":
            b = 2 * res * (n - 1) // n
        elif op == "reduce-scatter":
            b = res * (n - 1)
        elif op == "all-to-all":
            b = res * (n - 1) // n
        else:  # collective-permute
            b = res
        out[op]["count"] += 1
        out[op]["bytes"] += b
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch       # decode: 1 token/sequence


def _build(cfg, shape, mesh, *, calibrate: bool, num_micro: int,
           variant_opts=None):
    from repro.launch import steps as ST
    vo = variant_opts or {}
    if shape.kind == "train":
        step, (state_specs, batch_specs) = ST.make_train_step(
            cfg, mesh, shape, num_micro=num_micro, calibrate=calibrate,
            remat_policy=vo.get("remat_policy", "nothing"))
        return step.lower(state_specs, batch_specs)
    if shape.kind == "prefill":
        if vo.get("serve_bf16"):
            cfg = cfg.replace(param_dtype="bfloat16")
        step, (pspecs, batch_specs) = ST.make_prefill_step(
            cfg, mesh, shape, calibrate=calibrate,
            banded=vo.get("banded", False),
            seq_parallel=vo.get("seq_parallel", False),
            fsdp=not vo.get("no_fsdp", False))
        return step.lower(pspecs, batch_specs)
    if vo.get("serve_bf16"):
        cfg = cfg.replace(param_dtype="bfloat16")
    step, (pspecs, batch_specs, cache_specs) = ST.make_decode_step(
        cfg, mesh, shape, calibrate=calibrate,
        cache_shard_mode=vo.get("cache_shard", "hd"),
        per_row_write=vo.get("per_row_write", False),
        resident_weights=vo.get("resident", False))
    return step.lower(pspecs, batch_specs, cache_specs)


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll}


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, num_micro: int = 4, variant_opts=None) -> dict:
    import repro.configs as C
    from repro.launch import mesh as MS
    from repro.models.config import SHAPES_BY_NAME, shape_applicable

    cfg = C.get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "kind": shape.kind, "ok": False}
    if not ok:
        rec.update(skipped=True, why=why, ok=True)
        return rec

    mesh = MS.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec["mesh_shape"] = {k: int(v) for k, v in mesh.shape.items()}

    rec["variant"] = variant_opts or {}
    # ---- production compile: must succeed; gives memory + schedule --------
    t0 = time.time()
    lowered = _build(cfg, shape, mesh, calibrate=False, num_micro=num_micro,
                     variant_opts=variant_opts)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    sched = collective_stats(compiled.as_text())

    if mesh_kind == "multi":
        # multi-pod proves the `pod` axis shards; roofline terms are
        # single-pod only (assignment), so skip the calibration compiles.
        rec.update(
            ok=True, lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            chips=int(mesh.devices.size),
            memory_analysis={
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
            },
            collective_schedule=sched)
        return rec

    # ---- cost-calibration compiles (L=2, L=4, unrolled) -------------------
    c = {}
    for l in (2, 4):
        lw = _build(cfg.replace(num_layers=l), shape, mesh,
                    calibrate=True, num_micro=1, variant_opts=variant_opts)
        c[l] = _cost_of(lw.compile())
    L = cfg.num_layers

    def extrap(f2, f4):
        per_layer = max(0.0, (f4 - f2) / 2.0)
        fixed = max(0.0, f2 - 2.0 * per_layer)
        return fixed + per_layer * L

    flops_per_device = extrap(c[2]["flops"], c[4]["flops"])
    bytes_per_device = extrap(c[2]["bytes"], c[4]["bytes"])
    coll_by_op = {}
    for op in COLLECTIVE_OPS:
        coll_by_op[op] = int(extrap(c[2]["coll"][op]["bytes"],
                                    c[4]["coll"][op]["bytes"]))
    coll_total = sum(coll_by_op.values())

    n_chips = mesh.devices.size
    mf = model_flops(cfg, shape)
    compute_s = flops_per_device / PEAK_FLOPS_BF16
    memory_s = bytes_per_device / HBM_BW
    collective_s = coll_total / ICI_BW

    rec.update(
        ok=True,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        chips=int(n_chips),
        memory_analysis={
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0) or 0),
        },
        cost_analysis={"flops_per_device": flops_per_device,
                       "bytes_per_device": bytes_per_device,
                       "calib_L2": c[2], "calib_L4": c[4]},
        collective_schedule=sched,          # rolled program (body-once text)
        collective_bytes_by_op=coll_by_op,  # calibrated totals
        collective_bytes_total=coll_total,
        model_flops_total=mf,
        model_flops_per_device=mf / n_chips,
        roofline={
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": collective_s,
            "bound": max(("compute", compute_s), ("memory", memory_s),
                         ("collective", collective_s), key=lambda t: t[1])[0],
            "useful_flops_ratio": (mf / n_chips) / flops_per_device
            if flops_per_device else 0.0,
        },
    )
    return rec


def cell_path(out_dir: Path, arch: str, shape: str, mesh: str) -> Path:
    return out_dir / f"{arch}__{shape}__{mesh}.json"


def sweep(out_dir: Path, mesh_kinds, only_missing: bool = True,
          archs=None, shapes=None):
    """Run every cell in a subprocess; append-only JSON per cell."""
    import repro.configs as C
    cells = []
    for (a, s, ok, why) in C.cells(include_skipped=True):
        if archs and a not in archs:
            continue
        if shapes and s.name not in shapes:
            continue
        for mk in mesh_kinds:
            cells.append((a, s.name, mk, ok))
    print(f"sweep: {len(cells)} cells -> {out_dir}", flush=True)
    for a, sn, mk, ok in cells:
        p = cell_path(out_dir, a, sn, mk)
        if only_missing and p.exists():
            d = json.loads(p.read_text())
            if d.get("ok"):
                print(f"[skip-done] {a} {sn} {mk}", flush=True)
                continue
        t0 = time.time()
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
             "--shape", sn, "--mesh", mk, "--out", str(out_dir)],
            capture_output=True, text=True, timeout=7200)
        dt = time.time() - t0
        if p.exists():
            d = json.loads(p.read_text())
            if d.get("skipped"):
                status = f"SKIP ({d.get('why','')})"
            elif d.get("ok") and "roofline" not in d:
                status = f"OK   (compile {d.get('compile_s','?')}s)"
            elif d.get("ok"):
                rf = d["roofline"]
                status = (f"OK   bound={rf['bound']:10s} "
                          f"c={rf['compute_s']*1e3:9.2f}ms "
                          f"m={rf['memory_s']*1e3:9.2f}ms "
                          f"coll={rf['collective_s']*1e3:9.2f}ms")
            else:
                status = f"FAIL: {d.get('error', '')[:160]}"
        else:
            status = f"CRASH rc={r.returncode}: {(r.stderr or '')[-300:]}"
            p.write_text(json.dumps({"arch": a, "shape": sn, "mesh": mk,
                                     "ok": False,
                                     "error": f"crash rc={r.returncode}",
                                     "stderr_tail": (r.stderr or "")[-2000:]}))
        print(f"[{dt:7.1f}s] {a:20s} {sn:12s} {mk:6s} {status}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default="", help="comma list filter for --all")
    ap.add_argument("--shapes", default="", help="comma list filter for --all")
    ap.add_argument("--num-micro", type=int, default=4)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="", help="label for variant output")
    ap.add_argument("--banded", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--cache-shard", default="hd",
                    choices=["hd", "lc", "kv", "none"])
    ap.add_argument("--per-row-write", action="store_true")
    ap.add_argument("--serve-bf16", action="store_true")
    ap.add_argument("--resident-weights", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat-policy", default="nothing",
                    choices=["nothing", "dots"])
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        sweep(out_dir, kinds, only_missing=not args.force,
              archs=[a for a in args.archs.split(",") if a] or None,
              shapes=[s for s in args.shapes.split(",") if s] or None)
        return

    assert args.arch and args.shape and args.mesh != "both"
    suffix = f"__{args.variant}" if args.variant else ""
    p = out_dir / f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json"
    variant_opts = None
    if args.variant:
        variant_opts = {"banded": args.banded,
                        "seq_parallel": args.seq_parallel,
                        "cache_shard": args.cache_shard,
                        "per_row_write": args.per_row_write,
                        "serve_bf16": args.serve_bf16,
                        "resident": args.resident_weights,
                        "no_fsdp": args.no_fsdp,
                        "remat_policy": args.remat_policy}
    try:
        rec = run_cell(args.arch, args.shape, args.mesh,
                       num_micro=args.num_micro, variant_opts=variant_opts)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "ok": False, "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    p.write_text(json.dumps(rec, indent=2))
    if rec.get("ok") and not rec.get("skipped"):
        keys = [k for k in ("arch", "shape", "mesh", "compile_s",
                            "memory_analysis", "collective_bytes_by_op",
                            "roofline") if k in rec]
        print(json.dumps({k: rec[k] for k in keys}, indent=2))
    else:
        print(json.dumps(rec, indent=2)[:2000])
        if not rec.get("ok"):
            sys.exit(1)


if __name__ == "__main__":
    main()
