"""Serving driver: bring up the engine + continuous batcher and run a
request stream (the deployable analog of examples/serve_e2e.py).

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \
        --requests 16 --slots 4 [--schema 'topic VARCHAR,score INTEGER']

On TPU hardware the same builders (launch.steps.make_prefill_step /
make_decode_step with the `resident` layout — see EXPERIMENTS.md §Perf)
drive the full-size configs; on this CPU host the smoke configs exercise
the identical code path.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import repro.configs as C
from repro.serving.engine import InferenceEngine
from repro.serving.grammar import Field, JsonGrammar
from repro.serving.scheduler import ContinuousBatcher, Request


def parse_schema(s: str):
    fields = []
    for part in s.split(","):
        name, typ = part.strip().split()
        fields.append(Field(name, typ.upper()))
    return fields


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--schema", default="label VARCHAR")
    ap.add_argument("--max-len", type=int, default=512)
    args = ap.parse_args(argv)

    cfg = C.get_smoke_config(args.arch).replace(vocab_size=259) \
        if args.smoke else C.get_config(args.arch)
    print(f"[serve] {args.arch} ({cfg.num_layers}L d={cfg.d_model}) "
          f"slots={args.slots}", flush=True)
    eng = InferenceEngine(cfg, max_len=args.max_len)
    grammar = JsonGrammar(parse_schema(args.schema), max_str=12)

    reqs = [Request(prompt=f"request {i}: classify this row",
                    grammar=grammar, max_new_tokens=args.max_new_tokens)
            for i in range(args.requests)]
    cb = ContinuousBatcher(eng, num_slots=args.slots)
    t0 = time.time()
    done = cb.run(reqs, temperature=args.temperature)
    dt = time.time() - t0

    ok = 0
    for r in done:
        if r.text and not r.error:
            json.loads(r.text)      # guaranteed by the grammar
            ok += 1
    print(f"[serve] {ok}/{len(reqs)} ok in {dt:.2f}s "
          f"({cb.stats.output_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"ticks={cb.stats.decode_steps})", flush=True)
    return 0 if ok == len(reqs) else 1


if __name__ == "__main__":
    sys.exit(main())
