"""Training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --smoke \
        --steps 200 --ckpt-dir /tmp/ckpt [--resume] [--simulate-failure 80]

Fault-tolerance features exercised here (and by tests/test_training.py):
  * checkpoint/restart: async sharded checkpoints every --ckpt-every steps;
    --resume restores the latest manifest and continues the *exact* token
    stream (the data pipeline is stateless-resumable)
  * preemption handling: SIGTERM/SIGINT triggers checkpoint-and-exit
  * straggler mitigation: per-step wall times tracked; steps slower than
    --straggler-factor × rolling median are logged and counted (on a real
    multi-host run this feeds the coordinator's replace-node policy; here
    it is surfaced as metrics)
  * elastic scaling: restore re-device_puts into whatever mesh the relaunch
    has (see tests for a 1→1 device reshard round trip; the dry-run's
    multi-pod mesh uses the same path)
"""
from __future__ import annotations

import argparse
import signal
import statistics
import sys
import time
from pathlib import Path

import jax
import numpy as np

import repro.configs as C
from repro.launch import steps as ST
from repro.models.config import ShapeSpec
from repro.training import checkpoint as CKPT
from repro.training import optim as OPT
from repro.training.data import DataConfig, synthetic_batch


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--simulate-failure", type=int, default=0,
                    help="raise at this step (tests checkpoint/restart)")
    ap.add_argument("--straggler-factor", type=float, default=3.0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = C.get_smoke_config(args.arch) if args.smoke else C.get_config(args.arch)
    shape = ShapeSpec("cli", seq_len=args.seq_len, global_batch=args.batch,
                      kind="train")
    opt_cfg = OPT.AdamWConfig(lr=args.lr, warmup_steps=20,
                              total_steps=max(args.steps, 100))
    step_fn, (state_specs, _) = ST.make_train_step(
        cfg, None, shape, num_micro=1, opt_cfg=opt_cfg, donate=True)

    start = 0
    if args.resume and args.ckpt_dir:
        last = CKPT.latest_step(args.ckpt_dir)
        if last is not None:
            state = CKPT.restore(args.ckpt_dir, last, state_specs)
            start = int(np.asarray(state["step"]))
            print(f"[resume] restored step {start} from {args.ckpt_dir}",
                  flush=True)
        else:
            state = ST.init_train_state(cfg, jax.random.PRNGKey(0))
    else:
        state = ST.init_train_state(cfg, jax.random.PRNGKey(0))

    dcfg = DataConfig(batch=args.batch, seq_len=args.seq_len)

    stop = {"now": False}

    def _sig(_sig, _frm):
        print("[preempt] signal received → checkpoint and exit", flush=True)
        stop["now"] = True

    signal.signal(signal.SIGTERM, _sig)
    signal.signal(signal.SIGINT, _sig)

    times = []
    stragglers = 0
    pending_ckpt = None
    for step in range(start, args.steps):
        if args.simulate_failure and step == args.simulate_failure:
            raise RuntimeError(f"simulated node failure at step {step}")
        batch = {k: np.asarray(v) for k, v in
                 synthetic_batch(cfg, dcfg, step).items()}
        t0 = time.time()
        state, metrics = step_fn(state, batch)
        dt = time.time() - t0
        times.append(dt)
        if len(times) > 5:
            med = statistics.median(times[-50:])
            if dt > args.straggler_factor * med:
                stragglers += 1
                print(f"[straggler] step {step}: {dt:.3f}s vs median {med:.3f}s",
                      flush=True)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms", flush=True)
        if args.ckpt_dir and ((step + 1) % args.ckpt_every == 0 or stop["now"]):
            pending_ckpt = CKPT.save_async(args.ckpt_dir, step + 1, state)
        if stop["now"]:
            break
    if pending_ckpt is not None:
        pending_ckpt.join()
    if args.ckpt_dir and not stop["now"]:
        CKPT.save(args.ckpt_dir, args.steps, jax.tree.map(np.asarray, state))
    print(f"[done] steps={args.steps} stragglers={stragglers}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
