"""Production mesh + sharding rules.

`make_production_mesh` is a FUNCTION (importing this module never touches
jax device state). Axes:
  single-pod : (data=16, model=16)            — 256 chips (one v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     — 512 chips across DCN

Sharding strategy (baseline; §Perf hillclimbs deviate per-cell):
  * training  = 2D FSDP×TP: weight contraction dims shard over `data`
    (+`pod`), feature dims over `model`; optimizer state like weights.
  * serving   = same weight layout (weight-stationary 2D TP for decode —
    activations are small, so resharding them is cheaper than gathering
    weights).
  * attention = query heads over `model` when num_heads%16==0 (whole-head
    blocks stay within GQA groups); otherwise attention weights replicate
    over `model` and FFN/vocab carry the model axis (hymba-25H, paligemma-8H).
  * KV cache  = batch over `data`; head_dim over `model` (uniform across
    archs — head_dim is always divisible; avoids DUS on a sharded dim).
    long_500k (batch=1) shards the cache length axis over `data` instead.
  * MoE       = experts over `model` when num_experts%16==0 (EP, all-to-all
    dispatch via sharding constraints), else per-expert FFN TP (mixtral).
  * vocab     = always padded to a multiple of 256 → shards over `model`.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as MDL
from repro.models.config import ENCODER, VLM, ModelConfig

PyTree = Any


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh: Mesh):
    """The (possibly compound) batch-sharding axis."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= mesh.shape[n]
        return out
    return mesh.shape[name]


# ----------------------------- parameter specs --------------------------------
def param_pspecs(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True,
                 attn_mode: str = "heads", resident: bool = False) -> PyTree:
    """PartitionSpec tree matching model.param_specs(cfg).

    attn_mode:
      'heads'      — query heads over `model` when divisible (train/prefill);
      'hd'         — head_dim over `model` for all attention tensors (decode:
                     uniform across archs, matches the hd-sharded KV cache);
      'replicated' — attention weights carry no model-axis sharding (used
                     with length-sharded caches, §Perf opt B — the model
                     axis belongs to the cache length there).

    resident=True (serving decode, §Perf opt B'): weights stay sharded on
    device across steps — feature dims spread over BOTH mesh axes when they
    divide, and nothing is sharded on a dim that would force a per-step
    weight all-gather. Activations (tiny at decode) reshard instead.
    """
    da = data_axes(mesh)
    fa = da if fsdp else None          # fsdp axis (contraction dims)
    mdl = "model"
    heads_tp = cfg.heads_shardable and attn_mode == "heads"
    hd_tp = attn_mode == "hd" and cfg.head_dim % 16 == 0

    bd = axis_size(mesh, da)
    both = tuple(da) + (mdl,)
    nboth = bd * mesh.shape[mdl]

    def wide(dim: int):
        # widest axis set dividing `dim` (for resident layouts)
        if dim % nboth == 0:
            return both
        if dim % mesh.shape[mdl] == 0:
            return mdl
        if dim % bd == 0:
            return da
        return None

    if resident:
        fa = None

    def spec_for(path: str, ndim_core: int) -> P:
        # vectors (norm scales, biases over d_model / dt / conv)
        if path.endswith((".scale", ".bias")):
            return P(*( [None] * ndim_core ))
        if ".attn.wq" in path or ".attn.wk" in path or ".attn.wv" in path:
            # (M, H|KV, hd)
            if hd_tp:
                return P(fa, None, mdl)
            if ".attn.wq" in path and heads_tp:
                return P(fa, mdl, None)
            return P(fa, None, None)           # KV replicated / odd heads
        if ".attn.wo" in path:
            if hd_tp:
                return P(None, mdl, fa)
            return P(mdl, None, fa) if heads_tp else P(None, None, fa)
        if ".attn.b" in path:
            if hd_tp:
                return P(None, mdl)
            return P(mdl, None) if (heads_tp and ".bq" in path) else P(None, None)
        if ".mlp.w_gate" in path or ".mlp.w_up" in path or ".mlp.w_in" in path:
            return P(None, wide(cfg.d_ff)) if resident else P(fa, mdl)
        if ".mlp.w_down" in path or ".mlp.w_out" in path:
            return P(wide(cfg.d_ff), None) if resident else P(mdl, fa)
        if ".mlp.b_in" in path:
            return P(mdl)
        if ".mlp.b_out" in path:
            return P(None)
        if ".moe.router" in path:
            return P(fa, None)
        if ".moe.w_gate" in path or ".moe.w_up" in path:
            # (E, M, F)
            if resident:
                fdim = da if cfg.d_ff % bd == 0 else None
                return P(mdl, None, fdim) if cfg.expert_sharding == "ep" \
                    else P(None, None, wide(cfg.d_ff))
            return P(mdl, fa, None) if cfg.expert_sharding == "ep" \
                else P(None, fa, mdl)
        if ".moe.w_down" in path:
            # (E, F, M)
            if resident:
                fdim = da if cfg.d_ff % bd == 0 else None
                return P(mdl, fdim, None) if cfg.expert_sharding == "ep" \
                    else P(None, wide(cfg.d_ff), None)
            return P(mdl, None, fa) if cfg.expert_sharding == "ep" \
                else P(None, mdl, fa)
        if ".ssm.in_x" in path or ".ssm.in_z" in path:
            return P(fa, mdl)
        if ".ssm.conv_w" in path:
            return P(None, mdl)
        if ".ssm.conv_b" in path or ".ssm.dt_bias" in path or "ssm.D" in path:
            return P(mdl)
        if ".ssm.x_proj" in path:
            return P(mdl, None)
        if ".ssm.dt_proj" in path:
            return P(None, mdl)
        if ".ssm.A_log" in path:
            return P(mdl, None)
        if ".ssm.out_proj" in path:
            return P(mdl, fa)
        if "embed" in path:
            if resident:
                return P(wide(cfg.padded_vocab), None)
            return P(mdl, fa)                  # (Vp, M)
        if "lm_head" in path:
            if resident:
                return P(None, wide(cfg.padded_vocab))
            return P(fa, mdl)                  # (M, Vp)
        raise ValueError(f"no sharding rule for {path}")

    specs = MDL.param_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    out = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path).replace("'", "").replace("[", ".") \
            .replace("]", "")
        stacked = ".layers." in pstr
        core = len(leaf.shape) - (1 if stacked else 0)
        sp = spec_for(pstr, core)
        if stacked:
            sp = P(None, *sp)                  # leading layer-stack axis
        out.append(sp)
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------ batch/cache specs ------------------------------
def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_specs: Dict[str, Any]) -> Dict[str, Any]:
    da = data_axes(mesh)
    bd = axis_size(mesh, da)
    out = {}
    for k, v in batch_specs.items():
        b = v.shape[0]
        lead = da if b % bd == 0 and b >= bd else None
        out[k] = P(lead, *([None] * (len(v.shape) - 1)))
    return out


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, cache_specs: Dict[str, Any],
                 *, shard_mode: str = "hd") -> Dict[str, Any]:
    """shard_mode: 'hd' (head_dim over model), 'lc' (cache length over
    model), 'kv' (kv heads over model), 'none'. Batch=1 cells fall back to
    sharding the length axis over `data`."""
    da = data_axes(mesh)
    bd = axis_size(mesh, da)
    out: Dict[str, Any] = {}
    for k, v in cache_specs.items():
        if k == "idx":
            out[k] = P()
            continue
        if k == "row_idx":                       # (B,)
            b = v.shape[0]
            out[k] = P(da if (b % bd == 0 and b >= bd) else None)
            continue
        if k == "slot_pos":                      # (B, lc)
            b, lc = v.shape
            if b % bd == 0 and b >= bd:
                out[k] = P(da, None)
            elif lc % bd == 0:
                out[k] = P(None, da)
            else:
                out[k] = P(None, None)
            continue
        if k in ("k", "v"):                      # (L, B, lc, KV, hd)
            _, b, lc, kvh, hd = v.shape
            bspec = da if (b % bd == 0 and b >= bd) else None
            lspec = None if bspec is not None else (da if lc % bd == 0 else None)
            kspec, hspec = None, None
            if shard_mode == "kv" and kvh % 16 == 0:
                kspec = "model"
            elif shard_mode == "lc" and lc % 16 == 0:
                lspec = (lspec, "model") if lspec else "model"
            elif shard_mode == "hd" and hd % 16 == 0:
                hspec = "model"
            out[k] = P(None, bspec, lspec, kspec, hspec)
            continue
        if k == "conv":                          # (L, B, K-1, Di)
            _, b, _, di = v.shape
            bspec = da if (b % bd == 0 and b >= bd) else None
            out[k] = P(None, bspec, None, "model" if di % 16 == 0 else None)
            continue
        if k == "h":                             # (L, B, Di, N)
            _, b, di, _ = v.shape
            bspec = da if (b % bd == 0 and b >= bd) else None
            out[k] = P(None, bspec, "model" if di % 16 == 0 else None, None)
            continue
        raise ValueError(k)
    return out


# --------------------------- activation constraints ----------------------------
def moe_constraint_fns(cfg: ModelConfig, mesh: Mesh, shardable_groups: bool):
    """dispatch/combine sharding-constraint hooks for the MoE block."""
    da = data_axes(mesh)
    gspec = da if shardable_groups else None
    if cfg.expert_sharding == "ep":
        disp = P(gspec, "model", None, None)     # (G, E, C, M) → EP all-to-all
    else:
        disp = P(gspec, None, None, None)        # stay data-local (TP MoE)

    def dispatch_cs(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, disp))

    def combine_cs(x):
        # return path: bring experts back token-local before the gather
        back = P(gspec, None, None, None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, back))

    return dispatch_cs, combine_cs


def logits_constraint(cfg: ModelConfig, mesh: Mesh, batch_shardable: bool):
    da = data_axes(mesh)
    spec = P(da if batch_shardable else None, None, "model")

    def f(x):
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return f


# --------------------------- ZeRO-3 / sequence parallel -----------------------
def param_pspecs_zero3(cfg: ModelConfig, mesh: Mesh) -> PyTree:
    """ZeRO-3 layout for sequence-parallel prefill (§Perf opt C): every
    weight leaf is flat-sharded on its largest divisible dim over as many
    axes as divide it; weights are all-gathered per layer at use while
    activations stay (batch × sequence)-sharded."""
    da = data_axes(mesh)
    bd = axis_size(mesh, da)
    md = mesh.shape["model"]
    candidates = [tuple(da) + ("model",), tuple(da), ("model",)]
    sizes = [bd * md, bd, md]

    def leaf_spec(shape, stacked):
        core = list(shape[1:] if stacked else shape)
        order = sorted(range(len(core)), key=lambda i: -core[i])
        for cand, n in zip(candidates, sizes):
            for d in order:
                if core[d] % n == 0:
                    sp = [None] * len(core)
                    sp[d] = cand if len(cand) > 1 else cand[0]
                    return P(*( [None] + sp if stacked else sp ))
        return P(*([None] * len(shape)))

    specs = MDL.param_specs(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs)
    out = []
    for path, leaf in flat:
        stacked = "layers" in jax.tree_util.keystr(path)
        out.append(leaf_spec(leaf.shape, stacked))
    return jax.tree_util.tree_unflatten(treedef, out)


def seq_parallel_hooks(mesh: Mesh):
    """(residual_cs, kv_cs): residual stream sharded (batch→data,
    seq→model); K/V replicated over model for full-context attention
    (GSPMD inserts the per-layer KV all-gather)."""
    da = data_axes(mesh)

    def residual_cs(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(da, "model", None)))

    def kv_cs(x):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(da, None, None, None)))

    return residual_cs, kv_cs
