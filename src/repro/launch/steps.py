"""Step builders: jitted train / prefill / decode steps with explicit
in/out shardings, shared by the dry-run, the trainer, and the serving
engine.

Each builder returns (jitted_fn, in_specs, in_shardings) so callers can
either execute it or `.lower(*specs).compile()` it (dry-run).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import common as CC
from repro.launch import mesh as MS
from repro.models import layers as LY
from repro.models import mamba as MB
from repro.models import model as MDL
from repro.models import moe as MOE
from repro.models.config import ModelConfig, ShapeSpec
from repro.training import optim as OPT

PyTree = Any


def calibration_fns(seq_len: int, banded: bool = False):
    """Unrolled attention/scan variants so XLA's HloCostAnalysis counts
    every iteration (used by the dry-run's L∈{2,4} cost-calibration
    compiles; production steps keep rolled loops + small blocks). Banded
    variants calibrate with 2048 blocks so the band ratio is resolvable."""
    blk = 2048 if banded else min(4096, max(512, seq_len))
    attn_fn = functools.partial(LY.flash_attention, block_q=blk,
                                block_kv=blk, unroll=True, banded=banded)
    scan_fn = functools.partial(MB.selective_scan, chunk=2048, unroll=True)
    return attn_fn, scan_fn


def _named(mesh: Mesh, tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def train_state_specs(cfg: ModelConfig) -> Dict[str, Any]:
    ps = MDL.param_specs(cfg)
    f32 = lambda t: jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), t)
    return {"params": ps, "opt": {"m": f32(ps), "v": f32(ps)},
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_pspecs(cfg: ModelConfig, mesh: Mesh, *, fsdp: bool = True
                       ) -> Dict[str, Any]:
    pp = MS.param_pspecs(cfg, mesh, fsdp=fsdp)
    return {"params": pp, "opt": {"m": pp, "v": pp}, "step": P()}


def init_train_state(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    params = MDL.init_params(cfg, key)
    return {"params": params, "opt": OPT.init_opt_state(params),
            "step": jnp.int32(0)}


# ------------------------------- train step -----------------------------------
def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh], shape: ShapeSpec,
                    *, num_micro: int = 1, opt_cfg: OPT.AdamWConfig = None,
                    remat: bool = True, donate: bool = True,
                    calibrate: bool = False, remat_policy: str = "nothing"):
    """Returns (jitted step, (state_specs, batch_specs))."""
    opt_cfg = opt_cfg or OPT.AdamWConfig()
    attn_fn = scan_fn = None
    unroll_layers = False
    if calibrate:
        attn_fn, scan_fn = calibration_fns(shape.seq_len)
        unroll_layers = True
        num_micro = 1
    batch_specs = CC.train_batch_specs(cfg, shape.global_batch, shape.seq_len)
    data_shards = MS.axis_size(mesh, MS.data_axes(mesh)) if mesh else 1
    micro_tokens = (shape.global_batch // num_micro) * shape.seq_len
    num_groups = MOE.pick_num_groups(micro_tokens, data_shards) \
        if cfg.has_moe else 1

    if mesh is not None:
        da = MS.data_axes(mesh)
        dispatch_cs, combine_cs = MS.moe_constraint_fns(cfg, mesh, True)
        logits_cs = MS.logits_constraint(cfg, mesh, True)
        micro_cs = lambda t: jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, P(None, da, *([None] * (x.ndim - 2))))), t)
    else:
        dispatch_cs = combine_cs = logits_cs = MOE.Identity
        micro_cs = MOE.Identity

    def loss_fn(params, mb):
        logits, _ = MDL.forward(cfg, params, mb, mode="train", remat=remat,
                                num_groups=num_groups, dispatch_cs=dispatch_cs,
                                combine_cs=combine_cs, logits_cs=logits_cs,
                                attn_fn=attn_fn, scan_fn=scan_fn,
                                unroll_layers=unroll_layers,
                                remat_policy=remat_policy)
        return MDL.lm_loss(cfg, logits, mb["labels"], mb["mask"])

    def train_step(state, batch):
        params = state["params"]
        if num_micro == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = {k: v.reshape((num_micro, v.shape[0] // num_micro)
                                  + v.shape[1:]) for k, v in batch.items()}
            micro = micro_cs(micro)

            def acc(carry, mb):
                lsum, gsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (lsum + l, jax.tree.map(jnp.add, gsum, g)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (lsum, gsum), _ = jax.lax.scan(acc, (jnp.float32(0), g0), micro)
            loss = lsum / num_micro
            grads = jax.tree.map(lambda g: g / num_micro, gsum)
        new_params, new_opt, stats = OPT.adamw_update(
            opt_cfg, params, grads, state["opt"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, **stats}

    if mesh is None:
        return jax.jit(train_step, donate_argnums=(0,) if donate else ()), \
            (train_state_specs(cfg), batch_specs)

    state_sh = _named(mesh, train_state_pspecs(cfg, mesh))
    batch_sh = _named(mesh, MS.batch_pspecs(cfg, mesh, batch_specs))
    metric_sh = {k: NamedSharding(mesh, P()) for k in
                 ("loss", "grad_norm", "lr")}
    step = jax.jit(train_step,
                   in_shardings=(state_sh, batch_sh),
                   out_shardings=(state_sh, metric_sh),
                   donate_argnums=(0,) if donate else ())
    return step, (train_state_specs(cfg), batch_specs)


# ------------------------------ prefill step ----------------------------------
def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh], shape: ShapeSpec,
                      *, cache_len: Optional[int] = None,
                      emit_cache: bool = True, calibrate: bool = False,
                      banded: bool = False, seq_parallel: bool = False,
                      fsdp: bool = True):
    """Prefill: full-sequence forward → (last-token logits, decode cache).

    banded        — §Perf opt A: sliding-window flash skips out-of-window
                    kv blocks (SWA archs only).
    seq_parallel  — §Perf opt C: sequence over `model`, ZeRO-3 weights."""
    attn_fn = scan_fn = None
    unroll_layers = False
    if calibrate:
        attn_fn, scan_fn = calibration_fns(shape.seq_len, banded=banded)
        unroll_layers = True
    elif banded:
        attn_fn = functools.partial(LY.flash_attention, banded=True)
    batch_specs = CC.prefill_batch_specs(cfg, shape.global_batch, shape.seq_len)
    cache_len = cache_len or shape.seq_len
    data_shards = MS.axis_size(mesh, MS.data_axes(mesh)) if mesh else 1
    tokens = shape.global_batch * shape.seq_len
    num_groups = MOE.pick_num_groups(tokens, data_shards) if cfg.has_moe else 1

    residual_cs = kv_cs = MOE.Identity
    if mesh is not None:
        dispatch_cs, combine_cs = MS.moe_constraint_fns(cfg, mesh, True)
        if seq_parallel:
            residual_cs, kv_cs = MS.seq_parallel_hooks(mesh)
    else:
        dispatch_cs = combine_cs = MOE.Identity

    def prefill_step(params, batch):
        cache = MDL.init_cache(cfg, shape.global_batch, cache_len) \
            if (emit_cache and cfg.supports_decode) else None
        logits, new_cache = MDL.forward(
            cfg, params, batch, mode=("prefill" if cache is not None else "train"),
            cache=cache, remat=False, num_groups=num_groups,
            dispatch_cs=dispatch_cs, combine_cs=combine_cs,
            last_only=cfg.supports_decode,
            attn_fn=attn_fn, scan_fn=scan_fn, unroll_layers=unroll_layers,
            residual_cs=residual_cs, kv_cs=kv_cs)
        return logits[:, -1], new_cache

    if mesh is None:
        return jax.jit(prefill_step), (MDL.param_specs(cfg), batch_specs)

    pp = MS.param_pspecs_zero3(cfg, mesh) if seq_parallel else \
        MS.param_pspecs(cfg, mesh, fsdp=fsdp)
    param_sh = _named(mesh, pp)
    batch_sh = _named(mesh, MS.batch_pspecs(cfg, mesh, batch_specs))
    da = MS.data_axes(mesh)
    logit_sh = NamedSharding(mesh, P(da, "model")) if not seq_parallel \
        else NamedSharding(mesh, P(da, None))
    cache_sh = None
    if emit_cache and cfg.supports_decode:
        cache_sh = _named(mesh, MS.cache_pspecs(
            cfg, mesh, MDL.cache_specs(cfg, shape.global_batch, cache_len)))
    step = jax.jit(prefill_step,
                   in_shardings=(param_sh, batch_sh),
                   out_shardings=(logit_sh, cache_sh))
    return step, (MDL.param_specs(cfg), batch_specs)


# ------------------------------ decode step -----------------------------------
def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh], shape: ShapeSpec,
                     *, cache_shard_mode: str = "hd", donate_cache: bool = True,
                     calibrate: bool = False, per_row_write: bool = False,
                     resident_weights: bool = False):
    """One-token serve_step against a seq_len-deep cache.

    cache_shard_mode='lc' + per_row_write=True is §Perf opt B: cache length
    sharded over `model` (softmax partials → tiny collectives) with the
    slot write as a masked elementwise update (no DUS on a sharded dim)."""
    assert cfg.supports_decode, f"{cfg.name} has no decode step"
    batch_specs = CC.decode_batch_specs(cfg, shape.global_batch)
    cache_specs = MDL.cache_specs(cfg, shape.global_batch, shape.seq_len,
                                  include_row_idx=per_row_write)
    data_shards = MS.axis_size(mesh, MS.data_axes(mesh)) if mesh else 1
    num_groups = MOE.pick_num_groups(shape.global_batch, data_shards) \
        if cfg.has_moe else 1

    if mesh is not None:
        dispatch_cs, combine_cs = MS.moe_constraint_fns(cfg, mesh, True)
    else:
        dispatch_cs = combine_cs = MOE.Identity

    def decode_step(params, batch, cache):
        logits, new_cache = MDL.forward(
            cfg, params, batch, mode="decode", cache=cache, remat=False,
            num_groups=num_groups, dispatch_cs=dispatch_cs,
            combine_cs=combine_cs, unroll_layers=calibrate)
        return logits, new_cache

    if mesh is None:
        return jax.jit(decode_step,
                       donate_argnums=(2,) if donate_cache else ()), \
            (MDL.param_specs(cfg), batch_specs, cache_specs)

    if cache_shard_mode == "hd" and cfg.head_dim % 16 == 0:
        attn_mode = "hd"
    elif cache_shard_mode == "lc":
        attn_mode = "replicated"    # model axis belongs to cache length
    else:
        attn_mode = "heads"
    param_sh = _named(mesh, MS.param_pspecs(cfg, mesh,
                                            fsdp=not resident_weights,
                                            attn_mode=attn_mode,
                                            resident=resident_weights))
    batch_sh = _named(mesh, MS.batch_pspecs(cfg, mesh, batch_specs))
    cache_sh = _named(mesh, MS.cache_pspecs(cfg, mesh, cache_specs,
                                            shard_mode=cache_shard_mode))
    da = MS.data_axes(mesh)
    b = shape.global_batch
    bd = MS.axis_size(mesh, da)
    logit_sh = NamedSharding(
        mesh, P(da if (b % bd == 0 and b >= bd) else None, None, "model"))
    step = jax.jit(decode_step,
                   in_shardings=(param_sh, batch_sh, cache_sh),
                   out_shardings=(logit_sh, cache_sh),
                   donate_argnums=(2,) if donate_cache else ())
    return step, (MDL.param_specs(cfg), batch_specs, cache_specs)
