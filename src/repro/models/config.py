"""Model configuration for the assigned architecture pool.

Every architecture in the assignment maps onto one `ModelConfig`. The config
is purely declarative — no jax work happens at import time. Derived
quantities (padded vocab, padded heads, parameter counts) are computed from
shapes only, so the dry-run can reason about full-size models without
allocating them.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional, Tuple

# Block families --------------------------------------------------------------
DENSE = "dense"       # attention + dense MLP
MOE = "moe"           # attention + mixture-of-experts MLP
SSM = "ssm"           # mamba-1 mixer only (no attention, no separate MLP)
HYBRID = "hybrid"     # parallel attention ∥ mamba heads + dense MLP
ENCODER = "encoder"   # bidirectional attention + dense MLP (no decode path)
VLM = "vlm"           # decoder LM with prepended image-patch embeddings


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    vocab_size: int
    # Attention (heads == 0 → attention-free)
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 → full attention
    rope_theta: float = 10_000.0
    causal: bool = True
    # MLP
    d_ff: int = 0                    # per-expert width for MoE
    mlp_act: str = "silu"            # silu (SwiGLU) | gelu (plain 2-layer)
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba-1)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                 # 0 → d_model // 16
    # Norm
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    # Embedding
    tie_embeddings: bool = False
    scale_embed: bool = False        # gemma-style sqrt(d_model) scaling
    # VLM / audio frontend stub
    num_prefix_tokens: int = 0       # precomputed patch/frame embeddings
    # Numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # -- derived -------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_eff(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so it always shards over a
        16-way model axis (hymba 32001→32256, hubert 504→512)."""
        return _round_up(self.vocab_size, 256)

    @property
    def padded_heads(self) -> int:
        """Query heads padded (whole GQA groups kept intact) up to the next
        multiple of 16 when the overhead is ≤ 15%; otherwise unpadded and the
        sharding rules fall back to replicated attention over `model`.

        Padding query heads with zero w_q/w_o rows is function-preserving:
        the extra heads see zero scores (uniform attention) but their w_o
        rows are zero, contributing nothing to the output.
        """
        if self.num_heads == 0 or self.num_heads % 16 == 0:
            return self.num_heads
        kv = max(1, self.num_kv_heads)
        group = self.num_heads // kv
        # grow per-group width until total % 16 == 0, cap overhead at 15%
        for g in range(group + 1, group * 2):
            total = g * kv
            if total % 16 == 0 and total <= math.ceil(self.num_heads * 1.15):
                return total
        return self.num_heads

    @property
    def heads_shardable(self) -> bool:
        return self.padded_heads > 0 and self.padded_heads % 16 == 0

    @property
    def has_attention(self) -> bool:
        return self.family in (DENSE, MOE, HYBRID, ENCODER, VLM)

    @property
    def has_ssm(self) -> bool:
        return self.family in (SSM, HYBRID)

    @property
    def has_mlp(self) -> bool:
        return self.family in (DENSE, HYBRID, ENCODER, VLM)

    @property
    def has_moe(self) -> bool:
        return self.family == MOE

    @property
    def expert_sharding(self) -> str:
        """EP when experts divide the model axis, else per-expert FFN TP."""
        if not self.has_moe:
            return "none"
        return "ep" if self.num_experts % 16 == 0 else "tp"

    @property
    def supports_decode(self) -> bool:
        return self.family != ENCODER

    @property
    def subquadratic(self) -> bool:
        """Eligible for long_500k: SSM / hybrid / sliding-window attention."""
        if self.family == SSM:
            return True
        if not self.has_attention:
            return True
        return self.sliding_window > 0

    # -- parameter accounting (shape math only) -------------------------------
    def _attn_params(self) -> int:
        if not self.has_attention:
            return 0
        h, kv, hd, m = self.padded_heads, self.num_kv_heads, self.head_dim, self.d_model
        p = m * h * hd + 2 * m * kv * hd + h * hd * m
        if self.qkv_bias:
            p += h * hd + 2 * kv * hd
        return p

    def _mlp_params(self) -> int:
        if not self.has_mlp:
            return 0
        if self.mlp_act == "silu":
            return 3 * self.d_model * self.d_ff
        return 2 * self.d_model * self.d_ff + self.d_ff + self.d_model

    def _moe_params(self) -> int:
        if not self.has_moe:
            return 0
        per_expert = 3 * self.d_model * self.d_ff
        return self.num_experts * per_expert + self.d_model * self.num_experts

    def _ssm_params(self) -> int:
        if not self.has_ssm:
            return 0
        m, di, n, r, c = (self.d_model, self.d_inner, self.ssm_state,
                          self.dt_rank_eff, self.ssm_conv)
        p = m * 2 * di            # in_proj (x, z)
        p += di * c + di          # depthwise conv (+ bias)
        p += di * (r + 2 * n)     # x_proj -> (dt, B, C)
        p += r * di + di          # dt_proj
        p += di * n + di          # A_log, D
        p += di * m               # out_proj
        return p

    def _norm_params(self) -> int:
        if self.norm_type == "nonparam_ln":
            return 0
        per = self.d_model * (2 if self.norm_type == "layernorm" else 1)
        n_norms = 2 if (self.has_mlp or self.has_moe) else 1
        if self.family == HYBRID:
            n_norms = 2
        return per * n_norms

    def param_count(self, padded: bool = False) -> int:
        """Total parameters. padded=False gives the TRUE model size used for
        MODEL_FLOPS = 6·N·D; padded=True matches the allocated tree."""
        vocab = self.padded_vocab if padded else self.vocab_size
        heads_saved = 0
        if not padded and self.padded_heads != self.num_heads:
            hd, m = self.head_dim, self.d_model
            heads_saved = (self.padded_heads - self.num_heads) * hd * m * 2
        per_layer = (self._attn_params() + self._mlp_params()
                     + self._moe_params() + self._ssm_params()
                     + self._norm_params()) - heads_saved
        embed = 0 if self.family == ENCODER else vocab * self.d_model
        head = 0 if self.tie_embeddings else vocab * self.d_model
        final_norm = 0 if self.norm_type == "nonparam_ln" else (
            self.d_model * (2 if self.norm_type == "layernorm" else 1))
        return self.num_layers * per_layer + embed + head + final_norm

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k of num_experts)."""
        if not self.has_moe:
            return self.param_count()
        full = self.param_count()
        moe_all = self.num_layers * self.num_experts * 3 * self.d_model * self.d_ff
        moe_active = self.num_layers * self.top_k * 3 * self.d_model * self.d_ff
        return full - moe_all + moe_active

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# -- input shapes (assignment) -------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeSpec, ...] = (
    ShapeSpec("train_4k", 4_096, 256, "train"),
    ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    ShapeSpec("decode_32k", 32_768, 128, "decode"),
    ShapeSpec("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs, per the assignment skip rules."""
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention (pure full-attention arch)"
    return True, ""
