"""Shared neural-net layers: norms, RoPE, flash attention (custom VJP),
decode attention, MLPs.

The flash attention here is the pure-jnp oracle/production fallback: a
blockwise-streamed softmax identical in structure to the Pallas kernel in
`repro.kernels.flash_attention`. It carries a hand-written backward pass so
that neither forward nor backward ever materializes an (Sq × Skv) score
matrix — this is what makes the 32k/500k dry-run cells compile with sane
memory footprints.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# -- norms ---------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: Optional[jax.Array]) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + 1e-6)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm(x: jax.Array, w: Optional[jax.Array], b: Optional[jax.Array]) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    if w is not None:
        y = y * w.astype(jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def apply_norm(norm_type: str, x: jax.Array, params: Optional[dict]) -> jax.Array:
    if norm_type == "rmsnorm":
        return rmsnorm(x, params["scale"] if params else None)
    if norm_type == "layernorm":
        return layernorm(x, params["scale"], params["bias"])
    if norm_type == "nonparam_ln":
        return layernorm(x, None, None)
    raise ValueError(norm_type)


# -- rotary embeddings ----------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) with D even; positions: (B, S) absolute positions."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- flash attention (blockwise, custom VJP) ------------------------------------
def _pad_to(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _block_mask(qpos, kpos, causal: bool, window: int, prefix_len: int = 0):
    """qpos (Bq,), kpos (Bk,) → (Bq, Bk) bool mask of VALID entries.
    prefix_len > 0 gives prefix-LM masking: positions < prefix_len are
    bidirectionally visible (PaliGemma-style image+prefix block)."""
    present = kpos[None, :] >= 0
    valid = present
    if causal:
        valid = present & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            valid &= kpos[None, :] > qpos[:, None] - window
        if prefix_len > 0:
            valid |= present & (kpos[None, :] < prefix_len)
    return valid


def _scan_map(f, xs, unroll):
    """lax.map with an unroll option (cost-calibration compiles unroll so
    XLA's HloCostAnalysis sees every iteration)."""
    def body(_, x):
        return None, f(x)
    _, ys = jax.lax.scan(body, None, xs, unroll=unroll)
    return ys


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11))
def flash_attention(q, k, v, q_positions, kv_positions,
                    causal: bool = True, window: int = 0,
                    prefix_len: int = 0,
                    block_q: int = 512, block_kv: int = 1024,
                    unroll: bool = False, banded: bool = False):
    """Blockwise attention. q (B,Sq,H,D); k,v (B,Skv,KV,D); GQA via H = KV*G.
    positions are absolute (used for RoPE-independent masking); kv position
    -1 marks padding. Returns (B, Sq, H, D) in q.dtype.
    """
    out, _ = _flash_fwd(q, k, v, q_positions, kv_positions,
                        causal, window, prefix_len, block_q, block_kv,
                        unroll, banded)
    return out


def _flash_fwd(q, k, v, q_positions, kv_positions, causal, window, prefix_len,
               block_q, block_kv, unroll=False, banded=False):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(D)

    qp = _pad_to(q, 1, block_q)
    qpos = _pad_to(q_positions, 1, block_q, value=-1)
    kp = _pad_to(k, 1, block_kv)
    vp = _pad_to(v, 1, block_kv)
    kpos = _pad_to(kv_positions, 1, block_kv, value=-1)
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_kv

    qb = qp.reshape(B, nq, block_q, KV, G, D).astype(jnp.float32) * scale
    kb = kp.reshape(B, nk, block_kv, KV, D).astype(jnp.float32)
    vb = vp.reshape(B, nk, block_kv, KV, D).astype(jnp.float32)
    qposb = qpos.reshape(B, nq, block_q)
    kposb = kpos.reshape(B, nk, block_kv)

    # Banded mode (causal sliding window): per q block only the
    # ceil((window+block_q)/block_kv)+1 kv blocks intersecting
    # [q_start - window, q_end] are touched — a 90%+ FLOP/byte cut for
    # long sequences with small windows (§Perf opt A).
    use_band = banded and causal and window > 0 and prefix_len == 0
    nkw = min(nk, (window + block_q + block_kv - 1) // block_kv + 1)

    def per_q_block(qblk, qpos_blk):
        # qblk (B, block_q, KV, G, D); qpos_blk (B, block_q)
        def inner(carry, kblk, vblk, kpos_blk, live):
            m, l, acc = carry
            s = jnp.einsum("bqkgd,bjkd->bkgqj", qblk, kblk)
            mask = jax.vmap(_block_mask, in_axes=(0, 0, None, None, None))(
                qpos_blk, kpos_blk, causal, window, prefix_len)  # (B, bq, bk)
            mask = mask & live
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkgqj,bjkd->bkgqd", p, vblk)
            return (m_new, l_new, acc_new)

        m0 = jnp.full((B, KV, G, block_q), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, block_q), jnp.float32)
        a0 = jnp.zeros((B, KV, G, block_q, D), jnp.float32)

        if use_band:
            # min valid position across the whole block (pads excluded);
            # banded mode assumes near-uniform positions across the batch
            qmin = jnp.min(jnp.where(qpos_blk >= 0, qpos_blk, 2 ** 30))
            jb0 = jnp.clip((qmin - window) // block_kv, 0, nk - 1)

            def kv_step(carry, i):
                j = jnp.clip(jb0 + i, 0, nk - 1)
                live = (jb0 + i) <= (nk - 1)        # clamp guard: no dups
                kblk = jax.lax.dynamic_index_in_dim(kb, j, 1, keepdims=False)
                vblk = jax.lax.dynamic_index_in_dim(vb, j, 1, keepdims=False)
                kpos_blk = jax.lax.dynamic_index_in_dim(kposb, j, 1,
                                                        keepdims=False)
                return inner(carry, kblk, vblk, kpos_blk, live), None

            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nkw), unroll=unroll)
        else:
            def kv_step(carry, xs):
                kblk, vblk, kpos_blk = xs
                return inner(carry, kblk, vblk, kpos_blk, True), None

            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0),
                (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
                 kposb.transpose(1, 0, 2)), unroll=unroll)
        l = jnp.maximum(l, 1e-30)
        o = acc / l[..., None]                      # (B, KV, G, bq, D)
        lse = m + jnp.log(l)                        # (B, KV, G, bq)
        return o, lse

    o_blocks, lse_blocks = _scan_map(
        lambda xs: per_q_block(*xs),
        (qb.transpose(1, 0, 2, 3, 4, 5), qposb.transpose(1, 0, 2)), unroll)
    # o_blocks (nq, B, KV, G, bq, D) → (B, Sq, H, D)
    o = o_blocks.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * block_q, H, D)
    lse = lse_blocks.transpose(1, 0, 4, 2, 3).reshape(B, nq * block_q, H)
    o = o[:, :Sq].astype(q.dtype)
    lse = lse[:, :Sq]
    return o, (q, k, v, q_positions, kv_positions, o, lse)


def _flash_bwd(causal, window, prefix_len, block_q, block_kv, unroll, banded,
               res, g):
    q, k, v, q_positions, kv_positions, o, lse = res
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    scale = 1.0 / math.sqrt(D)

    qp = _pad_to(q, 1, block_q).astype(jnp.float32)
    op = _pad_to(o, 1, block_q).astype(jnp.float32)
    gp = _pad_to(g, 1, block_q).astype(jnp.float32)
    lsep = _pad_to(lse, 1, block_q, value=0.0)
    qpos = _pad_to(q_positions, 1, block_q, value=-1)
    kp = _pad_to(k, 1, block_kv).astype(jnp.float32)
    vp = _pad_to(v, 1, block_kv).astype(jnp.float32)
    kpos = _pad_to(kv_positions, 1, block_kv, value=-1)
    nq, nk = qp.shape[1] // block_q, kp.shape[1] // block_kv

    qb = qp.reshape(B, nq, block_q, KV, G, D)
    gb = gp.reshape(B, nq, block_q, KV, G, D)
    ob = op.reshape(B, nq, block_q, KV, G, D)
    lseb = lsep.reshape(B, nq, block_q, KV, G)   # lse laid out (B,S,H)→(...,KV,G)
    qposb = qpos.reshape(B, nq, block_q)
    kb = kp.reshape(B, nk, block_kv, KV, D)
    vb = vp.reshape(B, nk, block_kv, KV, D)
    kposb = kpos.reshape(B, nk, block_kv)

    # delta_i = rowsum(dO * O)
    delta = jnp.sum(gb * ob, axis=-1)            # (B, nq, bq, KV, G)

    def per_kv_block(kblk, vblk, kpos_blk):
        # accumulate dk, dv over all q blocks; also emit dq contribution
        def q_step(carry, xs):
            dk, dv = carry
            qblk, gblk, lse_blk, dlt_blk, qpos_blk = xs
            s = jnp.einsum("bqkgd,bjkd->bkgqj", qblk * scale, kblk)
            mask = jax.vmap(_block_mask, in_axes=(0, 0, None, None, None))(
                qpos_blk, kpos_blk, causal, window, prefix_len)
            s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
            p = jnp.exp(s - lse_blk.transpose(0, 2, 3, 1)[..., None])  # (B,KV,G,bq,bk)
            dp = jnp.einsum("bqkgd,bjkd->bkgqj", gblk, vblk)
            ds = p * (dp - dlt_blk.transpose(0, 2, 3, 1)[..., None])
            dq_blk = jnp.einsum("bkgqj,bjkd->bqkgd", ds, kblk) * scale
            dk = dk + jnp.einsum("bkgqj,bqkgd->bjkd", ds, qblk * scale)
            dv = dv + jnp.einsum("bkgqj,bqkgd->bjkd", p, gblk)
            return (dk, dv), dq_blk

        dk0 = jnp.zeros((B, block_kv, KV, D), jnp.float32)
        dv0 = jnp.zeros((B, block_kv, KV, D), jnp.float32)
        (dk, dv), dq_parts = jax.lax.scan(
            q_step, (dk0, dv0),
            (qb.transpose(1, 0, 2, 3, 4, 5), gb.transpose(1, 0, 2, 3, 4, 5),
             lseb.transpose(1, 0, 2, 3, 4), delta.transpose(1, 0, 2, 3, 4),
             qposb.transpose(1, 0, 2)), unroll=unroll)
        return dk, dv, dq_parts  # dq_parts (nq, B, bq, KV, G, D)

    dk_blocks, dv_blocks, dq_sum = _scan_map(
        lambda xs: per_kv_block(*xs),
        (kb.transpose(1, 0, 2, 3, 4), vb.transpose(1, 0, 2, 3, 4),
         kposb.transpose(1, 0, 2)), unroll)
    # dq: sum over kv blocks → (nq, B, bq, KV, G, D)
    dq = dq_sum.sum(axis=0).transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * block_q, H, D)
    dk = dk_blocks.transpose(1, 0, 2, 3, 4).reshape(B, nk * block_kv, KV, D)
    dv = dv_blocks.transpose(1, 0, 2, 3, 4).reshape(B, nk * block_kv, KV, D)
    return (dq[:, :Sq].astype(q.dtype), dk[:, :Skv].astype(k.dtype),
            dv[:, :Skv].astype(v.dtype), None, None)


def _flash_fwd_rule(q, k, v, qpos, kpos, causal, window, prefix_len,
                    block_q, block_kv, unroll, banded):
    out, res = _flash_fwd(q, k, v, qpos, kpos, causal, window, prefix_len,
                          block_q, block_kv, unroll, banded)
    return out, res


flash_attention.defvjp(_flash_fwd_rule, _flash_bwd)


# -- reference (naive) attention for tests --------------------------------------
def reference_attention(q, k, v, q_positions, kv_positions,
                        causal=True, window=0, prefix_len=0):
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, Sq, KV, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qf, kf) / math.sqrt(D)
    mask = jax.vmap(_block_mask, in_axes=(0, 0, None, None, None))(
        q_positions, kv_positions, causal, window, prefix_len)
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bqkgd", p, vf).reshape(B, Sq, H, D)
    return o.astype(q.dtype)


# -- decode attention (single query token vs. long KV cache) --------------------
def decode_attention(q, k_cache, v_cache, cache_positions, q_position):
    """q (B, H, D); caches (B, L, KV, D); cache_positions (B, L) absolute
    positions of each cache slot (-1 = empty); q_position (B,).
    Returns (B, H, D). Pure jnp — the Pallas twin lives in kernels/decode_attention.
    """
    B, H, D = q.shape
    _, L, KV, _ = k_cache.shape
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, G, D) / math.sqrt(D)
    s = jnp.einsum("bkgd,blkd->bkgl", qf, k_cache.astype(jnp.float32))
    valid = (cache_positions >= 0) & (cache_positions <= q_position[:, None])
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgl,blkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


# -- paged decode attention (block-table addressed page pool) -------------------
def decode_attention_paged(q, k_pool, v_pool, block_tables, q_position, *,
                           head_dim=None, quant=None):
    """q (B, H, D); pools pre-folded (KV, P, ps, Dp) with Dp = head_dim
    zero-padded to the 128-lane width — a GLOBAL page pool shared by all
    sequences (and, for a shared instruction prefix, by all batch rows);
    block_tables (B, NB) int32 page ids (-1 = invalid entry); q_position
    (B,). Returns (B, H, D).

    Paged-layout invariant: logical slot index == absolute token position,
    so slot validity is just `index <= q_position` plus table-entry
    validity. quant (dict or None) carries int8 shadow pools "kq"/"vq"
    (KV, P, ps, Dp), per-page scales "kscale"/"vscale" (KV, P) and frozen
    flags "flags" (P,): frozen pages are read from the dequantized shadow,
    live pages from the fp pool. Gathered pages are sliced back to the true
    head_dim before the softmax so the math is bit-identical to the dense
    layout. Pure jnp (gathers the pages); the zero-gather Pallas twin
    lives in kernels/decode_attention.
    """
    B, H, D = q.shape
    KV, P, ps, Dp = k_pool.shape
    D = head_dim or D
    NB = block_tables.shape[1]
    safe = jnp.clip(block_tables, 0, P - 1)
    k = k_pool[:, safe]                               # (KV, B, NB, ps, Dp)
    v = v_pool[:, safe]
    if quant is not None:
        fl = (quant["flags"][safe] > 0)[None, :, :, None, None]
        kdq = (quant["kq"][:, safe].astype(jnp.float32)
               * quant["kscale"][:, safe][..., None, None]).astype(k.dtype)
        vdq = (quant["vq"][:, safe].astype(jnp.float32)
               * quant["vscale"][:, safe][..., None, None]).astype(v.dtype)
        k = jnp.where(fl, kdq, k)
        v = jnp.where(fl, vdq, v)
    k = k.transpose(1, 2, 3, 0, 4).reshape(B, NB * ps, KV, Dp)[..., :D]
    v = v.transpose(1, 2, 3, 0, 4).reshape(B, NB * ps, KV, Dp)[..., :D]
    pos = jnp.broadcast_to(jnp.arange(NB * ps, dtype=jnp.int32)[None],
                           (B, NB * ps))
    valid = jnp.repeat(block_tables >= 0, ps, axis=1)
    pos = jnp.where(valid, pos, -1)
    return decode_attention(q, k, v, pos, q_position)


def prefix_suffix_attention(q, k_prefix, v_prefix, k_suf, v_suf,
                            positions, prefix_len):
    """Shared-prefix prefill attention WITHOUT replicating the prefix KV.

    q (B, S, H, D) suffix queries; k_prefix/v_prefix (Lp, KV, D) — ONE copy
    of the shared prefix KV, broadcast across the batch inside the einsum
    (no (B, Lp) materialization); k_suf/v_suf (B, S, KV, D) the suffix's
    own KV; positions (B, S) absolute (-1 = pad); prefix_len scalar number
    of valid prefix tokens (<= Lp). Prefix tokens are fully visible to
    every suffix query (their positions precede all suffix positions);
    the suffix part is causal. The two score blocks are merged with a
    joint streamed-softmax so the result equals one softmax over
    [prefix ++ suffix].
    """
    B, S, H, D = q.shape
    KV = k_suf.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(B, S, KV, G, D) / math.sqrt(D)

    ss = jnp.einsum("bskgd,btkd->bkgst", qf, k_suf.astype(jnp.float32))
    ok_s = (positions[:, None, :] >= 0) & \
           (positions[:, None, :] <= positions[:, :, None])       # (B, S, T)
    ss = jnp.where(ok_s[:, None, None], ss, NEG_INF)

    Lp = k_prefix.shape[0]
    if Lp:
        sp = jnp.einsum("bskgd,lkd->bkgsl", qf, k_prefix.astype(jnp.float32))
        ok_p = (jnp.arange(Lp)[None, None, :] < prefix_len) & \
               (positions[:, :, None] >= 0)                       # (B, S, Lp)
        sp = jnp.where(ok_p[:, None, None], sp, NEG_INF)
        m = jnp.maximum(sp.max(axis=-1), ss.max(axis=-1))         # (B,KV,G,S)
        pp = jnp.exp(sp - m[..., None])
        psx = jnp.exp(ss - m[..., None])
        denom = jnp.maximum(pp.sum(-1) + psx.sum(-1), 1e-30)
        o = jnp.einsum("bkgsl,lkd->bskgd", pp, v_prefix.astype(jnp.float32)) \
            + jnp.einsum("bkgst,btkd->bskgd", psx, v_suf.astype(jnp.float32))
    else:
        m = ss.max(axis=-1)
        psx = jnp.exp(ss - m[..., None])
        denom = jnp.maximum(psx.sum(-1), 1e-30)
        o = jnp.einsum("bkgst,btkd->bskgd", psx, v_suf.astype(jnp.float32))
    o = o / denom.transpose(0, 3, 1, 2)[..., None]
    return o.reshape(B, S, H, D).astype(q.dtype)


# -- MLPs ------------------------------------------------------------------------
def swiglu_mlp(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(x @ w_in + b_in)
    return h @ w_out + b_out
