"""Model assembly for all assigned architecture families.

One generic decoder/encoder stack, specialised by `ModelConfig.family`:
  dense/moe/vlm : pre-norm attention + (MLP | MoE) residual blocks
  ssm           : mamba-1 mixer blocks (attention-free)
  hybrid        : parallel attention ∥ mamba heads + MLP (hymba)
  encoder       : bidirectional pre-LN transformer (hubert)

All layers are stacked on a leading axis and executed with `jax.lax.scan`
(+ optional `jax.checkpoint`), keeping the HLO size O(1) in depth — both a
compile-time necessity on this box and the production pattern for 1000+
node runs.

Three modes:
  train   — full-sequence forward, no cache, returns token logits
  prefill — full-sequence forward, emits a decode cache
  decode  — single-token step against a (ring-buffered) KV / SSM cache
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models.config import (DENSE, ENCODER, HYBRID, MOE as MOE_F, SSM,
                                 VLM, ModelConfig)

PyTree = Any


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# =============================== parameters ===================================
def _layer_shapes(cfg: ModelConfig) -> Dict[str, Tuple[Tuple[int, ...], Any]]:
    """Per-layer leaf name → (shape-without-L, dtype)."""
    m, pd = cfg.d_model, _dt(cfg.param_dtype)
    h, kv, hd = cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    out: Dict[str, Tuple[Tuple[int, ...], Any]] = {}

    def norm(prefix: str):
        if cfg.norm_type == "rmsnorm":
            out[f"{prefix}.scale"] = ((m,), pd)
        elif cfg.norm_type == "layernorm":
            out[f"{prefix}.scale"] = ((m,), pd)
            out[f"{prefix}.bias"] = ((m,), pd)
        # nonparam_ln: no params

    if cfg.has_attention:
        norm("ln_attn")
        # 3D layout keeps head vs head_dim sharding choices expressible
        out["attn.wq"] = ((m, h, hd), pd)
        out["attn.wk"] = ((m, kv, hd), pd)
        out["attn.wv"] = ((m, kv, hd), pd)
        out["attn.wo"] = ((h, hd, m), pd)
        if cfg.qkv_bias:
            out["attn.bq"] = ((h, hd), pd)
            out["attn.bk"] = ((kv, hd), pd)
            out["attn.bv"] = ((kv, hd), pd)
    if cfg.has_ssm:
        if not cfg.has_attention:
            norm("ln_ssm")
        di, n, r, k = cfg.d_inner, cfg.ssm_state, cfg.dt_rank_eff, cfg.ssm_conv
        out["ssm.in_x"] = ((m, di), pd)      # split leaves: never slice a
        out["ssm.in_z"] = ((m, di), pd)      # model-sharded dim
        out["ssm.conv_w"] = ((k, di), pd)
        out["ssm.conv_b"] = ((di,), pd)
        out["ssm.x_proj"] = ((di, r + 2 * n), pd)
        out["ssm.dt_proj"] = ((r, di), pd)
        out["ssm.dt_bias"] = ((di,), pd)
        out["ssm.A_log"] = ((di, n), jnp.float32)
        out["ssm.D"] = ((di,), jnp.float32)
        out["ssm.out_proj"] = ((di, m), pd)
    if cfg.has_mlp:
        norm("ln_mlp")
        if cfg.mlp_act == "silu":
            out["mlp.w_gate"] = ((m, cfg.d_ff), pd)
            out["mlp.w_up"] = ((m, cfg.d_ff), pd)
            out["mlp.w_down"] = ((cfg.d_ff, m), pd)
        else:
            out["mlp.w_in"] = ((m, cfg.d_ff), pd)
            out["mlp.b_in"] = ((cfg.d_ff,), pd)
            out["mlp.w_out"] = ((cfg.d_ff, m), pd)
            out["mlp.b_out"] = ((m,), pd)
    if cfg.has_moe:
        norm("ln_mlp")
        e, f = cfg.num_experts, cfg.d_ff
        out["moe.router"] = ((m, e), pd)
        out["moe.w_gate"] = ((e, m, f), pd)
        out["moe.w_up"] = ((e, m, f), pd)
        out["moe.w_down"] = ((e, f, m), pd)
    return out


def param_specs(cfg: ModelConfig) -> PyTree:
    """ShapeDtypeStructs for the full parameter tree (stacked layers)."""
    m, vp, pd = cfg.d_model, cfg.padded_vocab, _dt(cfg.param_dtype)
    tree: Dict[str, Any] = {"layers": {}}
    for name, (shape, dt) in _layer_shapes(cfg).items():
        tree["layers"][name] = jax.ShapeDtypeStruct((cfg.num_layers,) + shape, dt)
    if cfg.family != ENCODER:
        tree["embed"] = jax.ShapeDtypeStruct((vp, m), pd)
    if not cfg.tie_embeddings:
        tree["lm_head"] = jax.ShapeDtypeStruct((m, vp), pd)
    if cfg.norm_type == "rmsnorm":
        tree["final_norm.scale"] = jax.ShapeDtypeStruct((m,), pd)
    elif cfg.norm_type == "layernorm":
        tree["final_norm.scale"] = jax.ShapeDtypeStruct((m,), pd)
        tree["final_norm.bias"] = jax.ShapeDtypeStruct((m,), pd)
    return tree


def init_params(cfg: ModelConfig, key: jax.Array) -> PyTree:
    specs = param_specs(cfg)
    flat_paths, treedef = jax.tree_util.tree_flatten_with_path(specs)
    keys = jax.random.split(key, len(flat_paths))
    vals = []
    for k, (path, s) in zip(keys, flat_paths):
        p = jax.tree_util.keystr(path)
        stacked = "layers" in p
        core_ndim = len(s.shape) - (1 if stacked else 0)
        if "A_log" in p:
            n = s.shape[-1]
            v = jnp.broadcast_to(jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)),
                                 s.shape)
        elif "ssm.D" in p:
            v = jnp.ones(s.shape, jnp.float32)
        elif core_ndim == 1:
            v = (jnp.ones if "scale" in p else jnp.zeros)(s.shape, jnp.float32)
        else:
            if "attn.w" in p:
                start = 1 if stacked else 0
                fan_in = (s.shape[start] if p.endswith(("wq']", "wk']", "wv']"))
                          else s.shape[start] * s.shape[start + 1])
            else:
                fan_in = s.shape[-2]
            std = 1.0 / math.sqrt(max(1, fan_in))
            v = jax.random.normal(k, s.shape, jnp.float32) * std
        vals.append(v.astype(s.dtype))
    return treedef.unflatten(vals)


def param_count_actual(params: PyTree) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ================================ cache =======================================
def cache_specs(cfg: ModelConfig, batch: int, cache_len: int,
                include_row_idx: bool = False) -> Dict[str, Any]:
    """ShapeDtypeStructs for the decode cache. include_row_idx adds the
    per-row write cursor (continuous batching / sharded-length caches —
    the write becomes a masked elementwise update instead of a DUS on a
    sharded dim)."""
    ln, cd = cfg.num_layers, _dt(cfg.compute_dtype)
    out: Dict[str, Any] = {"idx": jax.ShapeDtypeStruct((), jnp.int32)}
    if include_row_idx:
        out["row_idx"] = jax.ShapeDtypeStruct((batch,), jnp.int32)
    if cfg.has_attention:
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        lc = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        out["k"] = jax.ShapeDtypeStruct((ln, batch, lc, kv, hd), cd)
        out["v"] = jax.ShapeDtypeStruct((ln, batch, lc, kv, hd), cd)
        out["slot_pos"] = jax.ShapeDtypeStruct((batch, lc), jnp.int32)
    if cfg.has_ssm:
        out["conv"] = jax.ShapeDtypeStruct(
            (ln, batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32)
        out["h"] = jax.ShapeDtypeStruct(
            (ln, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    return out


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               include_row_idx: bool = False) -> Dict[str, Any]:
    specs = cache_specs(cfg, batch, cache_len, include_row_idx)
    out = {k: jnp.zeros(s.shape, s.dtype) for k, s in specs.items()}
    if "slot_pos" in out:
        out["slot_pos"] = jnp.full(specs["slot_pos"].shape, -1, jnp.int32)
    return out


def padded_head_dim(head_dim: int) -> int:
    """Pool lane width: head_dim zero-padded up to the TPU register lane
    count so the Pallas kernel's (KV·P, ps, 128) view is a free reshape."""
    return -(-head_dim // 128) * 128


def paged_cache_specs(cfg: ModelConfig, num_pages: int, page_size: int,
                      batch: int, quant: bool = False) -> Dict[str, Any]:
    """Paged KV layout: one GLOBAL pool of fixed-size pages per layer
    instead of per-row dense caches.  Sequences address the pool through a
    per-row block table (passed separately, host-managed), so a shared
    instruction prefix is one set of pages referenced by every row.  SSM
    conv/h state stays per-row dense — it is O(1) in sequence length.

    Pools are stored pre-folded as (layers, KV, P, ps, Dp) with head_dim
    zero-padded to Dp = 128 lanes: the per-layer (KV, P, ps, Dp) slice
    reshapes to the Pallas kernel's (KV·P, ps, Dp) view for free, so the
    decode step pays no per-step transpose.  With `quant`, int8 shadow
    pools plus per-(layer, kv-head, page) scales are added for
    quantize-on-commit of frozen shared-prefix pages."""
    ln, cd = cfg.num_layers, _dt(cfg.compute_dtype)
    out: Dict[str, Any] = {"idx": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.has_attention:
        kv, hd = cfg.num_kv_heads, cfg.head_dim
        dp = padded_head_dim(hd)
        out["k"] = jax.ShapeDtypeStruct((ln, kv, num_pages, page_size, dp), cd)
        out["v"] = jax.ShapeDtypeStruct((ln, kv, num_pages, page_size, dp), cd)
        if quant:
            out["kq"] = jax.ShapeDtypeStruct(
                (ln, kv, num_pages, page_size, dp), jnp.int8)
            out["vq"] = jax.ShapeDtypeStruct(
                (ln, kv, num_pages, page_size, dp), jnp.int8)
            out["kscale"] = jax.ShapeDtypeStruct((ln, kv, num_pages),
                                                 jnp.float32)
            out["vscale"] = jax.ShapeDtypeStruct((ln, kv, num_pages),
                                                 jnp.float32)
    if cfg.has_ssm:
        out["conv"] = jax.ShapeDtypeStruct(
            (ln, batch, cfg.ssm_conv - 1, cfg.d_inner), jnp.float32)
        out["h"] = jax.ShapeDtypeStruct(
            (ln, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    return out


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int,
                     batch: int = 0, quant: bool = False) -> Dict[str, Any]:
    specs = paged_cache_specs(cfg, num_pages, page_size, batch, quant)
    return {k: jnp.zeros(s.shape, s.dtype) for k, s in specs.items()}


# ================================ blocks ======================================
def _norm_p(lp: Dict[str, jax.Array], prefix: str) -> Optional[dict]:
    scale = lp.get(f"{prefix}.scale")
    bias = lp.get(f"{prefix}.bias")
    if scale is None and bias is None:
        return None
    return {"scale": scale, "bias": bias}


def _fold_write(x: jax.Array, dp: int) -> jax.Array:
    """(..., KV, D) → (KV, ..., Dp): move the kv-head axis to the front and
    zero-pad head_dim to the pool's padded lane width."""
    x = jnp.moveaxis(x, -2, 0)
    pad = dp - x.shape[-1]
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def _dequant_pages(qd: Dict[str, jax.Array], safe_pages: jax.Array,
                   kp: jax.Array, vp: jax.Array):
    """Replace frozen (quantized) pages of a gathered fp view with their
    dequantized int8 shadow.  safe_pages (npre,) clipped page ids;
    kp/vp (KV, npre, ps, Dp) gathered fp pages."""
    fl = qd["flags"][safe_pages] > 0                       # (npre,)
    kdq = (qd["kq"][:, safe_pages].astype(jnp.float32)
           * qd["kscale"][:, safe_pages][..., None, None]).astype(kp.dtype)
    vdq = (qd["vq"][:, safe_pages].astype(jnp.float32)
           * qd["vscale"][:, safe_pages][..., None, None]).astype(vp.dtype)
    kp = jnp.where(fl[None, :, None, None], kdq, kp)
    vp = jnp.where(fl[None, :, None, None], vdq, vp)
    return kp, vp


def _attention(cfg: ModelConfig, x, lp, positions, mode, ck, cv, slot_pos, idx,
               attn_fn=None, decode_attn_fn=None, extend_offset: int = 0,
               row_idx=None, kv_cs=MOE.Identity, paged=None):
    """x (B,S,M). Returns (out (B,S,M), new_ck, new_cv).
    extend_offset > 0 (prefill mode): attend over [cache[:offset] ++ new] and
    write the new K/V at slot offset — chunked prefill / shared-prefix reuse.
    paged (dict or None): block-table addressed page-pool layout — ck/cv are
    then pre-folded (KV, P, ps, Dp) pools (Dp = head_dim padded to 128),
    paged["block_tables"] is (B, NB) page ids
    (-1 = invalid; invalid/out-of-range writes are dropped), prefill may
    carry paged["prefix_table"]/["prefix_len"] pointing at shared prefix
    pages that are read in place, never replicated per row, and
    paged["quant"] (if set) holds int8 shadow pools + per-page scales +
    frozen flags for dequantizing committed shared pages on read."""
    B, S, m = x.shape
    h, kv, hd = cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    cd = _dt(cfg.compute_dtype)
    q = jnp.einsum("bsm,mhd->bshd", x, lp["attn.wq"].astype(cd))
    k = jnp.einsum("bsm,mhd->bshd", x, lp["attn.wk"].astype(cd))
    v = jnp.einsum("bsm,mhd->bshd", x, lp["attn.wv"].astype(cd))
    if cfg.qkv_bias:
        q = q + lp["attn.bq"].astype(cd)
        k = k + lp["attn.bk"].astype(cd)
        v = v + lp["attn.bv"].astype(cd)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if mode != "decode":
        k = kv_cs(k)        # sequence-parallel attention: kv replicated
        v = kv_cs(v)

    new_ck, new_cv = ck, cv
    if paged is not None and mode == "decode":
        bt = paged["block_tables"]
        KV_, P_, ps_, Dp_ = ck.shape
        NB_ = bt.shape[1]
        pos = positions[:, 0]                                     # (B,)
        blk = jnp.clip(pos, 0, None) // ps_
        entry = jnp.take_along_axis(
            bt, jnp.clip(blk, 0, NB_ - 1)[:, None], axis=1)[:, 0]
        # beyond table capacity (pos >= NB_·ps_, i.e. past max_len) writes
        # are dropped — the sequence keeps decoding against a frozen cache.
        # The dense layout ring-wraps instead; both are out of contract
        # past max_len and the layouts' byte-equality only holds within it.
        ok = (pos >= 0) & (blk < NB_) & (entry >= 0)
        page = jnp.where(ok, entry, P_)        # P_ is out of bounds → drop
        off = jnp.clip(pos, 0, None) % ps_
        # per-axis indexing keeps the P_ out-of-bounds drop trick safe: the
        # page axis is indexed on its own, so an invalid id can never fold
        # into a neighbouring kv-head's page 0
        new_ck = ck.at[:, page, off].set(
            _fold_write(k[:, 0], Dp_).astype(ck.dtype), mode="drop")
        new_cv = cv.at[:, page, off].set(
            _fold_write(v[:, 0], Dp_).astype(cv.dtype), mode="drop")
        fn = decode_attn_fn or L.decode_attention_paged
        o = fn(q[:, 0], new_ck, new_cv, bt, pos, head_dim=hd,
               quant=paged.get("quant"))[:, None]
    elif paged is not None:
        # paged prefill: suffix flash vs its own KV merged with a broadcast
        # (never replicated) read of the shared prefix pages; new KV is
        # committed straight into the rows' pages
        assert mode == "prefill" and not cfg.sliding_window
        bt = paged["block_tables"]
        pt = paged.get("prefix_table")
        plen = paged.get("prefix_len", jnp.int32(0))
        KV_, P_, ps_, Dp_ = ck.shape
        NB_ = bt.shape[1]
        if pt is not None and pt.shape[0]:
            safe_pt = jnp.clip(pt, 0, P_ - 1)
            kp = ck[:, safe_pt]                       # (KV, npre, ps, Dp)
            vp = cv[:, safe_pt]
            if paged.get("quant") is not None:
                kp, vp = _dequant_pages(paged["quant"], safe_pt, kp, vp)
            kp = kp.transpose(1, 2, 0, 3).reshape(-1, KV_, Dp_)[..., :hd]
            vp = vp.transpose(1, 2, 0, 3).reshape(-1, KV_, Dp_)[..., :hd]
        else:
            kp = jnp.zeros((0, KV_, hd), ck.dtype)
            vp = jnp.zeros((0, KV_, hd), cv.dtype)
        o = L.prefix_suffix_attention(q, kp, vp, k, v, positions, plen)
        blk = jnp.clip(positions, 0, None) // ps_                 # (B, S)
        entry = jnp.take_along_axis(bt, jnp.clip(blk, 0, NB_ - 1), axis=1)
        ok = (positions >= 0) & (blk < NB_) & (entry >= 0)
        page = jnp.where(ok, entry, P_)
        off = jnp.clip(positions, 0, None) % ps_
        new_ck = ck.at[:, page, off].set(
            _fold_write(k, Dp_).astype(ck.dtype), mode="drop")
        new_cv = cv.at[:, page, off].set(
            _fold_write(v, Dp_).astype(cv.dtype), mode="drop")
    elif mode == "decode":
        lc = ck.shape[1]
        if row_idx is not None:
            # per-row write slots (continuous batching: ragged fill levels)
            slot_b = row_idx % lc                          # (B,)
            hit = (jnp.arange(lc)[None, :] == slot_b[:, None])  # (B, lc)
            new_ck = jnp.where(hit[:, :, None, None], k.astype(ck.dtype), ck)
            new_cv = jnp.where(hit[:, :, None, None], v.astype(cv.dtype), cv)
            spos = jnp.where(hit, positions[:, :1], slot_pos)
        else:
            slot = idx % lc
            new_ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, slot, 0, 0))
            new_cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, slot, 0, 0))
            spos = jnp.where(jnp.arange(lc)[None, :] == slot, positions[:, :1],
                             slot_pos)
        fn = decode_attn_fn or L.decode_attention
        o = fn(q[:, 0], new_ck, new_cv, spos, positions[:, 0])[:, None]
    elif mode == "prefill" and extend_offset > 0:
        off = extend_offset
        lc = ck.shape[1]
        assert off + S <= lc and not cfg.sliding_window, (off, S, lc)
        k_all = jnp.concatenate([ck[:, :off].astype(k.dtype), k], axis=1)
        v_all = jnp.concatenate([cv[:, :off].astype(v.dtype), v], axis=1)
        kv_pos = jnp.concatenate([slot_pos[:, :off], positions], axis=1)
        fn = attn_fn or L.flash_attention
        o = fn(q, k_all, v_all, positions, kv_pos,
               causal=cfg.causal, window=0,
               prefix_len=cfg.num_prefix_tokens if cfg.family == VLM else 0)
        new_ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, off, 0, 0))
        new_cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, off, 0, 0))
    else:
        fn = attn_fn or L.flash_attention
        o = fn(q, k, v, positions, positions,
               causal=cfg.causal, window=cfg.sliding_window,
               prefix_len=cfg.num_prefix_tokens if cfg.family == VLM else 0)
        if mode == "prefill":
            lc = ck.shape[1]
            if S >= lc:
                shift = S % lc
                new_ck = jnp.roll(k[:, S - lc:].astype(ck.dtype), shift, axis=1)
                new_cv = jnp.roll(v[:, S - lc:].astype(cv.dtype), shift, axis=1)
            else:
                new_ck = jax.lax.dynamic_update_slice(
                    ck, k.astype(ck.dtype), (0, 0, 0, 0))
                new_cv = jax.lax.dynamic_update_slice(
                    cv, v.astype(cv.dtype), (0, 0, 0, 0))
    out = jnp.einsum("bshd,hdm->bsm", o, lp["attn.wo"].astype(cd))
    return out, new_ck, new_cv


def _block(cfg: ModelConfig, x, lp, positions, mode, cache_l, *,
           num_groups=1, dispatch_cs=MOE.Identity, combine_cs=MOE.Identity,
           attn_fn=None, decode_attn_fn=None, scan_fn=None,
           extend_offset: int = 0, kv_cs=MOE.Identity, paged=None):
    """One residual block. cache_l: per-layer cache slice dict (or {})."""
    B, S, m = x.shape
    new_cache = dict(cache_l)
    slot_pos = cache_l.get("slot_pos")
    idx = cache_l.get("idx", jnp.int32(0))
    if paged is not None and "kq" in cache_l:
        # attach this layer's int8 shadow pool + scales (scanned-in slices)
        # alongside the shared frozen-page flags
        paged = {**paged, "quant": {
            "kq": cache_l["kq"], "vq": cache_l["vq"],
            "kscale": cache_l["kscale"], "vscale": cache_l["vscale"],
            "flags": paged["quant_flags"]}}

    if cfg.family == HYBRID:
        xin = L.apply_norm(cfg.norm_type, x, _norm_p(lp, "ln_attn"))
        a, nk, nv = _attention(cfg, xin, lp, positions, mode,
                               cache_l.get("k"), cache_l.get("v"), slot_pos, idx,
                               attn_fn, decode_attn_fn, extend_offset,
                               cache_l.get("row_idx"), kv_cs, paged)
        state = None
        if mode != "train":
            state = M.SSMState(conv=cache_l["conv"], h=cache_l["h"])
        s, new_state = M.mamba_mixer(
            xin, {k[4:]: v for k, v in lp.items() if k.startswith("ssm.")},
            ssm_state_dim=cfg.ssm_state, dt_rank=cfg.dt_rank_eff,
            conv_dim=cfg.ssm_conv, mode=("decode" if mode == "decode" else "train"),
            state=state, scan_fn=scan_fn or M.selective_scan)
        x = x + 0.5 * (a + s)
        if mode != "train":
            new_cache.update(k=nk, v=nv, conv=new_state.conv, h=new_state.h)
        xin2 = L.apply_norm(cfg.norm_type, x, _norm_p(lp, "ln_mlp"))
        x = x + L.swiglu_mlp(xin2, lp["mlp.w_gate"].astype(x.dtype),
                             lp["mlp.w_up"].astype(x.dtype),
                             lp["mlp.w_down"].astype(x.dtype))
        return x, new_cache

    if cfg.family == SSM:
        xin = L.apply_norm(cfg.norm_type, x, _norm_p(lp, "ln_ssm"))
        state = None
        if mode != "train":
            state = M.SSMState(conv=cache_l["conv"], h=cache_l["h"])
        s, new_state = M.mamba_mixer(
            xin, {k[4:]: v for k, v in lp.items() if k.startswith("ssm.")},
            ssm_state_dim=cfg.ssm_state, dt_rank=cfg.dt_rank_eff,
            conv_dim=cfg.ssm_conv, mode=("decode" if mode == "decode" else "train"),
            state=state, scan_fn=scan_fn or M.selective_scan)
        if mode != "train":
            new_cache.update(conv=new_state.conv, h=new_state.h)
        return x + s, new_cache

    # attention families: dense / moe / encoder / vlm
    xin = L.apply_norm(cfg.norm_type, x, _norm_p(lp, "ln_attn"))
    a, nk, nv = _attention(cfg, xin, lp, positions, mode,
                           cache_l.get("k"), cache_l.get("v"), slot_pos, idx,
                           attn_fn, decode_attn_fn, extend_offset,
                           cache_l.get("row_idx"), kv_cs, paged)
    x = x + a
    if mode != "train" and cfg.has_attention:
        new_cache.update(k=nk, v=nv)
    xin2 = L.apply_norm(cfg.norm_type, x, _norm_p(lp, "ln_mlp"))
    if cfg.has_moe:
        moe_p = {k[4:]: v for k, v in lp.items() if k.startswith("moe.")}
        y = MOE.moe_block(xin2.reshape(B * S, m), moe_p,
                          num_experts=cfg.num_experts, top_k=cfg.top_k,
                          capacity_factor=cfg.capacity_factor,
                          num_groups=num_groups, dispatch_cs=dispatch_cs,
                          combine_cs=combine_cs,
                          compute_dtype=_dt(cfg.compute_dtype))
        x = x + y.reshape(B, S, m)
    elif cfg.mlp_act == "silu":
        x = x + L.swiglu_mlp(xin2, lp["mlp.w_gate"].astype(x.dtype),
                             lp["mlp.w_up"].astype(x.dtype),
                             lp["mlp.w_down"].astype(x.dtype))
    else:
        x = x + L.gelu_mlp(xin2, lp["mlp.w_in"].astype(x.dtype),
                           lp["mlp.b_in"].astype(x.dtype),
                           lp["mlp.w_out"].astype(x.dtype),
                           lp["mlp.b_out"].astype(x.dtype))
    return x, new_cache


# ============================== full forward ==================================
_LAYER_CACHE_KEYS = ("k", "v", "kq", "vq", "kscale", "vscale", "conv", "h")


def forward(cfg: ModelConfig, params: PyTree, batch: Dict[str, jax.Array],
            mode: str = "train", cache: Optional[Dict[str, Any]] = None, *,
            remat: bool = True, num_groups: int = 1,
            dispatch_cs=MOE.Identity, combine_cs=MOE.Identity,
            attn_fn=None, decode_attn_fn=None, scan_fn=None,
            logits_cs=MOE.Identity, last_only: bool = False,
            unroll_layers: bool = False, extend_offset: int = 0,
            residual_cs=MOE.Identity, kv_cs=MOE.Identity,
            remat_policy: str = "nothing"
            ) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
    """Run the stack. batch: tokens (B,S) int32 | embeds (B,S,M); positions
    (B,S). Returns (logits (B,S,V), new_cache or None)."""
    cd = _dt(cfg.compute_dtype)
    positions = batch["positions"]

    if "embeds" in batch:                       # encoder / stub frontend
        x = batch["embeds"].astype(cd)
    else:
        x = jnp.take(params["embed"].astype(cd), batch["tokens"], axis=0)
        if cfg.scale_embed:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), cd)
        if cfg.family == VLM and "prefix_embeds" in batch:
            x = jnp.concatenate([batch["prefix_embeds"].astype(cd), x], axis=1)
            positions = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(batch["prefix_embeds"].shape[1],
                                             dtype=jnp.int32)[None],
                                  batch["prefix_embeds"].shape[:2]),
                 batch["positions"] + batch["prefix_embeds"].shape[1]], axis=1)

    stacked = params["layers"]
    shared_cache = {}
    layer_cache = {}
    if cache is not None:
        layer_cache = {k: cache[k] for k in _LAYER_CACHE_KEYS if k in cache}
        shared_cache = {k: v for k, v in cache.items()
                        if k not in _LAYER_CACHE_KEYS}

    idx = shared_cache.get("idx", jnp.int32(0))
    slot_pos = shared_cache.get("slot_pos")
    row_idx = shared_cache.get("row_idx")
    paged = None
    if "block_tables" in shared_cache:
        paged = {"block_tables": shared_cache["block_tables"]}
        if "prefix_table" in shared_cache:
            paged["prefix_table"] = shared_cache["prefix_table"]
            paged["prefix_len"] = shared_cache.get("prefix_len", jnp.int32(0))
        if "quant_flags" in shared_cache:
            paged["quant_flags"] = shared_cache["quant_flags"]

    x = residual_cs(x)

    def body(x, xs):
        lp, cl = xs
        cl = dict(cl)
        if slot_pos is not None:
            cl["slot_pos"] = slot_pos
        if row_idx is not None:
            cl["row_idx"] = row_idx
        cl["idx"] = idx
        y, nc = _block(cfg, x, lp, positions, mode, cl,
                       num_groups=num_groups, dispatch_cs=dispatch_cs,
                       combine_cs=combine_cs, attn_fn=attn_fn,
                       decode_attn_fn=decode_attn_fn, scan_fn=scan_fn,
                       extend_offset=extend_offset, kv_cs=kv_cs, paged=paged)
        y = residual_cs(y)
        nc = {k: nc[k] for k in _LAYER_CACHE_KEYS if k in nc}
        return y, nc

    policies = {
        "nothing": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }
    body_fn = jax.checkpoint(body, policy=policies[remat_policy]) \
        if (remat and mode == "train") else body

    x, new_layer_cache = jax.lax.scan(body_fn, x, (stacked, layer_cache),
                                      unroll=unroll_layers)

    fn_params = {k: v for k, v in params.items() if k.startswith("final_norm")}
    x = L.apply_norm(cfg.norm_type, x, _norm_p(fn_params, "final_norm"))

    if last_only:
        x = x[:, -1:]
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head.astype(cd)
    logits = logits_cs(logits)

    new_cache = None
    if mode != "train" and cache is not None:
        new_cache = dict(new_layer_cache)
        if mode == "decode":
            lc = cache["k"].shape[2] if "k" in cache else 0
            if slot_pos is not None:
                if row_idx is not None:
                    hit = jnp.arange(lc)[None, :] == (row_idx % lc)[:, None]
                else:
                    hit = (jnp.arange(lc) == idx % lc)[None, :]
                new_cache["slot_pos"] = jnp.where(hit, positions[:, :1],
                                                  slot_pos)
            if row_idx is not None:
                new_cache["row_idx"] = row_idx + 1
            new_cache["idx"] = idx + 1
        else:  # prefill
            S = positions.shape[1]
            off = extend_offset
            if slot_pos is not None:
                lc = cache["k"].shape[2]
                if off > 0:
                    pad = jnp.full((positions.shape[0], lc - off - S), -1,
                                   jnp.int32)
                    new_cache["slot_pos"] = jnp.concatenate(
                        [slot_pos[:, :off], positions, pad], axis=1)
                elif S >= lc:
                    last = positions[:, S - lc:]
                    new_cache["slot_pos"] = jnp.roll(last, S % lc, axis=1)
                else:
                    pad = jnp.full((positions.shape[0], lc - S), -1, jnp.int32)
                    new_cache["slot_pos"] = jnp.concatenate([positions, pad], axis=1)
            new_cache["idx"] = jnp.int32(off + S)
    return logits, new_cache


def lm_loss(cfg: ModelConfig, logits: jax.Array, labels: jax.Array,
            mask: jax.Array) -> jax.Array:
    """Cross-entropy over the (padded) vocab; labels are < vocab_size so
    padded logit columns never receive probability mass via the label path —
    they only inflate the partition function, which is fine at init and
    irrelevant for roofline purposes."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    true = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = (lse - true) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)
