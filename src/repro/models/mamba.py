"""Mamba-1 selective SSM block (falcon-mamba / hymba's SSM heads).

Training/prefill uses a chunked parallel scan: `lax.scan` over sequence
chunks carrying the hidden state, `lax.associative_scan` inside each chunk.
Peak memory is O(B · chunk · d_inner · state) instead of O(B · S · d · N),
which is what lets the long_500k cells compile. Decode is the O(1)
single-step recurrence over carried (conv_state, ssm_state).

The Pallas twin (same chunked structure, VMEM-tiled) lives in
`repro.kernels.selective_scan`; `selective_scan_ref` below is the shared
sequential oracle.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class SSMState(NamedTuple):
    conv: jax.Array   # (B, conv-1, d_inner) last inputs seen by the conv
    h: jax.Array      # (B, d_inner, state) SSM hidden state


def init_ssm_state(batch: int, d_inner: int, state: int, conv: int,
                   dtype=jnp.float32) -> SSMState:
    return SSMState(conv=jnp.zeros((batch, conv - 1, d_inner), dtype),
                    h=jnp.zeros((batch, d_inner, state), dtype))


def _causal_conv(x: jax.Array, conv_w: jax.Array, conv_b: jax.Array,
                 prev: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x (B,S,D); conv_w (K,D); prev (B,K-1,D).
    Returns (y (B,S,D), new_prev)."""
    K = conv_w.shape[0]
    xx = jnp.concatenate([prev.astype(x.dtype), x], axis=1)    # (B, S+K-1, D)
    y = sum(xx[:, i:i + x.shape[1]] * conv_w[i][None, None, :]
            for i in range(K))
    y = y + conv_b[None, None, :]
    new_prev = xx[:, -(K - 1):] if K > 1 else prev
    return y, new_prev


def selective_scan(u: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, D: jax.Array, h0: jax.Array,
                   chunk: int = 256, unroll: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Chunked parallel selective scan.
    u, dt: (Bz, S, Di); A: (Di, N); B, C: (Bz, S, N); D: (Di,); h0: (Bz, Di, N).
    Returns (y (Bz, S, Di) fp32, h_final (Bz, Di, N))."""
    Bz, S, Di = u.shape
    N = A.shape[1]
    pad = (-S) % chunk
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    nc = u.shape[1] // chunk

    uf = u.astype(jnp.float32).reshape(Bz, nc, chunk, Di)
    dtf = dt.astype(jnp.float32).reshape(Bz, nc, chunk, Di)
    Bf = B.astype(jnp.float32).reshape(Bz, nc, chunk, N)
    Cf = C.astype(jnp.float32).reshape(Bz, nc, chunk, N)
    Af = A.astype(jnp.float32)

    def chunk_step(h, xs):
        uc, dtc, bc, cc = xs                       # (Bz, chunk, ...)
        a = jnp.exp(dtc[..., None] * Af[None, None])          # (Bz,ck,Di,N)
        b = (dtc * uc)[..., None] * bc[:, :, None, :]          # (Bz,ck,Di,N)

        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, bl * ar + br

        a_cum, b_cum = jax.lax.associative_scan(comb, (a, b), axis=1)
        hs = a_cum * h[:, None] + b_cum                        # (Bz,ck,Di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, cc)
        h_new = hs[:, -1]
        return h_new, y

    h_final, ys = jax.lax.scan(
        chunk_step, h0.astype(jnp.float32),
        (uf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2, 3),
         Bf.transpose(1, 0, 2, 3), Cf.transpose(1, 0, 2, 3)), unroll=unroll)
    y = ys.transpose(1, 0, 2, 3).reshape(Bz, nc * chunk, Di)[:, :S]
    y = y + u.astype(jnp.float32)[:, :S] * D[None, None, :]
    return y, h_final


def selective_scan_ref(u, dt, A, B, C, D, h0):
    """Sequential oracle (one step at a time)."""
    Bz, S, Di = u.shape
    uf, dtf = u.astype(jnp.float32), dt.astype(jnp.float32)
    Bf, Cf, Af = B.astype(jnp.float32), C.astype(jnp.float32), A.astype(jnp.float32)

    def step(h, xs):
        ut, dtt, bt, ct = xs
        a = jnp.exp(dtt[..., None] * Af[None])                 # (Bz, Di, N)
        h = a * h + (dtt * ut)[..., None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                         (uf.transpose(1, 0, 2), dtf.transpose(1, 0, 2),
                          Bf.transpose(1, 0, 2), Cf.transpose(1, 0, 2)))
    y = ys.transpose(1, 0, 2) + uf * D[None, None, :]
    return y, h


def mamba_mixer(x: jax.Array, params: dict, *, ssm_state_dim: int,
                dt_rank: int, conv_dim: int, mode: str = "train",
                state: Optional[SSMState] = None, chunk: int = 256,
                scan_fn=selective_scan) -> Tuple[jax.Array, Optional[SSMState]]:
    """Full mamba-1 mixer. x (B, S, M) [S=1 for decode]. params:
    in_x/in_z (M, Di), conv_w (K, Di), conv_b (Di), x_proj (Di, R+2N),
    dt_proj (R, Di), dt_bias (Di), A_log (Di, N), D (Di), out_proj (Di, M).
    Returns (out (B, S, M), new_state or None)."""
    Bz, S, M = x.shape
    Di = params["A_log"].shape[0]
    N, R, K = ssm_state_dim, dt_rank, conv_dim

    x_in = x @ params["in_x"].astype(x.dtype)                  # (B, S, Di)
    z = x @ params["in_z"].astype(x.dtype)                     # (B, S, Di)

    if state is None:
        state = init_ssm_state(Bz, Di, N, K, jnp.float32)
    conv_out, new_conv = _causal_conv(
        x_in, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype),
        state.conv)
    u = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)

    dbc = u @ params["x_proj"].astype(u.dtype)                 # (B, S, R+2N)
    dt_raw = dbc[..., :R]
    Bmat = dbc[..., R:R + N]
    Cmat = dbc[..., R + N:]
    dt = jax.nn.softplus(
        (dt_raw @ params["dt_proj"].astype(dt_raw.dtype)).astype(jnp.float32)
        + params["dt_bias"].astype(jnp.float32))               # (B, S, Di)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))          # (Di, N)

    if mode == "decode":
        # single-step recurrence
        a = jnp.exp(dt[:, 0, :, None] * A[None])               # (B, Di, N)
        h = a * state.h + (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] \
            * Bmat[:, 0, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, Cmat[:, 0].astype(jnp.float32))
        y = y + u[:, 0].astype(jnp.float32) * params["D"].astype(jnp.float32)
        y = y[:, None]
        new_state = SSMState(conv=new_conv.astype(jnp.float32), h=h)
    else:
        y, h = scan_fn(u, dt, A, Bmat, Cmat,
                       params["D"].astype(jnp.float32), state.h)
        new_state = SSMState(conv=new_conv.astype(jnp.float32), h=h)

    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ params["out_proj"].astype(x.dtype)
    return out, new_state
