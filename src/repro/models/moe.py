"""Mixture-of-experts block: top-k routing with grouped, capacity-bounded
scatter dispatch.

Layout: tokens are split into `num_groups` groups (group axis shards over the
`data` mesh axis). Within each group every (token, choice) pair gets a slot
`expert * C + position_in_expert` via a cumsum over the one-hot routing
matrix; overflow beyond capacity C is dropped and the gate weights are
renormalized over surviving choices (standard capacity-factor dropping).

Expert parallelism is injected from the distribution layer via `dispatch_cs`
/ `combine_cs` sharding-constraint hooks:
  EP (num_experts % 16 == 0): expert axis constrained to `model` → GSPMD
      inserts the dispatch/return all-to-alls.
  TP (small expert counts): per-expert FFN hidden dim sharded over `model`,
      dispatch stays local to the data shard.

FLOP note: dispatch/combine are scatters/gathers (no matmul FLOPs), so the
compiled HLO FLOPs ≈ active-expert FLOPs × capacity_factor, keeping the
roofline's useful-compute ratio honest (unlike dense all-experts fallbacks).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

Identity = lambda x: x


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def pick_num_groups(num_tokens: int, data_shards: int, target_group: int = 4096) -> int:
    """Choose a group count that (a) divides the token count, (b) is a
    multiple of the data-axis size when possible, (c) keeps groups ≈4k."""
    g = max(1, num_tokens // target_group)
    if g >= data_shards:
        g = (g // data_shards) * data_shards
    elif num_tokens % data_shards == 0 and num_tokens >= 4 * data_shards:
        g = data_shards          # decode-sized batches: one group per shard
    while num_tokens % g:
        g -= 1
    return max(1, g)


def moe_block(x: jax.Array, params: dict, *, num_experts: int, top_k: int,
              capacity_factor: float, num_groups: int = 1,
              dispatch_cs: Callable = Identity, combine_cs: Callable = Identity,
              compute_dtype=jnp.bfloat16) -> jax.Array:
    """x: (T, M) token-major. params: router (M, E), w_gate/w_up (E, M, H),
    w_down (E, H, M). Returns (T, M)."""
    T, M = x.shape
    E, K = num_experts, top_k
    G = num_groups
    assert T % G == 0, (T, G)
    Tg = T // G
    C = max(4, _round_up(int(Tg * K * capacity_factor / E + 0.999), 4))
    C = min(C, Tg * K)

    # --- routing (fp32) ---
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    top_logits, top_idx = jax.lax.top_k(logits, K)          # (T, K)
    gates = jax.nn.softmax(top_logits, axis=-1)             # renorm over top-k

    xg = x.reshape(G, Tg, M)
    idxg = top_idx.reshape(G, Tg * K)
    gatesg = gates.reshape(G, Tg * K)

    def dispatch_one(xs, idx):
        # xs (Tg, M); idx (Tg*K,)
        oh = jax.nn.one_hot(idx, E, dtype=jnp.int32)         # (Tg*K, E)
        pos = jnp.cumsum(oh, axis=0) - 1                     # running count
        pos = jnp.sum(pos * oh, axis=-1)                     # (Tg*K,)
        keep = pos < C
        dest = jnp.where(keep, idx * C + pos, E * C)         # overflow slot
        x_rep = jnp.repeat(xs, K, axis=0)                    # (Tg*K, M)
        buf = jnp.zeros((E * C + 1, M), compute_dtype)
        buf = buf.at[dest].add(x_rep.astype(compute_dtype))
        return buf[:-1], dest, keep

    expert_in, dest, keep = jax.vmap(dispatch_one)(xg, idxg)   # (G, E*C, M)
    expert_in = expert_in.reshape(G, E, C, M)
    expert_in = dispatch_cs(expert_in)                          # EP all-to-all

    wg = params["w_gate"].astype(compute_dtype)                 # (E, M, H)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)                 # (E, H, M)
    h = jax.nn.silu(jnp.einsum("gecm,emh->gech", expert_in, wg))
    h = h * jnp.einsum("gecm,emh->gech", expert_in, wu)
    out = jnp.einsum("gech,ehm->gecm", h, wd)                   # (G, E, C, M)
    out = combine_cs(out)                                       # return a2a

    out_flat = out.reshape(G, E * C, M)

    def combine_one(buf, dest, keep, gate):
        # buf (E*C, M); dest/keep/gate (Tg*K,)
        vals = jnp.take(buf, jnp.minimum(dest, E * C - 1), axis=0)
        w = gate * keep.astype(gate.dtype)                      # drop overflow
        denom = jnp.maximum(w.reshape(Tg, K).sum(-1, keepdims=True), 1e-9)
        y = (vals.astype(jnp.float32).reshape(Tg, K, M)
             * (w.reshape(Tg, K) / denom)[..., None]).sum(axis=1)
        return y

    y = jax.vmap(combine_one)(out_flat, dest, keep, gatesg)     # (G, Tg, M)
    return y.reshape(T, M).astype(x.dtype)


def moe_block_reference(x, params, *, num_experts, top_k, **_):
    """Oracle: dense per-token loop over all experts (no capacity drops).
    Used by tests to bound the capacity-dropping error of moe_block."""
    T, M = x.shape
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    top_logits, top_idx = jax.lax.top_k(logits, top_k)
    gates = jax.nn.softmax(top_logits, axis=-1)
    xf = x.astype(jnp.float32)
    wg = params["w_gate"].astype(jnp.float32)
    wu = params["w_up"].astype(jnp.float32)
    wd = params["w_down"].astype(jnp.float32)
    h = jax.nn.silu(jnp.einsum("tm,emh->teh", xf, wg))
    h = h * jnp.einsum("tm,emh->teh", xf, wu)
    all_out = jnp.einsum("teh,ehm->tem", h, wd)                 # (T, E, M)
    sel = jnp.take_along_axis(all_out, top_idx[..., None], axis=1)  # (T, K, M)
    return (sel * gates[..., None]).sum(axis=1).astype(x.dtype)
