"""One front-door query session: db.stream() → NDJSON frames.

`QuerySession.run(emit)` executes on a worker thread.  It opens a
`QueryStream` tagged with the session id, then alternates

    gate.acquire(tenant)  →  produce one chunk  →  gate.release(cost)

where `cost` is the chunk's actual dispatched-call delta read from the
service's per-session counters (post-paid fairness, see fairness.py).
Each produced chunk is emitted as one `{"type": "chunk", ...}` frame;
the stream always ends with a `trailer` frame carrying the final
ExecStats (and the EXPLAIN text when requested) — or the cancellation /
error outcome.  `emit` must be thread-safe and non-blocking (the server
bridges frames into its asyncio loop).

Cancellation: the session's `CancelScope` is fired by the server on
client disconnect or DELETE /query/<id>.  The scope's callbacks (wired
here) set the session's abort event and kick the gate, so a session
blocked waiting for a fairness slot aborts immediately instead of
consuming one; a session mid-chunk unwinds at the next chunk boundary
while the service has already dropped its queued requests.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import traceback
from typing import Callable, Optional

from repro.core.cancel import CancelScope, QueryCancelled


def stats_frame_dict(stats) -> dict:
    """ExecStats → JSON-safe dict for the trailer frame."""
    if stats is None:
        return {}
    return dataclasses.asdict(stats)


class QuerySession:
    def __init__(self, db, sql: str, *, tenant: str = "",
                 session_id: str, gate, explain: bool = False,
                 deadline_ms: Optional[int] = None):
        self.db = db
        self.sql = sql
        self.tenant = tenant
        self.id = session_id
        self.gate = gate
        self.explain = explain
        self.deadline_ms = deadline_ms
        self.scope = CancelScope()
        self.status = "queued"          # queued|running|ok|cancelled|error
        self.rows_emitted = 0
        self.created_s = time.time()
        self.first_chunk_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self._abort = threading.Event()
        # order matters: set the abort flag BEFORE waking gate waiters so
        # a woken acquire() observes it and returns without a grant
        self.scope.add_callback(self._abort.set)
        self.scope.add_callback(gate.kick)

    def cancel(self, reason: str = "") -> bool:
        return self.scope.cancel(reason)

    # ------------------------------------------------------------------
    def run(self, emit: Callable[[dict], None]) -> None:
        self.status = "running"
        svc = self.db.inference_service
        try:
            stream = self.db.stream(self.sql, tenant=self.tenant,
                                    session=self.id,
                                    cancel_scope=self.scope,
                                    explain=self.explain,
                                    deadline_ms=self.deadline_ms)
        except QueryCancelled:
            self._trail(emit, "cancelled", None)
            return
        except Exception as exc:                    # bind/parse errors
            self._trail(emit, "error", None, error="{}: {}".format(
                type(exc).__name__, exc))
            return
        seq = 0
        gen = stream.chunks()
        try:
            while True:
                if not self.gate.acquire(self.tenant, abort=self._abort):
                    gen.close()                     # runs stream teardown
                    break
                before = svc.session_stats(self.id).dispatched_calls
                try:
                    chunk = next(gen, None)
                finally:
                    cost = (svc.session_stats(self.id).dispatched_calls
                            - before)
                    self.gate.release(self.tenant, cost=float(cost))
                if chunk is None:
                    break
                if self.first_chunk_s is None:
                    self.first_chunk_s = time.time()
                rows = chunk.rows()
                self.rows_emitted += len(rows)
                emit({"type": "chunk", "session": self.id, "seq": seq,
                      "rows": rows})
                seq += 1
        except Exception:
            gen.close()
            self._trail(emit, "error", stream.stats,
                        error=traceback.format_exc(limit=4))
            return
        cancelled = self.scope.cancelled or stream.cancelled \
            or (stream.stats is not None and stream.stats.cancelled)
        self._trail(emit, "cancelled" if cancelled else "ok",
                    stream.stats, plan=stream.plan)

    def _trail(self, emit, status: str, stats, *, plan: Optional[str] = None,
               error: str = "") -> None:
        self.status = status
        self.finished_s = time.time()
        frame = {"type": "trailer", "session": self.id, "status": status,
                 "rows": self.rows_emitted,
                 "stats": stats_frame_dict(stats)}
        if plan is not None:
            frame["plan"] = plan
        if error:
            frame["error"] = error
        emit(frame)
