"""Per-tenant weighted fair sharing over the shared dispatch pool.

Sessions do not talk to the inference service directly for scheduling —
before producing each result chunk they `acquire()` a slot from a gate,
and `release()` it afterwards with the chunk's actual cost (the
dispatched-call delta the service accounted for the session).  The gate
decides WHICH waiting session gets the next free slot:

`DeficitRoundRobin` keeps one FIFO of waiters per tenant and a signed
credit balance per tenant.  A slot goes to the waiting tenant with the
highest credit (ties broken by arrival order); when every waiting tenant
is out of credit, all of them are replenished by `quantum * weight`
rounds until one is positive — classic deficit round robin, except the
cost is charged POST-PAID at release time because a chunk's dispatch
cost is only known after it ran.  A heavy tenant's large charges drive
its balance negative, so a light tenant's waiters keep winning slots
even while the heavy tenant has a deep backlog: the light tenant's tail
latency is bounded by slots-in-flight, not by the heavy backlog.

Credits are capped above (idle tenants cannot hoard) and floored below
(an ancient debt cannot starve a tenant forever).  `FifoGate` grants in
pure arrival order with the same interface — the benchmark's baseline.

Both gates are thread-safe and deterministic: grant order is a pure
function of (arrival order, weights, released costs).
"""
from __future__ import annotations

import collections
import threading
from typing import Deque, Dict, Optional


class _Waiter:
    __slots__ = ("ticket", "tenant")

    def __init__(self, ticket: int, tenant: str):
        self.ticket = ticket
        self.tenant = tenant


class _GateBase:
    """Common slot accounting: a condition variable, `slots` concurrent
    grants, a global ticket counter, per-tenant grant/wait statistics."""

    def __init__(self, slots: int = 1):
        self._cv = threading.Condition()
        self._slots = max(1, int(slots))
        self._free = self._slots
        self._ticket = 0
        self.grants: Dict[str, int] = collections.defaultdict(int)

    def kick(self) -> None:
        """Wake every waiter (cancel scopes call this so a waiter blocked
        on a slot notices its abort event without polling)."""
        with self._cv:
            self._cv.notify_all()

    def release(self, tenant: str, cost: float = 1.0) -> None:
        with self._cv:
            self._free += 1
            self._charge(tenant, float(cost))
            self._cv.notify_all()

    def waiting(self) -> int:
        with self._cv:
            return self._n_waiting()

    # subclass hooks ---------------------------------------------------
    def _charge(self, tenant: str, cost: float) -> None:
        pass

    def _n_waiting(self) -> int:
        raise NotImplementedError


class FifoGate(_GateBase):
    """Grant slots in pure arrival order, tenant-blind (the baseline the
    fairness benchmark compares DRR against)."""

    def __init__(self, slots: int = 1):
        super().__init__(slots)
        self._queue: Deque[_Waiter] = collections.deque()

    def acquire(self, tenant: str, timeout: Optional[float] = None,
                abort: Optional[threading.Event] = None) -> bool:
        with self._cv:
            self._ticket += 1
            w = _Waiter(self._ticket, tenant)
            self._queue.append(w)
            while not (self._free > 0 and self._queue[0] is w):
                if abort is not None and abort.is_set():
                    self._queue.remove(w)
                    return False
                if not self._cv.wait(timeout):
                    self._queue.remove(w)
                    return False
            self._queue.popleft()
            self._free -= 1
            self.grants[tenant] += 1
            self._cv.notify_all()
            return True

    def _n_waiting(self) -> int:
        return len(self._queue)


class DeficitRoundRobin(_GateBase):
    """Weighted deficit-round-robin credit scheduler (see module doc)."""

    def __init__(self, slots: int = 1, *, quantum: float = 4.0,
                 weights: Optional[Dict[str, float]] = None,
                 default_weight: float = 1.0, debt_cap_rounds: int = 16):
        super().__init__(slots)
        self._quantum = max(1e-9, float(quantum))
        self._weights = dict(weights or {})
        self._default_weight = float(default_weight)
        self._debt_cap_rounds = max(1, int(debt_cap_rounds))
        self._queues: Dict[str, Deque[_Waiter]] = collections.OrderedDict()
        self._credit: Dict[str, float] = collections.defaultdict(float)

    def weight(self, tenant: str) -> float:
        return float(self._weights.get(tenant, self._default_weight))

    def set_weight(self, tenant: str, weight: float) -> None:
        with self._cv:
            self._weights[tenant] = float(weight)

    def credit(self, tenant: str) -> float:
        with self._cv:
            return self._credit[tenant]

    def acquire(self, tenant: str, timeout: Optional[float] = None,
                abort: Optional[threading.Event] = None) -> bool:
        with self._cv:
            self._ticket += 1
            w = _Waiter(self._ticket, tenant)
            q = self._queues.get(tenant)
            if q is None:
                q = self._queues[tenant] = collections.deque()
                # a tenant re-entering after idling cannot spend hoarded
                # credit (DRR resets deficit on empty queues); debt is
                # kept — it is the memory that makes heavy tenants yield
                cap = self._quantum * self.weight(tenant)
                self._credit[tenant] = min(self._credit[tenant], cap)
            q.append(w)
            while not self._grantable(w):
                if abort is not None and abort.is_set():
                    self._drop(w)
                    return False
                if not self._cv.wait(timeout):
                    self._drop(w)
                    return False
            self._queues[tenant].popleft()
            if not self._queues[tenant]:
                del self._queues[tenant]
            self._free -= 1
            self.grants[tenant] += 1
            self._cv.notify_all()
            return True

    # internals (caller holds the lock) --------------------------------
    def _drop(self, w: _Waiter) -> None:
        q = self._queues.get(w.tenant)
        if q is not None:
            try:
                q.remove(w)
            except ValueError:
                pass
            if not q:
                del self._queues[w.tenant]

    def _grantable(self, w: _Waiter) -> bool:
        if self._free <= 0:
            return False
        q = self._queues.get(w.tenant)
        if q is None or q[0] is not w:
            return False
        return self._pick() == w.tenant

    def _pick(self) -> Optional[str]:
        waiting = [t for t, q in self._queues.items() if q]
        if not waiting:
            return None
        if all(self._credit[t] <= 0.0 for t in waiting):
            # replenish one DRR round at a time until somebody can spend;
            # bounded because debt is floored at debt_cap_rounds quanta
            for _ in range(self._debt_cap_rounds + 1):
                for t in waiting:
                    cap = self._quantum * self.weight(t)
                    self._credit[t] = min(self._credit[t] + cap, cap)
                if any(self._credit[t] > 0.0 for t in waiting):
                    break
        # richest tenant wins; ties go to the earliest-arrived head
        # waiter so the pick is deterministic and starvation-free
        return min(waiting,
                   key=lambda t: (-self._credit[t],
                                  self._queues[t][0].ticket))

    def _charge(self, tenant: str, cost: float) -> None:
        floor = -self._debt_cap_rounds * self._quantum * self.weight(tenant)
        self._credit[tenant] = max(self._credit[tenant] - max(0.0, cost),
                                   floor)

    def _n_waiting(self) -> int:
        return sum(len(q) for q in self._queues.values())
