"""Serving tier: the HTTP front door over the iPDB engine.

`FrontDoor` (server.py) accepts concurrent query sessions over HTTP,
streams each session's result chunks as NDJSON while the chunked
physical pipeline produces them, and closes every stream with an
ExecStats trailer.  Admission control bounds concurrent + queued
sessions (429 beyond the cap); `DeficitRoundRobin` (fairness.py)
schedules chunk production across tenants with weighted fair credits
charged post-paid from the inference service's per-tenant dispatch
counters; cancellation (client disconnect, DELETE /query/<id>) flows
through a per-session `CancelScope` into the service so a dead session
stops consuming dispatch within one flush.

Everything is stdlib: asyncio for the socket/HTTP layer, threads for
query execution (the engine is thread-based), a blocking socket client
(client.py) for tests, benchmarks and the demo driver.
"""
from repro.frontdoor.client import FrontDoorClient, QueryRejected
from repro.frontdoor.fairness import DeficitRoundRobin, FifoGate
from repro.frontdoor.server import FrontDoor
from repro.frontdoor.session import QuerySession

__all__ = ["FrontDoor", "FrontDoorClient", "QueryRejected",
           "DeficitRoundRobin", "FifoGate", "QuerySession"]
