"""Asyncio HTTP front door (stdlib only).

One `FrontDoor` wraps one `IPDB`.  The socket/HTTP layer runs on a
dedicated asyncio thread; query execution runs on a worker-thread pool
(the engine is thread-based) and frames cross back into the loop via
`call_soon_threadsafe`.  HTTP/1.1 is hand-rolled — the protocol surface
is three routes:

    POST   /query        {"sql": ..., "tenant": ..., "explain": bool}
                         → 200, Transfer-Encoding: chunked,
                           application/x-ndjson: a `hello` frame (the
                           session id, sent even while queued), then one
                           `chunk` frame per produced result chunk, then
                           one `trailer` frame (ExecStats / EXPLAIN, or
                           the cancelled/error outcome);
                         → 429 + JSON when admission control rejects.
    DELETE /query/<id>   cancel a session → {"cancelled": bool}
    GET    /stats        server + gate counters as JSON.

Admission control: at most `max_sessions` sessions execute at once
(that is also the worker-pool width); up to `max_queued` more may wait
for a worker; beyond that POST /query is rejected with 429 BEFORE any
engine work happens.  Disconnect detection: while streaming, the
handler watches the connection's read side — EOF (or a failed write)
fires the session's CancelScope, which drops the session's queued
inference requests within one flush (see core/cancel.py).
"""
from __future__ import annotations

import asyncio
import collections
import json
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, Optional, Tuple

from repro.frontdoor.fairness import DeficitRoundRobin
from repro.frontdoor.session import QuerySession

_MAX_BODY = 8 << 20
_DONE = object()            # sentinel closing a session's frame queue


class FrontDoor:
    def __init__(self, db, *, host: str = "127.0.0.1", port: int = 0,
                 max_sessions: int = 4, max_queued: int = 8,
                 gate=None, tenant_weights: Optional[Dict[str, float]] = None,
                 gate_slots: Optional[int] = None,
                 snapshot_every_s: float = 0.0, retry_after_s: int = 1):
        self.db = db
        self.host = host
        self.port = port                    # 0 → ephemeral, set by start()
        self.max_sessions = max(1, int(max_sessions))
        self.max_queued = max(0, int(max_queued))
        # graceful degradation: while any backend breaker is open, new
        # queries are shed with 503 + Retry-After instead of queueing work
        # that would only feed the outage
        self.retry_after_s = max(1, int(retry_after_s))
        # crash safety: with the db configured for snapshots, persist its
        # warm state every snapshot_every_s seconds (and once at stop())
        self.snapshot_every_s = float(snapshot_every_s)
        self._snap_stop = threading.Event()
        self._snap_thread: Optional[threading.Thread] = None
        self.gate = gate if gate is not None else DeficitRoundRobin(
            gate_slots if gate_slots is not None else self.max_sessions,
            weights=tenant_weights)
        self._pool = ThreadPoolExecutor(
            max_workers=self.max_sessions,
            thread_name_prefix="frontdoor-session")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.AbstractServer] = None
        self._thread: Optional[threading.Thread] = None
        self._started = threading.Event()
        self._lock = threading.Lock()
        self._sessions: Dict[str, QuerySession] = {}
        self._seq = 0
        self._active = 0
        self._queued = 0
        self.counters = collections.Counter()   # accepted/rejected/...

    # -- lifecycle -------------------------------------------------------
    def start(self) -> Tuple[str, int]:
        """Start serving on a dedicated asyncio thread; returns the bound
        (host, port) — port 0 resolves to an ephemeral port."""
        self._thread = threading.Thread(target=self._serve_thread,
                                        name="frontdoor-loop", daemon=True)
        self._thread.start()
        if not self._started.wait(timeout=10):
            raise RuntimeError("front door failed to start")
        if self.snapshot_every_s > 0 and getattr(self.db, "snapshot_dir",
                                                 None):
            self._snap_stop.clear()
            self._snap_thread = threading.Thread(
                target=self._snapshot_loop, name="frontdoor-snapshot",
                daemon=True)
            self._snap_thread.start()
        return self.host, self.port

    def _snapshot_loop(self) -> None:
        while not self._snap_stop.wait(self.snapshot_every_s):
            self._snapshot_once()

    def _snapshot_once(self) -> None:
        try:
            if self.db.save_snapshot() is not None:
                self.counters["snapshots"] += 1
        except Exception:
            # a failed snapshot (disk full, race with shutdown) must
            # never take the serving path down
            self.counters["snapshot_failures"] += 1

    def stop(self) -> None:
        """Cancel live sessions, close the listener, join the loop thread
        and the worker pool (idempotent)."""
        if self._snap_thread is not None:
            self._snap_stop.set()
            self._snap_thread.join(timeout=10)
            self._snap_thread = None
            self._snapshot_once()           # parting snapshot: warm state
            # survives a clean shutdown as well as a crash
        with self._lock:
            sessions = list(self._sessions.values())
        for s in sessions:
            s.cancel("server shutdown")
        loop = self._loop
        ev = getattr(self, "_shutdown_ev", None)
        if loop is not None and ev is not None and loop.is_running():
            loop.call_soon_threadsafe(ev.set)
        if self._thread is not None:
            self._thread.join(timeout=10)
            self._thread = None
        self._pool.shutdown(wait=True)

    def __enter__(self) -> "FrontDoor":
        self.start()
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.stop()

    def _serve_thread(self) -> None:
        loop = asyncio.new_event_loop()
        asyncio.set_event_loop(loop)
        self._loop = loop
        self._shutdown_ev = asyncio.Event()
        try:
            loop.run_until_complete(self._serve())
        finally:
            loop.close()

    async def _serve(self) -> None:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._started.set()
        try:
            await self._shutdown_ev.wait()
        finally:
            self._server.close()
            await self._server.wait_closed()
            # reap straggling connection handlers so the loop closes clean
            tasks = [t for t in asyncio.all_tasks()
                     if t is not asyncio.current_task()]
            for t in tasks:
                t.cancel()
            await asyncio.gather(*tasks, return_exceptions=True)

    # -- http plumbing ---------------------------------------------------
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            req = await self._read_request(reader)
            if req is None:
                return
            method, path, body = req
            if method == "POST" and path == "/query":
                await self._route_query(reader, writer, body)
            elif method == "DELETE" and path.startswith("/query/"):
                self._route_cancel(writer, path[len("/query/"):])
            elif method == "GET" and path == "/stats":
                self._write_json(writer, 200, self._stats_dict())
            else:
                self._write_json(writer, 404, {"error": "not found"})
        except (ConnectionResetError, BrokenPipeError, asyncio.TimeoutError):
            pass
        finally:
            try:
                writer.close()
            except Exception:
                pass

    async def _read_request(self, reader: asyncio.StreamReader):
        line = await reader.readline()
        if not line:
            return None
        parts = line.decode("latin-1").split()
        if len(parts) < 2:
            return None
        method, path = parts[0].upper(), parts[1]
        length = 0
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            name, _, val = h.decode("latin-1").partition(":")
            if name.strip().lower() == "content-length":
                length = min(int(val.strip() or 0), _MAX_BODY)
        body = await reader.readexactly(length) if length else b""
        return method, path, body

    def _write_json(self, writer: asyncio.StreamWriter, status: int,
                    obj: dict, *, headers: Optional[Dict[str, str]] = None
                    ) -> None:
        payload = json.dumps(obj).encode()
        reason = {200: "OK", 404: "Not Found",
                  429: "Too Many Requests", 400: "Bad Request",
                  503: "Service Unavailable"}.get(status, "OK")
        extra = "".join(f"{k}: {v}\r\n" for k, v in (headers or {}).items())
        writer.write(
            "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\n"
            "Content-Length: {}\r\n{}Connection: close\r\n\r\n".format(
                status, reason, len(payload), extra).encode() + payload)

    # -- routes ----------------------------------------------------------
    async def _route_query(self, reader, writer, body: bytes) -> None:
        try:
            spec = json.loads(body.decode() or "{}")
            sql = spec["sql"]
        except (ValueError, KeyError):
            self._write_json(writer, 400, {"error": "bad request body"})
            return
        tenant = str(spec.get("tenant", ""))
        deadline_ms = spec.get("deadline_ms")
        # breaker-open shed BEFORE admission: while a backend is tripped,
        # accepted queries would mostly burn their deadline against
        # CircuitOpenError, so tell clients when to come back instead
        svc = getattr(self.db, "inference_service", None)
        if svc is not None and svc.breaker_open():
            self.counters["rejected_breaker"] += 1
            self._write_json(
                writer, 503, {"error": "backend circuit open",
                              "retry_after_s": self.retry_after_s},
                headers={"Retry-After": str(self.retry_after_s)})
            return
        with self._lock:
            if (self._active >= self.max_sessions
                    and self._queued >= self.max_queued):
                self.counters["rejected"] += 1
                self._write_json(writer, 429, {
                    "error": "too many sessions",
                    "active": self._active, "queued": self._queued})
                return
            self._seq += 1
            sid = f"fd{self._seq}"
            session = QuerySession(
                self.db, sql, tenant=tenant, session_id=sid,
                gate=self.gate, explain=bool(spec.get("explain", False)),
                deadline_ms=None if deadline_ms is None
                else int(deadline_ms))
            self._sessions[sid] = session
            self._queued += 1
            self.counters["accepted"] += 1
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: application/x-ndjson\r\n"
                     b"Transfer-Encoding: chunked\r\n"
                     b"Connection: close\r\n\r\n")
        frames: asyncio.Queue = asyncio.Queue()
        loop = asyncio.get_running_loop()

        def emit(frame):                    # worker thread → loop
            try:
                loop.call_soon_threadsafe(frames.put_nowait, frame)
            except RuntimeError:
                pass                        # loop already closed (shutdown)

        self._pool.submit(self._run_session, session, emit)
        try:
            await self._stream_frames(
                reader, writer, frames,
                hello={"type": "hello", "session": sid, "tenant": tenant})
        except (ConnectionResetError, BrokenPipeError, OSError):
            session.cancel("client disconnected")
        finally:
            # drain until the session signals done so its emits never
            # pile onto a dead queue, then forget it
            while True:
                frame = await frames.get()
                if frame is _DONE:
                    break
            with self._lock:
                self._sessions.pop(sid, None)
                if session.status == "cancelled":
                    self.counters["cancelled_sessions"] += 1
                elif session.status == "error":
                    self.counters["errored_sessions"] += 1
                else:
                    self.counters["completed"] += 1

    def _run_session(self, session: QuerySession, emit) -> None:
        with self._lock:
            self._queued -= 1
            self._active += 1
        try:
            session.run(emit)
        finally:
            with self._lock:
                self._active -= 1
            emit(_DONE)

    async def _stream_frames(self, reader, writer, frames: asyncio.Queue,
                             *, hello: dict) -> None:
        session_done = False
        self._write_chunk(writer, hello)
        await writer.drain()
        # watch the read side for EOF: an HTTP client that goes away
        # half-closes or resets, and that is our only disconnect signal
        eof_task = asyncio.ensure_future(reader.read(1))
        get_task: Optional[asyncio.Task] = None
        try:
            while True:
                if get_task is None:
                    get_task = asyncio.ensure_future(frames.get())
                done, _ = await asyncio.wait(
                    {get_task, eof_task},
                    return_when=asyncio.FIRST_COMPLETED)
                if eof_task in done and not get_task.done():
                    raise ConnectionResetError("client went away")
                frame = get_task.result()
                get_task = None
                if frame is _DONE:
                    session_done = True
                    frames.put_nowait(_DONE)    # re-arm the outer drain
                    break
                self._write_chunk(writer, frame)
                await writer.drain()
            writer.write(b"0\r\n\r\n")
            await writer.drain()
        finally:
            for t in (eof_task, get_task):
                if t is not None and not t.done():
                    t.cancel()
            if not session_done:
                # let the outer drain-loop wait for the worker's _DONE
                pass

    def _write_chunk(self, writer, frame: dict) -> None:
        data = (json.dumps(frame, default=str) + "\n").encode()
        writer.write(f"{len(data):x}\r\n".encode() + data + b"\r\n")

    def _route_cancel(self, writer, sid: str) -> None:
        with self._lock:
            session = self._sessions.get(sid)
        if session is None:
            self._write_json(writer, 404,
                             {"session": sid, "cancelled": False})
            return
        fired = session.cancel("DELETE /query")
        self.counters["delete_cancels"] += 1 if fired else 0
        self._write_json(writer, 200, {"session": sid, "cancelled": fired})

    def _stats_dict(self) -> dict:
        with self._lock:
            d = {"active": self._active, "queued": self._queued,
                 "max_sessions": self.max_sessions,
                 "max_queued": self.max_queued,
                 "gate_waiting": self.gate.waiting(),
                 "gate_grants": dict(self.gate.grants)}
            d.update(self.counters)
        return d
