"""Blocking socket client for the front door (stdlib only).

Used by tests, the saturation benchmark and the `--frontdoor` demo
driver.  `FrontDoorClient.query()` POSTs the SQL and returns a
`QueryHandle` as soon as the `hello` frame arrives (i.e. immediately,
even while the session waits in the admission queue); iterating
`handle.frames()` decodes the chunked NDJSON stream.  `handle.abort()`
closes the socket mid-stream — the server sees the EOF and fires the
session's CancelScope, which is exactly the client-disconnect path a
real browser exercises.
"""
from __future__ import annotations

import json
import socket
from typing import Dict, Iterator, List, Optional


class QueryRejected(Exception):
    """Admission control returned 429 (or another non-200 status)."""

    def __init__(self, status: int, payload: dict):
        super().__init__(f"front door returned {status}: {payload}")
        self.status = status
        self.payload = payload


class QueryHandle:
    """One streaming response.  Frames are decoded lazily; `rows()` /
    `result()` drain the stream and memoize the trailer."""

    def __init__(self, sock: socket.socket, session_id: str, tenant: str):
        self._sock = sock
        self._fp = sock.makefile("rb")
        self.session_id = session_id
        self.tenant = tenant
        self.trailer: Optional[dict] = None
        self._chunks: List[dict] = []
        self._drained = False

    def frames(self) -> Iterator[dict]:
        """Yield chunk/trailer frames as they arrive (hello was consumed
        by `query()`)."""
        if self._drained:
            yield from self._chunks
            if self.trailer is not None:
                yield self.trailer
            return
        try:
            for frame in _ndjson_frames(self._fp):
                if frame.get("type") == "trailer":
                    self.trailer = frame
                else:
                    self._chunks.append(frame)
                yield frame
        finally:
            self._drained = True
            self.close()

    def rows(self) -> List[dict]:
        out: List[dict] = []
        for frame in self.frames():
            if frame.get("type") == "chunk":
                out.extend(frame["rows"])
        return out

    def result(self) -> dict:
        """Drain the stream; returns the trailer frame."""
        for _ in self.frames():
            pass
        return self.trailer or {"type": "trailer", "status": "disconnected"}

    def stats(self) -> dict:
        return (self.result() or {}).get("stats", {})

    def abort(self) -> None:
        """Simulate the client going away: hard-close the socket.  The
        server's EOF watch fires the session's CancelScope."""
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.close()

    def close(self) -> None:
        try:
            self._fp.close()
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


class FrontDoorClient:
    def __init__(self, host: str, port: int, *, timeout: float = 30.0):
        self.host = host
        self.port = port
        self.timeout = timeout

    # ------------------------------------------------------------------
    def query(self, sql: str, *, tenant: str = "",
              explain: bool = False,
              deadline_ms: Optional[int] = None) -> QueryHandle:
        """POST /query; returns once the hello frame arrives.  Raises
        `QueryRejected` on 429 (admission), 503 (breaker open — check
        the Retry-After hint in the payload), or any other error."""
        spec: Dict[str, object] = {"sql": sql, "tenant": tenant,
                                   "explain": explain}
        if deadline_ms is not None:
            spec["deadline_ms"] = int(deadline_ms)
        body = json.dumps(spec).encode()
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.timeout)
        sock.sendall(self._request("POST", "/query", body))
        fp = sock.makefile("rb")
        status, headers = _read_status_and_headers(fp)
        if status != 200:
            payload = _read_json_body(fp, headers)
            fp.close()
            sock.close()
            raise QueryRejected(status, payload)
        hello = next(_ndjson_frames(fp))
        handle = QueryHandle(sock, hello.get("session", ""), tenant)
        handle._fp = fp
        return handle

    def cancel(self, session_id: str) -> bool:
        payload = self._simple("DELETE", f"/query/{session_id}")
        return bool(payload.get("cancelled", False))

    def server_stats(self) -> dict:
        return self._simple("GET", "/stats")

    # ------------------------------------------------------------------
    def _simple(self, method: str, path: str) -> dict:
        with socket.create_connection((self.host, self.port),
                                      timeout=self.timeout) as sock:
            sock.sendall(self._request(method, path, b""))
            fp = sock.makefile("rb")
            status, headers = _read_status_and_headers(fp)
            payload = _read_json_body(fp, headers)
            fp.close()
            if status >= 500:
                raise QueryRejected(status, payload)
            return payload

    def _request(self, method: str, path: str, body: bytes) -> bytes:
        return ("{} {} HTTP/1.1\r\nHost: {}:{}\r\n"
                "Content-Type: application/json\r\n"
                "Content-Length: {}\r\nConnection: close\r\n\r\n".format(
                    method, path, self.host, self.port,
                    len(body))).encode() + body


# -- wire helpers --------------------------------------------------------
def _read_status_and_headers(fp) -> "tuple[int, Dict[str, str]]":
    line = fp.readline()
    if not line:
        raise ConnectionError("empty response from front door")
    status = int(line.split()[1])
    headers: Dict[str, str] = {}
    while True:
        h = fp.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        name, _, val = h.decode("latin-1").partition(":")
        headers[name.strip().lower()] = val.strip()
    return status, headers


def _read_json_body(fp, headers: Dict[str, str]) -> dict:
    n = int(headers.get("content-length", 0) or 0)
    raw = fp.read(n) if n else b"{}"
    try:
        return json.loads(raw.decode() or "{}")
    except ValueError:
        return {"raw": raw.decode(errors="replace")}


def _ndjson_frames(fp) -> Iterator[dict]:
    """Decode chunked transfer encoding and re-split into NDJSON lines
    (a frame may span transfer chunks; a transfer chunk may carry many
    frames)."""
    buf = b""
    while True:
        size_line = fp.readline()
        if not size_line:
            break
        try:
            size = int(size_line.strip() or b"0", 16)
        except ValueError:
            break
        if size == 0:
            fp.readline()                   # trailing CRLF after 0-chunk
            break
        data = fp.read(size)
        fp.read(2)                          # chunk-terminating CRLF
        if data is None:
            break
        buf += data
        while b"\n" in buf:
            line, buf = buf.split(b"\n", 1)
            if line.strip():
                yield json.loads(line.decode())
    if buf.strip():
        yield json.loads(buf.decode())
