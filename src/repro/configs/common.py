"""Shared input-spec construction for every (arch × shape) cell.

`input_specs` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, and allocation-free — which is what the
dry-run lowers against. The same dict keys are produced (as real arrays)
by the training data pipeline and the serving engine.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import model as MDL
from repro.models.config import ENCODER, VLM, ModelConfig, ShapeSpec

S = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """Batch for one train_step: token LM (or frame-classification for the
    encoder, prefix-LM for the VLM)."""
    if cfg.family == ENCODER:
        return {
            "embeds": S((batch, seq, cfg.d_model), jnp.bfloat16),
            "positions": S((batch, seq), jnp.int32),
            "labels": S((batch, seq), jnp.int32),
            "mask": S((batch, seq), jnp.float32),
        }
    if cfg.family == VLM:
        p = cfg.num_prefix_tokens
        text = seq - p
        return {
            "tokens": S((batch, text), jnp.int32),
            "prefix_embeds": S((batch, p, cfg.d_model), jnp.bfloat16),
            "positions": S((batch, text), jnp.int32),
            # labels cover the full (prefix + text) logits row; loss mask
            # zeroes the prefix positions
            "labels": S((batch, seq), jnp.int32),
            "mask": S((batch, seq), jnp.float32),
        }
    return {
        "tokens": S((batch, seq), jnp.int32),
        "positions": S((batch, seq), jnp.int32),
        "labels": S((batch, seq), jnp.int32),
        "mask": S((batch, seq), jnp.float32),
    }


def prefill_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    if cfg.family == ENCODER:
        return {
            "embeds": S((batch, seq, cfg.d_model), jnp.bfloat16),
            "positions": S((batch, seq), jnp.int32),
        }
    if cfg.family == VLM:
        p = cfg.num_prefix_tokens
        return {
            "tokens": S((batch, seq - p), jnp.int32),
            "prefix_embeds": S((batch, p, cfg.d_model), jnp.bfloat16),
            "positions": S((batch, seq - p), jnp.int32),
        }
    return {
        "tokens": S((batch, seq), jnp.int32),
        "positions": S((batch, seq), jnp.int32),
    }


def decode_batch_specs(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    return {
        "tokens": S((batch, 1), jnp.int32),
        "positions": S((batch, 1), jnp.int32),
    }


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """All jit inputs for the given cell, EXCLUDING params/opt-state (those
    come from `model.param_specs` / the train-state builder)."""
    if shape.kind == "train":
        return {"batch": train_batch_specs(cfg, shape.global_batch, shape.seq_len)}
    if shape.kind == "prefill":
        return {"batch": prefill_batch_specs(cfg, shape.global_batch, shape.seq_len)}
    # decode: one new token against a seq_len-deep cache
    return {
        "batch": decode_batch_specs(cfg, shape.global_batch),
        "cache": MDL.cache_specs(cfg, shape.global_batch, shape.seq_len),
    }
