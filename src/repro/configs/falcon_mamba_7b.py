"""falcon-mamba-7b [ssm] — mamba1 arch, attention-free. [arXiv:2410.05355]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, vocab_size=65024,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
    norm_type="rmsnorm",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288, ssm_state=8)
