"""hymba-1.5b [hybrid] — parallel attn+mamba heads. [arXiv:2411.13676; hf]

Simplifications noted in DESIGN.md: meta-tokens omitted; all layers use the
same SWA window (1024) + parallel SSM heads; combination is an unweighted
mean of the two branch outputs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, vocab_size=32001,
    num_heads=25, num_kv_heads=5, head_dim=64,
    d_ff=5504, ssm_state=16, ssm_conv=4, ssm_expand=2,
    sliding_window=1024,
    norm_type="rmsnorm", mlp_act="silu",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=5, num_kv_heads=5, head_dim=8,
                          d_ff=96, ssm_state=8, sliding_window=16)
