"""qwen2-7b [dense] — GQA, QKV bias. [arXiv:2407.10671; hf]

28 query heads do not divide the 16-way model axis; `padded_heads` pads to
32 (whole GQA groups, zero-weight extra heads — function-preserving, +14%
attention FLOPs, recorded in the roofline useful-ratio).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, vocab_size=152064,
    num_heads=28, num_kv_heads=4, head_dim=128, qkv_bias=True,
    d_ff=18944,
    rope_theta=1e6, norm_type="rmsnorm", mlp_act="silu",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96)
