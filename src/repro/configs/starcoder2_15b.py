"""starcoder2-15b [dense] — GQA, RoPE. [arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, vocab_size=49152,
    num_heads=48, num_kv_heads=4, head_dim=128,
    d_ff=24576, mlp_act="gelu",
    rope_theta=1e5, norm_type="layernorm",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96)
