"""hubert-xlarge [audio] — encoder-only, wav2vec2-family arch.
[arXiv:2106.07447]

The CNN waveform frontend is a STUB per the assignment: `input_specs`
provides precomputed frame embeddings (B, S, d_model). No decode shapes
(encoder-only). Integrated into iPDB as a TABULAR executor (DESIGN.md
§Arch-applicability).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="encoder",
    num_layers=48, d_model=1280, vocab_size=504,
    num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120, mlp_act="gelu", causal=False,
    norm_type="layernorm",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=40,
                          num_heads=4, num_kv_heads=4, head_dim=16, d_ff=96)
