"""qwen3-moe-30b-a3b [moe] — 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, vocab_size=151936,
    num_heads=32, num_kv_heads=4, head_dim=64,
    d_ff=768, num_experts=128, top_k=8,
    rope_theta=1e6, norm_type="rmsnorm", mlp_act="silu",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=4, num_kv_heads=2, head_dim=16,
                          d_ff=32, num_experts=8, top_k=2)
