"""mixtral-8x22b [moe] — 8 experts top-2, SWA. [arXiv:2401.04088; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    num_layers=56, d_model=6144, vocab_size=32768,
    num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, num_experts=8, top_k=2,
    sliding_window=4096,                 # per assignment: SWA variant
    rope_theta=1e6, norm_type="rmsnorm", mlp_act="silu",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=4, num_kv_heads=2, head_dim=16,
                          d_ff=96, num_experts=4, top_k=2, sliding_window=16)
