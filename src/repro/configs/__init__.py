"""Architecture registry: ``--arch <id>`` resolves through here."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import (SHAPES, SHAPES_BY_NAME, ModelConfig,
                                 ShapeSpec, shape_applicable)

ARCH_IDS: List[str] = [
    "mixtral-8x22b",
    "qwen3-moe-30b-a3b",
    "hymba-1.5b",
    "yi-6b",
    "olmo-1b",
    "qwen2-7b",
    "starcoder2-15b",
    "falcon-mamba-7b",
    "hubert-xlarge",
    "paligemma-3b",
]

_MODULES: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()


def input_specs(arch_id: str, shape_name: str):
    from repro.configs.common import input_specs as mk
    return mk(get_config(arch_id), SHAPES_BY_NAME[shape_name])


def cells(include_skipped: bool = False):
    """All (arch_id, shape, runnable, why) cells of the assignment matrix."""
    out = []
    for a in ARCH_IDS:
        cfg = get_config(a)
        for s in SHAPES:
            ok, why = shape_applicable(cfg, s)
            if ok or include_skipped:
                out.append((a, s, ok, why))
    return out
