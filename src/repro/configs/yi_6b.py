"""yi-6b [dense] — llama-arch GQA. [arXiv:2403.04652; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    num_layers=32, d_model=4096, vocab_size=64000,
    num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=11008,
    rope_theta=5e6, norm_type="rmsnorm", mlp_act="silu",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=4, num_kv_heads=2, head_dim=16, d_ff=96)
