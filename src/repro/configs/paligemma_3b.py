"""paligemma-3b [vlm] — SigLIP + gemma backbone. [arXiv:2407.07726; hf]

The SigLIP vision tower is a STUB per the assignment: `input_specs`
provides 256 precomputed patch embeddings, prepended with prefix-LM
(bidirectional) masking. 8 query heads cannot shard over the 16-way model
axis; attention stays replicated over `model` (FFN/vocab carry TP) —
sequence-parallel attention is the recorded hillclimb alternative.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    num_layers=18, d_model=2048, vocab_size=257216,
    num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, mlp_act="silu",
    tie_embeddings=True, scale_embed=True,
    num_prefix_tokens=256,
    norm_type="rmsnorm",
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=4, num_kv_heads=1, head_dim=16,
                          d_ff=96, num_prefix_tokens=8)
