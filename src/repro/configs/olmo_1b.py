"""olmo-1b [dense] — non-parametric LN. [arXiv:2402.00838; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    num_layers=16, d_model=2048, vocab_size=50304,
    num_heads=16, num_kv_heads=16, head_dim=128,
    d_ff=8192,
    norm_type="nonparam_ln", mlp_act="silu", tie_embeddings=True,
)

def smoke_config() -> ModelConfig:
    return CONFIG.replace(num_layers=2, d_model=64, vocab_size=288,
                          num_heads=4, num_kv_heads=4, head_dim=16, d_ff=96)
