"""End-to-end query cancellation.

A `CancelScope` is the single object that ties a query session's
lifecycle together across layers: the front door cancels it when the
client disconnects (or an explicit DELETE /query/<id> arrives), the
physical pipeline checks it at every chunk boundary, and registered
callbacks let the inference service drop the session's still-queued
requests without waiting for the pipeline to unwind on its own.

Propagation contract ("within one flush"):

  * the executing thread raises `QueryCancelled` at the next
    `PhysicalOp.next_chunk` boundary; the exception unwinds the operator
    tree, running every `finally:` — pipelined operators cancel their
    pending chunks, which releases the still-queued service handles;
  * a dispatch batch that already started (flush or speculative kick)
    is never interrupted mid-executor-call — it completes, its results
    are discarded with the unwinding pipeline;
  * scope callbacks run on the CANCELLING thread, exactly once, even if
    `cancel()` races; a callback added after cancellation fires
    immediately.  The front door registers
    `InferenceService.cancel_session` here so queued requests disappear
    even while the executing thread is blocked inside a running flush.

Thread safety: `cancel()` and `add_callback()` may be called from any
thread; `cancelled`/`raise_if_cancelled` are lock-free reads of a
`threading.Event`.
"""
from __future__ import annotations

import threading
from typing import Callable, List


class QueryCancelled(Exception):
    """Raised by the executing pipeline when its CancelScope fires."""


class CancelScope:
    __slots__ = ("_event", "_lock", "_callbacks", "reason")

    def __init__(self):
        self._event = threading.Event()
        self._lock = threading.Lock()
        self._callbacks: List[Callable[[], None]] = []
        self.reason = ""

    @property
    def cancelled(self) -> bool:
        return self._event.is_set()

    def raise_if_cancelled(self) -> None:
        if self._event.is_set():
            raise QueryCancelled(self.reason or "query cancelled")

    def add_callback(self, fn: Callable[[], None]) -> None:
        """Register `fn` to run when the scope is cancelled.  If the
        scope is already cancelled the callback runs immediately (on the
        registering thread) — registration order never races the
        cancel."""
        run_now = False
        with self._lock:
            if self._event.is_set():
                run_now = True
            else:
                self._callbacks.append(fn)
        if run_now:
            fn()

    def cancel(self, reason: str = "") -> bool:
        """Fire the scope (idempotent).  Returns True on the first call.
        Callbacks run outside the lock, in registration order, on the
        cancelling thread; a callback that raises does not block the
        others."""
        with self._lock:
            if self._event.is_set():
                return False
            self.reason = reason or self.reason
            self._event.set()
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            try:
                fn()
            except Exception:
                pass
        return True
