"""Calibrated model cascades: proxy-scored semantic operators with
accuracy contracts (Cortex AISQL / Larch, PAPERS.md).

A `CascadePredictor` composes two registered backends behind the ordinary
`Predictor` interface, so every existing layer (PredictOperator marshaling,
InferenceService lanes, the optimizer's cost estimates) works unchanged:

  proxy stage      ONE batched `complete_many` scores every marshaled
                   prompt; per-row confidence comes from
                   `CallResult.confidences` (never re-parsed from text —
                   text-only backends degrade to a logit-free 1.0).
  threshold pair   a calibrated (tau_pos, tau_neg) acceptance pair per
                   proxy verdict: rows at-or-above their class threshold
                   resolve immediately, rows below EITHER threshold form
                   the escalation band.
  expensive stage  only the escalation band re-enters the expensive
                   backend — escalated rows from ALL prompts in the
                   dispatch batch are re-marshaled into `batch_size`-row
                   prompts, so the expensive model sees full batches, not
                   per-row dribble.

Calibration is a SNAPSHOT taken once per query (`load()`): thresholds come
from the per-(model, instruction) held-out reservoir in the
StatisticsStore (`calibrate_cascade`), targeting the user-declared
contract (`cascade_target_precision` via db option, model OPTIONS, or
`PREDICT ... WITH (...)`).  Evidence recorded while the query runs —
escalated-row agreement, score sketches, periodic audits of
would-be-accepted rows — only affects FUTURE queries, which keeps routing
a pure function of the batch contents (the PR 4 determinism contract).

Stats accounting is stage-split to fix the double-count: the cascade
records proxy-stage calls under (proxy_model, instruction) and
expensive-stage calls under the BASE (model, instruction) key — so the
cost model's direct-route estimate stays observed — while the
InferenceService records the merged two-stage call under the
`staged_key(..., "cascade")` tag (`Predictor.stats_stage`).
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.executors import CallResult, Predictor
from repro.core.faults import TransientError
from repro.core.predict import parse_structured, render_rows
from repro.core.stats import (CascadeCalibration, StatisticsStore,
                              stats_key)

__all__ = ["CascadePredictor", "confidences_of", "row_hash",
           "cascade_section"]


def confidences_of(res: CallResult, num_rows: int) -> List[float]:
    """Per-row confidence vector for one call result: `None` (a text-only
    backend with no score channel) reads as all-1.0, short vectors pad
    with 0.0 (rows the backend could not answer)."""
    if res.confidences is None:
        return [1.0] * num_rows
    confs = [float(c) for c in res.confidences[:num_rows]]
    confs.extend([0.0] * (num_rows - len(confs)))
    return confs


def row_hash(instruction: str, row: dict) -> int:
    """Deterministic 64-bit identity of one (instruction, input row) pair:
    keys the agreement reservoir and the audit schedule, so both are
    independent of batch composition and dispatch order."""
    payload = json.dumps([instruction, sorted(row.items())], default=str)
    return int.from_bytes(hashlib.sha256(payload.encode()).digest()[:8],
                          "little")


class CascadePredictor(Predictor):
    """Two-stage cascade behind the `Predictor` interface.

    The dispatch concurrency it declares is the MIN of its stages (a
    dispatch runs both), so the InferenceService gives the cascade its own
    lane and overlapping chunks pipeline through proxy and expensive
    stages exactly like any concurrency-capable backend."""
    name = "cascade"
    #: stage tag: requests routed through this executor batch/dedup/record
    #: separately from the direct route (see `service.staged_key`)
    stats_stage = "cascade"

    def __init__(self, proxy: Predictor, expensive: Predictor, *,
                 store: Optional[StatisticsStore] = None,
                 key: Tuple[str, str] = ("", ""), proxy_model: str = "",
                 target_precision: float = 0.9, min_records: int = 8,
                 audit_every: int = 16, breaker=None):
        self.proxy = proxy
        self.expensive = expensive
        self.store = store
        # optional CircuitBreaker guarding the expensive backend (the
        # database wires the service's per-model breaker in).  When it is
        # open — or the expensive stage throws a TransientError — the
        # escalation band falls back to the proxy's answers (graceful
        # degradation) instead of failing the whole batch; passthrough
        # prompts keep the raw proxy text and rely on the operator's
        # parse-retry path once the backend recovers.
        self.breaker = breaker
        self.key = key
        self.proxy_model = proxy_model or getattr(proxy, "name", "proxy")
        self.target_precision = float(target_precision)
        self.min_records = max(1, int(min_records))
        # 1-in-N deterministic audit of would-be-accepted rows (by row
        # hash): keeps the held-out reservoir honest after calibration
        # converges.  0 disables auditing.
        self.audit_every = max(0, int(audit_every))
        self.max_concurrency = min(proxy.max_concurrency,
                                   expensive.max_concurrency)
        self.calibration = CascadeCalibration(target=self.target_precision)

    # -- lifecycle ---------------------------------------------------------
    def configure(self, options: Dict[str, object]) -> None:
        super().configure(options)
        self.proxy.configure(options)
        self.expensive.configure(options)

    def load(self) -> None:
        self.proxy.load()
        self.expensive.load()
        # calibration snapshot for the whole query: prefer the thresholds
        # the optimizer stamped on the plan (EXPLAIN shows exactly what
        # runs), else calibrate from the store now
        opts = self.options or {}
        if "cascade_tau_pos" in opts:
            self.calibration = CascadeCalibration(
                target=float(opts.get("cascade_target_precision",
                                      self.target_precision)),
                tau_pos=float(opts["cascade_tau_pos"]),
                tau_neg=float(opts.get("cascade_tau_neg", 2.0)),
                escalation_rate=float(opts.get("cascade_esc_rate", 1.0)),
                status=str(opts.get("cascade_status", "ok")))
        elif self.store is not None:
            self.calibration = self.store.calibrate_cascade(
                self.key, self.target_precision,
                min_records=self.min_records)

    # -- dispatch ----------------------------------------------------------
    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        return self.complete_many(
            [prompt], schema, [num_rows], shared_prefix=shared_prefix,
            rows_list=[rows], instruction=instruction)[0]

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        rows_list = rows_list if rows_list is not None \
            else [None] * len(prompts)
        cal = self.calibration
        # ---- proxy stage: score every prompt in one batched call --------
        pres_list = self.proxy.complete_many(
            prompts, schema, num_rows_list, shared_prefix=shared_prefix,
            rows_list=rows_list, instruction=instruction)
        if self.store is not None:
            pkey = (self.proxy_model, self.key[1])
            for pr in pres_list:
                self.store.record_call(pkey, pr.in_tokens, pr.out_tokens,
                                       pr.sim_latency_s)

        boolean = bool(schema) and schema[0][1].upper() == "BOOLEAN"
        first_out = schema[0][0] if schema else None
        parsed_list: List[Optional[List[dict]]] = []
        confs_list: List[Optional[List[float]]] = []
        passthrough: List[int] = []    # prompt indices sent whole
        esc: List[Tuple] = []          # (pi, ri, row, preamble, conf,
        #                                 pos, hash, audited)
        scored_confs: List[float] = []
        scored_pos: List[bool] = []
        for pi, (prompt, nr, rows, pres) in enumerate(
                zip(prompts, num_rows_list, rows_list, pres_list)):
            parsed = parse_structured(pres.text, schema, nr) \
                if nr > 0 else None
            rendered = render_rows(rows) if rows else ""
            # rows we cannot re-marshal (table generation, aggregates,
            # unparseable proxy output) pass through to the expensive
            # stage unchanged — the cascade never degrades correctness
            if not rows or parsed is None or not prompt.endswith(rendered):
                parsed_list.append(None)
                confs_list.append(None)
                passthrough.append(pi)
                continue
            preamble = prompt[:len(prompt) - len(rendered)]
            confs = confidences_of(pres, nr)
            parsed_list.append(parsed)
            confs_list.append(confs)
            for ri in range(nr):
                pos = bool(parsed[ri].get(first_out)) if boolean else True
                conf = confs[ri]
                scored_confs.append(conf)
                scored_pos.append(pos)
                rh = row_hash(instruction, rows[ri])
                tau = cal.tau_pos if pos else cal.tau_neg
                audited = (conf >= tau and self.audit_every > 0
                           and cal.status == "ok"
                           and rh % self.audit_every == 0)
                if conf < tau or audited:
                    esc.append((pi, ri, rows[ri], preamble, conf, pos, rh,
                                audited))
        if self.store is not None and scored_confs:
            self.store.record_cascade_scores(self.key, scored_confs,
                                             scored_pos)

        # ---- expensive stage: re-marshal the escalation band ------------
        bs = int(self.options.get("batch_size", 16)) \
            if self.options.get("use_batching", True) else 1
        bs = max(1, bs)
        esc_groups = [esc[s:s + bs] for s in range(0, len(esc), bs)]
        exp_prompts: List[str] = []
        exp_nrs: List[int] = []
        exp_rows: List[Optional[List[dict]]] = []
        for g in esc_groups:
            g_rows = [e[2] for e in g]
            # every prompt in a dispatch batch shares its preamble (same
            # queue key ⇒ same instruction/schema), so the first
            # contributor's preamble re-marshals the group faithfully
            exp_prompts.append(g[0][3] + render_rows(g_rows))
            exp_nrs.append(len(g_rows))
            exp_rows.append(g_rows)
        for pi in passthrough:
            exp_prompts.append(prompts[pi])
            exp_nrs.append(num_rows_list[pi])
            exp_rows.append(rows_list[pi])
        eres_list: List[CallResult] = []
        degraded = False
        if exp_prompts:
            if self.breaker is not None and not self.breaker.allow():
                degraded = True        # breaker open: proxy-only fallback
            else:
                try:
                    eres_list = self.expensive.complete_many(
                        exp_prompts, schema, exp_nrs,
                        shared_prefix=shared_prefix, rows_list=exp_rows,
                        instruction=instruction)
                except TransientError:
                    if self.breaker is not None:
                        self.breaker.record_failure()
                    degraded = True
                else:
                    if self.breaker is not None:
                        self.breaker.record_success()
            if self.store is not None:
                for er in eres_list:
                    # base key: the cost model's direct-route estimate
                    # keeps observing the expensive backend
                    self.store.record_call(self.key, er.in_tokens,
                                           er.out_tokens, er.sim_latency_s)

        # ---- merge: splice expensive verdicts over proxy answers --------
        for gi, g in enumerate(esc_groups):
            if gi >= len(eres_list):
                break                  # degraded: keep the proxy answers
            eparsed = parse_structured(eres_list[gi].text, schema, len(g))
            for k, (pi, ri, row, _pre, conf, pos, rh, audited) in \
                    enumerate(g):
                if eparsed is None:
                    continue           # keep the proxy answer
                exp_obj = eparsed[k]
                if self.store is not None:
                    agree = exp_obj == parsed_list[pi][ri]
                    self.store.record_cascade_agreement(
                        self.key, rh, conf, pos, agree, audited=audited)
                parsed_list[pi][ri] = exp_obj
                confs_list[pi][ri] = confidences_of(
                    eres_list[gi], len(g))[k]

        merged: List[CallResult] = []
        pt_results = dict(zip(passthrough, eres_list[len(esc_groups):]))
        for pi, (nr, pres) in enumerate(zip(num_rows_list, pres_list)):
            if parsed_list[pi] is None:
                er = pt_results.get(pi)
                if er is None:
                    # degraded passthrough: only the proxy's raw text is
                    # available — the operator's parse/retry path decides
                    # what survives
                    merged.append(CallResult(
                        pres.text, pres.in_tokens, pres.out_tokens,
                        pres.sim_latency_s, pres.wall_s,
                        confidences=pres.confidences))
                    continue
                merged.append(CallResult(
                    er.text, pres.in_tokens + er.in_tokens,
                    pres.out_tokens + er.out_tokens,
                    pres.sim_latency_s + er.sim_latency_s,
                    pres.wall_s + er.wall_s, confidences=er.confidences))
                continue
            objs = parsed_list[pi]
            text = json.dumps(objs[0] if nr == 1 else objs)
            merged.append(CallResult(
                text, pres.in_tokens, pres.out_tokens, pres.sim_latency_s,
                pres.wall_s, confidences=confs_list[pi]))
        # escalation-group cost rides on the group's first contributor
        for gi, g in enumerate(esc_groups):
            if gi >= len(eres_list):
                break                  # degraded: no expensive cost to add
            er, m = eres_list[gi], merged[g[0][0]]
            m.in_tokens += er.in_tokens
            m.out_tokens += er.out_tokens
            m.sim_latency_s += er.sim_latency_s
            m.wall_s += er.wall_s

        routed = sum(nr for pl, nr in zip(parsed_list, num_rows_list)
                     if pl is not None)
        if merged:
            # whole-batch cascade accounting on the first result, like the
            # JAX engine counters (operators only ever sum these)
            merged[0].proxy_calls += len(prompts)
            merged[0].escalated_calls += len(eres_list)
            merged[0].cascade_rows += routed
            merged[0].escalated_rows += len(esc)
            if degraded:
                merged[0].degraded_calls += len(exp_prompts)
        if self.store is not None:
            self.store.record_cascade_batch(
                self.key, routed, len(esc), len(prompts), len(eres_list),
                degraded=int(degraded))
        return merged


# ---------------------------------------------------------------------------
def cascade_section(plan, store: Optional[StatisticsStore],
                    options: Optional[Dict[str, object]] = None) -> str:
    """EXPLAIN `-- cascade --` body: per cascaded operator the chosen
    route, the threshold pair, the contract with its empirical estimate,
    and the estimated vs observed escalation rate."""
    from repro.relational.plan import Predict, SemanticJoin, walk_plan

    def fmt(v, spec="{:.3f}"):
        return spec.format(v) if v is not None else "n/a"

    lines: List[str] = []
    for node in walk_plan(plan):
        if not isinstance(node, (Predict, SemanticJoin)):
            continue
        info = node.info
        opts = {**(options or {}), **(info.options or {})}
        proxy = opts.get("cascade_proxy")
        if not proxy:
            continue
        key = stats_key(info)
        route = str(opts.get("cascade_route", "cascade"))
        status = str(opts.get("cascade_status", "cold"))
        target = opts.get("cascade_target_precision")
        tau_pos = opts.get("cascade_tau_pos")
        tau_neg = opts.get("cascade_tau_neg")
        esc_rate = opts.get("cascade_esc_rate")
        rec = store.cascade_get(key) if store is not None else None
        emp = held = None
        observed = "none"
        if rec is not None:
            held = rec.n_records
            if rec.audited > 0:
                emp = rec.audit_agree / rec.audited
            if rec.routed_rows:
                observed = (f"rows={rec.escalated_rows}/{rec.routed_rows} "
                            f"proxy_calls={rec.proxy_calls} "
                            f"expensive_calls={rec.expensive_calls}")
            if rec.degraded_batches > 0:
                # proxy-only fallback fired (expensive backend down /
                # breaker open): the contract is not currently enforced
                status = "degraded"
                observed += f" degraded_batches={rec.degraded_batches}"
        kind = type(node).__name__
        instr = key[1] if len(key[1]) <= 48 else key[1][:45] + "..."
        lines.append(
            f"{kind}[{info.model_name}] '{instr}'\n"
            f"  route={route} proxy={proxy} status={status}\n"
            f"  thresholds: accept_pos>={fmt(tau_pos)} "
            f"accept_neg>={fmt(tau_neg)}\n"
            f"  contract: target_precision={fmt(target)} "
            f"empirical={fmt(emp)} "
            f"held_out={held if held is not None else 0}\n"
            f"  escalation: est_rate={fmt(esc_rate)} observed={observed}")
    return "\n".join(lines) if lines else "(no cascaded operators)"
