"""Crash-safe versioned snapshots of the database's warm state.

A snapshot is a single file::

    MAGIC (8B) | version (4B LE) | sha256(payload) (32B) | pickle payload

written with the classic tmp-file + ``fsync`` + atomic ``os.rename``
dance, so a crash mid-write can never corrupt the previous snapshot.
Files are named ``warm-<seq:08d>.snap``; loaders walk them newest-first
and fall back to the next-older file (ultimately a clean cold start)
whenever the magic, version, or checksum fails validation.

The payload itself is a plain dict assembled by ``IPDB.save_snapshot``
(prompt-cache entries, statistics-store export, radix prefix-cache KV
pages); this module knows nothing about its schema beyond "picklable".
"""
from __future__ import annotations

import hashlib
import os
import pickle
import tempfile
from typing import Any, List, Optional, Tuple

MAGIC = b"IPDBSNAP"
VERSION = 1
_HEADER = len(MAGIC) + 4 + 32


class SnapshotError(RuntimeError):
    """Snapshot failed validation (magic / version / checksum)."""


def _encode(payload: Any) -> bytes:
    body = pickle.dumps(payload, protocol=pickle.HIGHEST_PROTOCOL)
    digest = hashlib.sha256(body).digest()
    return MAGIC + VERSION.to_bytes(4, "little") + digest + body


def _decode(blob: bytes) -> Any:
    if len(blob) < _HEADER or blob[: len(MAGIC)] != MAGIC:
        raise SnapshotError("bad magic")
    ver = int.from_bytes(blob[len(MAGIC): len(MAGIC) + 4], "little")
    if ver != VERSION:
        raise SnapshotError(f"unsupported snapshot version {ver}")
    digest = blob[len(MAGIC) + 4: _HEADER]
    body = blob[_HEADER:]
    if hashlib.sha256(body).digest() != digest:
        raise SnapshotError("checksum mismatch")
    return pickle.loads(body)


def snapshot_files(snapshot_dir: str) -> List[str]:
    """Snapshot paths in the directory, newest (highest seq) first."""
    try:
        names = os.listdir(snapshot_dir)
    except OSError:
        return []
    snaps = sorted(n for n in names
                   if n.startswith("warm-") and n.endswith(".snap"))
    return [os.path.join(snapshot_dir, n) for n in reversed(snaps)]


def write_snapshot(snapshot_dir: str, payload: Any, *,
                   keep: int = 3) -> str:
    """Atomically write a new versioned snapshot; prune to ``keep`` files."""
    os.makedirs(snapshot_dir, exist_ok=True)
    existing = snapshot_files(snapshot_dir)
    seq = 0
    if existing:
        try:
            seq = int(os.path.basename(existing[0])[5:-5]) + 1
        except ValueError:
            seq = len(existing)
    path = os.path.join(snapshot_dir, f"warm-{seq:08d}.snap")
    blob = _encode(payload)
    fd, tmp = tempfile.mkstemp(dir=snapshot_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    for old in snapshot_files(snapshot_dir)[max(1, keep):]:
        try:
            os.unlink(old)
        except OSError:
            pass
    return path


def load_latest(snapshot_dir: str
                ) -> Tuple[Optional[Any], Optional[str], List[str]]:
    """Load the newest valid snapshot.

    Returns ``(payload, path, skipped)`` where ``skipped`` lists files
    that failed validation (corrupt / truncated / foreign) and were
    passed over.  ``(None, None, skipped)`` means cold start.
    """
    skipped: List[str] = []
    for path in snapshot_files(snapshot_dir):
        try:
            with open(path, "rb") as f:
                return _decode(f.read()), path, skipped
        except (SnapshotError, OSError, pickle.UnpicklingError,
                EOFError, AttributeError, ImportError):
            skipped.append(path)
    return None, None, skipped
