"""Semantic query optimizer (paper §6.4–§6.6 + §7.10 guidance).

Rules (each individually switchable for the ablation benchmarks):

  pullup      (§6.4) — predict pull-up, implemented as its dual: cheap
                (zero-cost) predicates are pushed BELOW Predict nodes, so
                expensive inference runs after traditional filtering. The
                engine's guardrail "inference is not zero-cost" is
                structural: no rule ever moves a Predict downward.
  join_order  (§6.5) — semantic select vs join ordering: a semantic select
                above a join is pushed to its input side only when the
                side's distinct input count is LOWER than the deduplicated
                distinct count seen above the join (cost-aware, using real
                distinct-value statistics collected from the cheap
                relational prefix of the plan).
  merge       (§6.6) — adjacent Predict nodes with the same model over the
                same child are fused into one multi-output call.
  order       (§7.10) — stacks of semantic selects are ordered by input
                size, then selectivity estimate, then quality hint.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.stats import CostModel, PilotSampler, StatisticsStore
from repro.relational.catalog import Catalog
from repro.relational.expr import (BinOp, Col, Expr, PredictExpr,
                                   PromptTemplate, find_predicts)
from repro.relational.plan import (Filter, GroupBy, Join, Limit, Node,
                                   OrderBy, Predict, PredictInfo, Project,
                                   Scan, SemanticJoin)

DEFAULT_FLAGS = {
    "enable_pullup": True,
    "enable_join_order": True,
    "enable_merge": True,
    "enable_select_order": True,
    "enable_cascade": True,
    "enable_rewrites": True,    # learned rewrite-pattern engine
    "enable_reopt": True,       # mid-query re-ranking of select stacks
}


def _is_cheap(e: Expr) -> bool:
    return not find_predicts(e)


def _split_and(e: Expr) -> List[Expr]:
    if isinstance(e, BinOp) and e.op == "AND":
        return _split_and(e.left) + _split_and(e.right)
    return [e]


def _and_all(es: List[Expr]) -> Optional[Expr]:
    out = None
    for e in es:
        out = e if out is None else BinOp("AND", out, e)
    return out


def _cols_of(e: Expr) -> set:
    return set(e.columns()) | {
        p.resolved_col for p in find_predicts(e) if p.resolved_col}


class Optimizer:
    def __init__(self, catalog: Catalog, flags: Dict[str, bool] = None, *,
                 stats: Optional[StatisticsStore] = None,
                 cost_model: Optional[CostModel] = None,
                 pilot: Optional[PilotSampler] = None):
        self.cat = catalog
        self.session = dict(flags or {})
        self.flags = dict(DEFAULT_FLAGS)
        if flags:
            self.flags.update({k: v for k, v in flags.items()
                               if k in DEFAULT_FLAGS})
        self.stats = stats if stats is not None else StatisticsStore()
        self.cost = cost_model if cost_model is not None else \
            CostModel(self.stats, self.session)
        self.pilot = pilot
        self._filter_used = set()
        self.rewrite_events = []    # RewriteEvents from the last optimize()

    # ------------------------------------------------------------------
    def optimize(self, plan: Node) -> Node:
        self.rewrite_events = []
        plan = self._split_filters(plan)
        # outputs referenced by Filters = selective predicts.  Computed for
        # EVERY rule pass (merge uses it to avoid fusing two highly
        # selective selects, §6.6 caveat) — not only when merge is enabled.
        self._filter_used = set()
        for x in _walk(plan):
            if isinstance(x, Filter):
                self._filter_used |= _cols_of(x.predicate)
        if self.flags["enable_merge"]:
            plan = self._merge_predicts(plan)
        if self.flags["enable_pullup"]:
            for _ in range(8):                    # to fixpoint (bounded)
                new = self._pushdown_cheap(plan)
                if new is plan:
                    break
                plan = new
        if self.flags["enable_rewrites"]:
            # learned rewrite patterns (subsumption, duplicate-predict
            # consolidation, select-vs-join placement) run after pushdown
            # has formed the interleaved select units; every application
            # passes the engine's validation gate and is recorded for
            # EXPLAIN's `-- rewrites --` section
            from repro.core.rewrite import RewriteEngine
            eng = RewriteEngine(self.cat, self.cost, ctx=self)
            plan = eng.rewrite(plan)
            self.rewrite_events = eng.events
        if self.flags["enable_join_order"]:
            plan = self._semantic_select_vs_join(plan)
        if self.flags["enable_select_order"]:
            plan = self._order_semantic_selects(plan)
        plan = self._annotate_selectivities(plan)
        plan = self._annotate_cardinalities(plan)
        if self.flags["enable_cascade"]:
            plan = self._choose_cascade_routes(plan)
        return plan

    # -- helpers --------------------------------------------------------
    def _map_children(self, n: Node, fn) -> Node:
        if isinstance(n, Filter):
            return Filter(fn(n.child), n.predicate, n.selectivity)
        if isinstance(n, Project):
            return Project(fn(n.child), n.exprs)
        if isinstance(n, Join):
            return Join(fn(n.left), fn(n.right), n.kind, n.left_keys,
                        n.right_keys, n.extra)
        if isinstance(n, GroupBy):
            g = GroupBy(fn(n.child), n.keys, n.aggs)
            g.llm_agg_infos = getattr(n, "llm_agg_infos", {})
            return g
        if isinstance(n, OrderBy):
            return OrderBy(fn(n.child), n.keys)
        if isinstance(n, Limit):
            return Limit(fn(n.child), n.n)
        if isinstance(n, Predict):
            return Predict(fn(n.child) if n.child else None, n.info)
        if isinstance(n, SemanticJoin):
            return SemanticJoin(fn(n.left), fn(n.right), n.info)
        return n

    # -- rule: split conjunctive filters ---------------------------------
    def _split_filters(self, n: Node) -> Node:
        n = self._map_children(n, self._split_filters)
        if isinstance(n, Filter):
            parts = _split_and(n.predicate)
            if len(parts) > 1:
                child = n.child
                # cheap parts innermost so they can keep sinking
                for p in sorted(parts, key=lambda e: 0 if _is_cheap(e) else 1,
                                reverse=True):
                    child = Filter(child, p)
                return child
        return n

    # -- rule: cheap predicate pushdown (= predict pull-up, §6.4) ---------
    def _pushdown_cheap(self, n: Node) -> Node:
        n2 = self._map_children(n, self._pushdown_cheap)
        n = n2
        if not isinstance(n, Filter):
            return n
        cols = _cols_of(n.predicate)
        c = n.child
        # ANY filter (cheap or semantic) sinks below a Predict it doesn't
        # depend on — this both realizes predict pull-up (§6.4) and forms
        # the interleaved Filter(Predict(...)) units that §7.10 reorders
        if isinstance(c, Predict) and c.child is not None and \
                not (cols & set(c.info.out_cols)):
            return self._pushdown_cheap(
                Predict(Filter(c.child, n.predicate), c.info))
        if not _is_cheap(n.predicate):
            return n
        # below the matching side of a Join
        if isinstance(c, Join):
            lsch = set(c.left.schema(self.cat))
            rsch = set(c.right.schema(self.cat))
            if cols <= lsch:
                return Join(Filter(c.left, n.predicate), c.right, c.kind,
                            c.left_keys, c.right_keys, c.extra)
            if cols <= rsch:
                return Join(c.left, Filter(c.right, n.predicate), c.kind,
                            c.left_keys, c.right_keys, c.extra)
        # below a SemanticJoin side
        if isinstance(c, SemanticJoin):
            lsch = set(c.left.schema(self.cat))
            rsch = set(c.right.schema(self.cat))
            if cols <= lsch:
                return SemanticJoin(Filter(c.left, n.predicate), c.right,
                                    c.info)
            if cols <= rsch:
                return SemanticJoin(c.left, Filter(c.right, n.predicate),
                                    c.info)
        # through another (cheap or semantic) Filter: reorder cheap-first
        if isinstance(c, Filter) and not _is_cheap(c.predicate):
            return Filter(Filter(c.child, n.predicate), c.predicate,
                          c.selectivity)
        return n

    # -- rule: predicate merging (§6.6) -----------------------------------
    def _merge_predicts(self, n: Node) -> Node:
        n = self._map_children(n, self._merge_predicts)
        if isinstance(n, Predict) and isinstance(n.child, Predict):
            a, b = n.info, n.child.info
            a_sel = bool(set(a.out_cols) & getattr(self, "_filter_used", set()))
            b_sel = bool(set(b.out_cols) & getattr(self, "_filter_used", set()))
            if (a.model_name == b.model_name and not a.agg and not b.agg
                    and a.prompt is not None and b.prompt is not None
                    and n.child.child is not None
                    and not (a_sel and b_sel)):
                merged_prompt = PromptTemplate(
                    raw=b.prompt.raw + " ; " + a.prompt.raw,
                    instruction=b.prompt.instruction + " AND ALSO: "
                    + a.prompt.instruction,
                    inputs=list(dict.fromkeys(b.prompt.inputs + a.prompt.inputs)),
                    outputs=b.prompt.outputs + a.prompt.outputs)
                info = PredictInfo(
                    model_name=a.model_name, prompt=merged_prompt,
                    inputs=list(dict.fromkeys(b.inputs + a.inputs)),
                    outputs=b.outputs + a.outputs,
                    options={**b.options, **a.options},
                    out_cols_override=b.out_cols + a.out_cols)
                return Predict(n.child.child, info)
        return n

    # -- rule: semantic select vs join ordering (§6.5) ---------------------
    def _cheap_table(self, plan: Node):
        """Execute a subplan containing no inference (cheap relational
        prefix) and return its Table; None when the subplan is not cheap
        or fails."""
        for x in _walk(plan):
            if isinstance(x, (Predict, SemanticJoin)):
                return None
            if isinstance(x, Filter) and not _is_cheap(x.predicate):
                return None
        try:
            from repro.relational.executor import PlanExecutor
            ex = PlanExecutor(self.cat, predict_factory=None)
            return ex.run(plan)
        except Exception:
            return None

    def _distinct_count(self, plan: Node, cols: List[str]) -> Optional[float]:
        """Real distinct-value statistics when the subplan is cheap-only."""
        t = self._cheap_table(plan)
        if t is None:
            return None
        if len(t) == 0:
            return 0.0
        arrs = [t.column(c) for c in cols if c in t.cols]
        if not arrs:
            return None
        vals = set()
        for i in range(len(t)):
            vals.add(tuple(a[i] for a in arrs))
        return float(len(vals))

    def _semantic_select_vs_join(self, n: Node) -> Node:
        n = self._map_children(n, self._semantic_select_vs_join)
        # pattern: Filter_sem(Predict(Join(A, B))) with inputs from one side
        if (isinstance(n, Filter) and not _is_cheap(n.predicate)
                and isinstance(n.child, Predict)
                and n.child.child is not None
                and isinstance(n.child.child, Join)):
            pred_node = n.child
            join = pred_node.child
            inputs = set(pred_node.info.inputs)
            lsch = set(join.left.schema(self.cat))
            rsch = set(join.right.schema(self.cat))
            side = "left" if inputs <= lsch else \
                "right" if inputs <= rsch else None
            if side:
                side_plan = join.left if side == "left" else join.right
                d_side = self._distinct_count(side_plan, list(inputs))
                d_join = self._distinct_count(join, list(inputs))
                if d_side is not None and d_join is not None \
                        and self._placement_cost(pred_node, d_side) \
                        < self._placement_cost(pred_node, d_join):
                    # push: cheaper expected cost below the join (dedup makes
                    # the above-join placement cost d_join distinct calls)
                    sub = Filter(Predict(side_plan, pred_node.info),
                                 n.predicate, n.selectivity)
                    if side == "left":
                        return Join(sub, join.right, join.kind,
                                    join.left_keys, join.right_keys,
                                    join.extra)
                    return Join(join.left, sub, join.kind, join.left_keys,
                                join.right_keys, join.extra)
        return n

    # -- pass: cardinality annotation for lowering -------------------------
    def _annotate_cardinalities(self, n: Node) -> Node:
        """Stamp Predict/SemanticJoin nodes with estimated per-chunk input
        cardinalities (est_in_rows / est_cross_rows in info.options) so the
        physical lowering pass can size chunks/windows. Estimation only —
        never changes plan shape or results."""
        n = self._map_children(n, self._annotate_cardinalities)
        if isinstance(n, Predict):
            try:
                est = n.child.est_rows(self.cat) if n.child else 32.0
            except Exception:
                return n                    # unknown stats → no annotation
            info = dataclasses.replace(
                n.info, options={**n.info.options,
                                 "est_in_rows": float(est)})
            return Predict(n.child, info)
        if isinstance(n, SemanticJoin):
            try:
                est = n.left.est_rows(self.cat) * n.right.est_rows(self.cat)
            except Exception:
                return n
            info = dataclasses.replace(
                n.info, options={**n.info.options,
                                 "est_in_rows": float(est),
                                 "est_cross_rows": float(est)})
            return SemanticJoin(n.left, n.right, info)
        return n

    # -- pass: cascade-vs-direct route choice (PR 7) ------------------------
    def _choose_cascade_routes(self, n: Node) -> Node:
        """For every semantic operator with a configured cascade proxy,
        choose cascade vs direct through the cost model and stamp the
        calibration snapshot (thresholds, escalation rate, contract
        status) on the node's options — the CascadePredictor executes
        exactly the stamped snapshot and EXPLAIN's `-- cascade --` section
        renders it.  Runs after cardinality annotation so est_in_rows is
        available.  Decision rule:

          unachievable/violated  contract cannot be (or was not) met →
                                 route direct, cascade disabled;
          ok                     cascade iff proxy-stage + escalated-band
                                 call cost (expected calls x per-call
                                 latency — the metered resource) beats
                                 the direct route's under the observed
                                 escalation rate.  Total cost, not
                                 makespan: with a large worker pool
                                 direct's few calls all run in parallel,
                                 which would hide the cascade's actual
                                 win (fewer expensive calls);
          cold                   cascade (escalate-everything bootstrap:
                                 full direct cost + proxy scoring, buys
                                 the held-out evidence future queries
                                 calibrate from).
        """
        n = self._map_children(n, self._choose_cascade_routes)
        if not isinstance(n, (Predict, SemanticJoin)):
            return n
        info = n.info
        opts = {**self.session, **(info.options or {})}
        proxy = opts.get("cascade_proxy")
        if not proxy or info.agg or \
                (isinstance(n, Predict) and n.child is None):
            return n
        from repro.core.stats import stats_key
        target = float(opts.get("cascade_target_precision", 0.9))
        cal = self.stats.calibrate_cascade(
            stats_key(info), target,
            min_records=int(opts.get("cascade_min_records", 8)))
        route = "cascade"
        if cal.status in ("unachievable", "violated"):
            route = "direct"
        elif cal.status == "ok":
            rows = float(opts.get("est_cross_rows",
                                  opts.get("est_in_rows", 32.0)) or 32.0)
            direct = self.cost.estimate(info, rows)
            esc = self.cost.estimate(info, rows * cal.escalation_rate)
            pinfo = dataclasses.replace(info, model_name=str(proxy))
            prox = self.cost.estimate(pinfo, rows)

            def call_cost(est):
                return est.expected_calls * est.per_call_s

            if call_cost(prox) + call_cost(esc) >= call_cost(direct):
                route = "direct"
        info = dataclasses.replace(info, options={
            **info.options, "cascade_route": route,
            "cascade_proxy": str(proxy),
            "cascade_target_precision": target,
            "cascade_tau_pos": cal.tau_pos,
            "cascade_tau_neg": cal.tau_neg,
            "cascade_esc_rate": cal.escalation_rate,
            "cascade_status": cal.status})
        if isinstance(n, SemanticJoin):
            return SemanticJoin(n.left, n.right, info)
        return Predict(n.child, info)

    def _placement_cost(self, pred_node: Predict,
                        rows: float) -> Tuple[float, float, float]:
        """Cost of running a semantic select over `rows` distinct inputs,
        via the unified cost model: (expected calls, modeled makespan,
        rows).  Rows break ties so marshaling (which quantizes calls by
        batch_size) never hides a strictly smaller input — fewer distinct
        rows always means fewer prompt tokens."""
        est = self.cost.estimate(pred_node.info, rows,
                                 self._fallback_tokens(pred_node))
        return (est.expected_calls, est.makespan_s, rows)

    # -- rule: semantic select ordering (§7.10) ----------------------------
    def _fallback_tokens(self, p: Predict) -> float:
        """Static per-call input-size estimate (instruction chars + sampled
        column widths) — the cost model's fallback when the statistics
        store has no observations for the predicate."""
        instr = len(p.info.prompt.raw) if p.info.prompt else 64
        sizes = []
        for c in p.info.inputs:
            base = _find_base_column(p.child, c, self.cat)
            if base is not None:
                vals = base[:256]
                sizes.append(float(np.mean([len(str(v)) for v in vals]))
                             if len(vals) else 8.0)
            else:
                sizes.append(16.0)
        return instr + sum(sizes)

    def _order_semantic_selects(self, n: Node) -> Node:
        n = self._map_children(n, self._order_semantic_selects)
        # collect a maximal stack Filter_sem(Predict(Filter_sem(Predict(X))))
        units = []
        cur = n
        while (isinstance(cur, Filter) and not _is_cheap(cur.predicate)
               and isinstance(cur.child, Predict)
               and cur.child.child is not None):
            units.append((cur, cur.child))
            cur = cur.child.child
        if len(units) < 2:
            return n
        # only reorder when each unit's predicate depends solely on its own
        # predict outputs and base columns below the whole stack
        base_schema = set(cur.schema(self.cat))
        for f, p in units:
            need = _cols_of(f.predicate) - set(p.info.out_cols)
            if not need <= base_schema:
                return n
            if not set(p.info.inputs) <= base_schema:
                return n
        if self.pilot is not None:
            # calibrate units with no history on a reservoir sample of the
            # (cheap) stack input before committing to an order; the stack
            # input is only materialized when some unit actually needs it
            need = [(f, p) for f, p in units if self.pilot.wants(p.info)]
            base_t = self._cheap_table(cur) if need else None
            if base_t is not None and len(base_t):
                for f, p in need:
                    self.pilot.calibrate(f.predicate, p.info, base_t)
        ranked = sorted(units, key=lambda fp: self.cost.rank(
            fp[1].info, self._fallback_tokens(fp[1])))
        # the legality conditions above (predicates self-contained, inputs
        # from the base schema) are exactly what mid-query re-ranking needs,
        # so stamp each unit with its modeled per-call cost and the planner's
        # selectivity estimate; lowering turns a stamped stack into one
        # SemanticSelectStackOp that re-ranks on observed chunk pass rates
        reopt = bool(self.flags.get("enable_reopt", True))
        plan = cur
        for f, p in ranked:                 # cheapest wraps first → innermost
            info = p.info
            if reopt:
                sel, _ = self.cost.selectivity(info)
                _, _, lat = self.cost.per_call(
                    info, self._fallback_tokens(p))
                info = dataclasses.replace(info, options={
                    **info.options, "reopt": True,
                    "reopt_cost": float(lat), "reopt_sel": float(sel)})
            plan = Filter(Predict(plan, info), f.predicate, f.selectivity)
        return plan

    # -- pass: stats-informed selectivity annotation -----------------------
    def _annotate_selectivities(self, n: Node) -> Node:
        """Stamp semantic select units with the cost model's selectivity:
        the Filter's planner estimate feeds est_rows propagation (and so
        the est_in_rows/est_cross_rows cardinality annotations below),
        and the Predict carries est_selectivity/sel_source for EXPLAIN.
        Estimation only — never changes plan shape or results."""
        n = self._map_children(n, self._annotate_selectivities)
        if (isinstance(n, Filter) and not _is_cheap(n.predicate)
                and isinstance(n.child, Predict)
                and _cols_of(n.predicate) & set(n.child.info.out_cols)):
            p = n.child
            sel, src = self.cost.selectivity(p.info)
            info = dataclasses.replace(
                p.info, options={**p.info.options, "est_selectivity": sel,
                                 "sel_source": src})
            return Filter(Predict(p.child, info), n.predicate, sel)
        return n


def _walk(n: Node):
    yield n
    for c in n.children:
        yield from _walk(c)


def _find_base_column(plan: Node, col: str, cat) -> Optional[np.ndarray]:
    """Column values from the unique base table carrying `col`.  Under a
    join of tables that share a column name, the owner is ambiguous from
    the logical plan alone — return None (callers fall back to a default
    width) instead of sizing prompts from whichever Scan happens to walk
    first.  A self-join (same table twice) is not ambiguous."""
    owners: Dict[str, np.ndarray] = {}
    for x in _walk(plan):
        if isinstance(x, Scan) and x.table not in owners:
            t = cat.table(x.table)
            if col in t.cols:
                owners[x.table] = t.column(col)
    if len(owners) == 1:
        return next(iter(owners.values()))
    return None
