"""Model executors behind the physical predict operator (paper §5.4,
Table 4: Config / Load / PredictChunk / ScanChunk interface).

Three executors, mirroring the paper's ONNX / llama.cpp / LLM-API trio:
  * JaxExecutor     — the in-process JAX serving engine (grammar-forced
                      generation; real compute, real wall time)
  * OracleExecutor  — deterministic semantic oracle with a calibrated
                      latency model + error injection. Used by the
                      accuracy-bearing benchmarks: it isolates the SYSTEMS
                      effects (calls/tokens/ordering) that the paper
                      evaluates, while exercising the same prompt/parse/
                      fallback code paths as a real model.
  * TabularExecutor — encoder/classifier models bound to a table
                      (CREATE TABULAR MODEL; hubert-style frame classifier)

All executors consume the SAME rewritten prompt text and return raw text;
structured parsing/validation lives in the predict operator.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os.path
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving import tokenizer as TOK


@dataclasses.dataclass
class CallResult:
    text: str
    in_tokens: int
    out_tokens: int
    sim_latency_s: float          # modeled provider latency (oracle) or wall
    wall_s: float
    # engine-side accounting (JaxExecutor fills these; remote-API-style
    # backends have no visible prefill/decode split and leave them 0)
    prefill_tokens: int = 0       # tokens actually prefit through the model
    decode_tokens: int = 0        # lock-step decode tokens generated
    prefix_hits: int = 0          # shared-prefix KV memo/radix hits
    radix_hit_tokens: int = 0     # prompt tokens served from the radix tree
    # per-answer confidence scores, one per returned row, aligned with the
    # parsed objects.  Backends with calibrated scores (tabular classifiers,
    # oracles carrying a "__confidence__" field) populate them; text-only
    # backends leave None, which readers treat as all-1.0 (logit-free).
    confidences: Optional[List[float]] = None
    # cascade accounting (CascadePredictor fills these; whole-batch counts
    # ride on the first result of a dispatch, like the engine counters)
    proxy_calls: int = 0          # proxy-stage complete_many prompt count
    escalated_calls: int = 0      # expensive-stage calls actually made
    cascade_rows: int = 0         # rows routed through the cascade
    escalated_rows: int = 0       # rows escalated to the expensive stage
    degraded_calls: int = 0       # expensive-stage calls skipped because the
                                  # backend was down (proxy-only degradation)


class Predictor:
    """Extensible executor interface (paper Table 4)."""
    name = "base"
    options: Dict[str, object] = {}
    #: hard cap on concurrent `complete_many` dispatches this backend can
    #: take (1 = not thread-safe, dispatch stays synchronous).  Stateless
    #: remote-API-style backends raise it; the in-process JAX engine
    #: cannot (one engine, one compute stream).
    max_concurrency = 1

    def configure(self, options: Dict[str, object]) -> None:
        self.options = dict(options)

    def load(self) -> None:
        pass

    def dispatch_workers(self) -> int:
        """Effective dispatch-worker-pool size for this backend: the
        session/model `dispatch_workers` option clamped to the backend's
        declared `max_concurrency`.  1 (the default) keeps the old
        synchronous flush-on-the-submitting-thread behavior."""
        want = int(self.options.get("dispatch_workers", 1) or 1)
        return max(1, min(self.max_concurrency, want))

    def complete(self, prompt: str, schema: Sequence[Tuple[str, str]],
                 num_rows: int, *, shared_prefix: str = "",
                 rows: Optional[List[dict]] = None,
                 instruction: str = "") -> CallResult:
        raise NotImplementedError

    def complete_many(self, prompts: Sequence[str],
                      schema: Sequence[Tuple[str, str]],
                      num_rows_list: Sequence[int], *,
                      shared_prefix: str = "",
                      rows_list: Optional[List[Optional[List[dict]]]] = None,
                      instruction: str = "") -> List[CallResult]:
        """Answer a batch of marshaled prompts in one dispatch (the
        InferenceService entry point).  Base implementation loops
        `complete`; backends override with real batched execution."""
        rows_list = rows_list if rows_list is not None \
            else [None] * len(prompts)
        return [self.complete(p, schema, nr, shared_prefix=shared_prefix,
                              rows=r, instruction=instruction)
                for p, nr, r in zip(prompts, num_rows_list, rows_list)]

    def scan_chunk(self, prompt: str, schema, max_rows: int) -> CallResult:
        return self.complete(prompt, schema, max_rows, instruction=prompt)


# ---------------------------------------------------------------------------
class JaxExecutor(Predictor):
    """Local model executor: grammar-constrained generation on the
    in-process engine (llama.cpp-analog, §5.2 'grammar forced generation').

    Single prompts go through `engine.generate` (keeping shared-prefix KV
    reuse); multi-prompt dispatches from the InferenceService run through
    ONE slot-based `ContinuousBatcher.run`, so relational queries get real
    continuous batching instead of sequential generate calls."""
    name = "jax"
    # one engine, one compute stream: dispatch batches must not overlap —
    # intra-dispatch parallelism comes from the continuous batcher instead
    max_concurrency = 1

    def __init__(self, engine):
        self.engine = engine
        self._batcher = None

    def _grammar(self, schema, num_rows):
        from repro.serving.grammar import Field, JsonGrammar
        nr = num_rows if num_rows > 0 else \
            int(self.options.get("gen_rows", 4))     # table generation
        return JsonGrammar([Field(n, t) for n, t in schema], num_rows=nr,
                           max_str=int(self.options.get("max_str", 24)))

    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        g = self._grammar(schema, num_rows)
        ns = max(1, int(self.options.get("n_samples", 1)))
        t0 = time.time()
        res = self.engine.generate(
            [prompt] * ns, grammar=g, shared_prefix=shared_prefix,
            max_new_tokens=int(self.options.get("max_tokens", 4096)),
            temperature=float(self.options.get("temperature", 0.7)))
        wall = time.time() - t0
        s = res.stats
        if ns > 1:
            # self-consistency: majority text across the sampled streams
            # (the paged engine shares their prompt KV zero-copy)
            from repro.serving.scheduler import _vote
            text = _vote(res.texts)
        else:
            text = res.texts[0]
        return CallResult(text, s.input_tokens, s.output_tokens,
                          wall, wall, prefill_tokens=s.prefill_tokens,
                          decode_tokens=s.output_tokens,
                          prefix_hits=s.prefix_hits,
                          radix_hit_tokens=s.radix_hit_tokens)

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        paged = getattr(self.engine, "kv_layout", "dense") == "paged"
        # single prompt, or a shared instruction prefix under the DENSE
        # layout (whose per-slot prefill cannot KV-share): generate path.
        # The paged batcher CAN share a prefix — its pages are referenced,
        # not copied, by every slot's block table — so it keeps batching.
        if len(prompts) == 1 or (shared_prefix and not paged):
            return super().complete_many(
                prompts, schema, num_rows_list, shared_prefix=shared_prefix,
                rows_list=rows_list, instruction=instruction)
        from repro.serving.scheduler import ContinuousBatcher, Request
        if self._batcher is None:
            self._batcher = ContinuousBatcher(
                self.engine, num_slots=int(self.options.get("num_slots", 8)))
        # `prompts` are suffixes EXCLUDING any caller-provided shared_prefix
        # (the InferenceService contract) — only a prefix WE carve out of
        # the prompts below may be stripped from them
        prefix = shared_prefix
        run_prompts = list(prompts)
        radix = getattr(self.engine, "prefix_cache_mode", "exact") == "radix"
        if paged and not prefix and not radix:
            # Exact mode only: marshaled prompts all start with the same
            # instruction text, so carve the common prefix out and prefill
            # it once into shared pages (only worth it at >= one full
            # page).  The radix engine skips this — partial overlap is
            # discovered token-by-token at match time, and a text-level
            # carve would only constrain it.
            #
            # The carve must land on a TOKEN boundary: tokens are UTF-8
            # bytes, so compare byte encodings (two prompts can share a
            # lead byte inside a multi-byte character that a character
            # comparison would miss), trim in byte units, then back off
            # until the cut decodes — prefix/suffix stay real strings.
            # Keep every suffix non-empty — a prompt that EQUALS the
            # common prefix must still contribute its last token to the
            # prefill.
            enc = [p.encode("utf-8") for p in run_prompts]
            cb = os.path.commonprefix(enc)
            cb = cb[:max(0, min(len(e) for e in enc) - 1)]
            common = ""
            while cb:
                try:
                    common = cb.decode("utf-8")
                    break
                except UnicodeDecodeError:
                    cb = cb[:-1]
            if TOK.count_tokens(common) + 1 >= self.engine.page_size:
                prefix = common
                run_prompts = [p[len(prefix):] for p in prompts]
        max_new = min(int(self.options.get("max_tokens", 4096)),
                      self.engine.max_len)
        ns = max(1, int(self.options.get("n_samples", 1)))
        reqs = [Request(prompt=p, grammar=self._grammar(schema, nr),
                        max_new_tokens=max_new, n_samples=ns)
                for p, nr in zip(run_prompts, num_rows_list)]
        bs = self._batcher.stats
        before = (bs.prefill_tokens, bs.output_tokens, bs.prefix_hits,
                  bs.radix_hit_tokens)
        t0 = time.time()
        done = self._batcher.run(
            reqs, temperature=float(self.options.get("temperature", 0.7)),
            shared_prefix=prefix if paged else "")
        per = (time.time() - t0) / max(1, len(done))
        out = []
        for orig, r in zip(prompts, done):
            text = r.text or ""
            out.append(CallResult(text,
                                  TOK.count_tokens(shared_prefix + orig),
                                  TOK.count_tokens(text), per, per))
        # whole-run engine accounting rides on the first result (per-row
        # attribution of lock-step prefill/decode work is arbitrary; the
        # operator only ever sums these)
        out[0].prefill_tokens = bs.prefill_tokens - before[0]
        out[0].decode_tokens = bs.output_tokens - before[1]
        out[0].prefix_hits = bs.prefix_hits - before[2]
        out[0].radix_hit_tokens = bs.radix_hit_tokens - before[3]
        return out


# ---------------------------------------------------------------------------
def default_latency_model(in_tokens: int, out_tokens: int) -> float:
    """Calibrated against paper Fig. 4 (o4-mini): ~2 s base + per-token."""
    return 2.0 + 2.5e-4 * in_tokens + 6e-3 * out_tokens


class OracleExecutor(Predictor):
    """Simulated remote LLM: answers come from a task oracle
    (benchmark-registered `oracle_fn(instruction, rows) -> List[dict]`),
    serialized as the same JSON a real model would emit, with seeded error
    injection so F1 < 1 and failure-handling paths run.

    Answers, rng draws and modeled latency are keyed by the prompt text
    alone, so the executor is batch-invariant AND thread-safe: it may take
    concurrent dispatches (`max_concurrency`).  `sleep_per_call_s` adds a
    real wall-clock sleep per answered call — an API round-trip stand-in
    that makes dispatch overlap measurable (`bench_multibackend`) without
    touching the modeled latency."""
    name = "oracle"
    max_concurrency = 32

    def __init__(self, oracle_fn: Callable[[str, List[dict]], List[dict]],
                 *, error_rate: float = 0.0, malform_rate: float = 0.0,
                 refusal_rate: float = 0.0,
                 latency_model: Callable[[int, int], float] = default_latency_model,
                 seed: int = 0, sleep_per_call_s: float = 0.0):
        self.oracle_fn = oracle_fn
        self.error_rate = error_rate
        self.malform_rate = malform_rate
        self.refusal_rate = refusal_rate
        self.latency_model = latency_model
        self.seed = seed
        self.sleep_per_call_s = float(sleep_per_call_s)

    def _rng(self, prompt: str) -> np.random.Generator:
        h = hashlib.sha256(f"{self.seed}:{prompt}".encode()).digest()
        return np.random.default_rng(int.from_bytes(h[:8], "little"))

    def _corrupt(self, val, typ, rng):
        t = typ.upper()
        if t == "BOOLEAN":
            return not bool(val)
        if t == "INTEGER":
            return int(val) + int(rng.integers(1, 5)) if val is not None else 0
        if t == "DOUBLE":
            return (float(val) if val is not None else 0.0) * float(rng.uniform(0.5, 2.0))
        return f"{val}x" if val else "unknown"

    def _answer(self, prompt, schema, num_rows, shared_prefix, rows,
                instruction) -> CallResult:
        """One request; the rng is keyed by the full prompt so answers are
        deterministic regardless of how requests were batched."""
        wall = self.sleep_per_call_s
        if wall:
            time.sleep(wall)
        rng = self._rng(prompt)
        full = shared_prefix + prompt
        in_toks = TOK.count_tokens(full)
        if rng.uniform() < self.refusal_rate:
            text = "I cannot help with that request."
            out = TOK.count_tokens(text)
            return CallResult(text, in_toks, out,
                              self.latency_model(in_toks, out), wall)
        answers = self.oracle_fn(instruction, rows or [{}] * num_rows)
        objs, confs = [], []
        # num_rows == 0 → table generation: the oracle decides cardinality
        take = answers if num_rows == 0 else answers[:num_rows]
        for r_ans in take:
            o = {}
            for name, typ in schema:
                v = r_ans.get(name)
                if rng.uniform() < self.error_rate:
                    v = self._corrupt(v, typ, rng)
                o[name] = v
            objs.append(o)
            # oracles may carry a per-row score under the reserved
            # "__confidence__" key; schema filtering keeps it out of `o`
            confs.append(float(r_ans.get("__confidence__", 1.0)))
        while len(objs) < num_rows:
            objs.append({name: None for name, _ in schema})
            confs.append(0.0)
        text = json.dumps(objs[0] if num_rows == 1 else objs)
        if rng.uniform() < self.malform_rate:
            text = "Sure! Here is the result:\n" + text[:max(3, len(text) - 5)]
        out_toks = TOK.count_tokens(text)
        return CallResult(text, in_toks, out_toks,
                          self.latency_model(in_toks, out_toks), wall,
                          confidences=confs if num_rows > 0 else None)

    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        return self._answer(prompt, schema, num_rows, shared_prefix, rows,
                            instruction)

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        # baseline emulations override complete(); route through it so
        # their behavior (refusal abort, unstructured output) is preserved
        if type(self).complete is not OracleExecutor.complete:
            return super().complete_many(
                prompts, schema, num_rows_list, shared_prefix=shared_prefix,
                rows_list=rows_list, instruction=instruction)
        rows_list = rows_list if rows_list is not None \
            else [None] * len(prompts)
        return [self._answer(p, schema, nr, shared_prefix, r, instruction)
                for p, nr, r in zip(prompts, num_rows_list, rows_list)]


# ---------------------------------------------------------------------------
class TabularExecutor(Predictor):
    """CREATE TABULAR MODEL executor: features in, typed outputs out, no
    prompting (paper Listing 4). predict_fn maps a feature-row list to
    output dicts — backed by e.g. the hubert encoder config or any
    ONNX-analog callable."""
    name = "tabular"

    def __init__(self, predict_fn: Callable[[List[dict]], List[dict]],
                 latency_per_row: float = 1e-4, max_concurrency: int = 1):
        self.predict_fn = predict_fn
        self.latency_per_row = latency_per_row
        # concurrency is a property of the wrapped callable: pure feature
        # mappers can take parallel dispatches, stateful ones cannot
        self.max_concurrency = max(1, int(max_concurrency))

    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        t0 = time.time()
        outs = self.predict_fn(rows or [])
        objs = [{n: o.get(n) for n, _ in schema} for o in outs]
        confs = [float(o.get("__confidence__", 1.0)) for o in outs]
        text = json.dumps(objs[0] if num_rows == 1 else objs)
        wall = time.time() - t0
        return CallResult(text, 0, 0,
                          max(wall, self.latency_per_row * max(1, num_rows)),
                          wall, confidences=confs or None)

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        """Vectorized dispatch: all requests' feature rows go through ONE
        predict_fn call, then the outputs are split back per request."""
        rows_list = rows_list if rows_list is not None \
            else [[] for _ in prompts]
        t0 = time.time()
        flat = [r for rws in rows_list for r in (rws or [])]
        outs = self.predict_fn(flat)
        per = (time.time() - t0) / max(1, len(prompts))
        results, off = [], 0
        for rws, nr in zip(rows_list, num_rows_list):
            k = len(rws or [])
            part = outs[off:off + k]
            objs = [{n: o.get(n) for n, _ in schema} for o in part]
            confs = [float(o.get("__confidence__", 1.0)) for o in part]
            off += k
            text = json.dumps(objs[0] if nr == 1 else objs)
            results.append(CallResult(
                text, 0, 0,
                max(per, self.latency_per_row * max(1, nr)), per,
                confidences=confs or None))
        return results
