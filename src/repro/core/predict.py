"""The physical PREDICT operator (paper §5) with the intra-operator
optimizations of §6:

  configuration stage  — option precedence: model OPTIONS > session SET >
                         defaults (§5.3)
  loading stage        — executor resolution via the registry
  execution stage      — chunked, vectorized, and SPLIT INTO TWO PHASES:
      submit(table)  -> PendingChunk   cache probe, prompt rewriting
                                       (§5.1), multi-row marshaling (§6.2)
                                       and request construction; requests
                                       are queued on the shared
                                       InferenceService, nothing blocks
      resolve(pending) -> Table        typed extraction (Table 3), retry
                                       with stricter formatting, per-tuple
                                       fallback, output assembly

  The split lets physical operators keep several windows submitted ahead
  (`inflight_windows`) so the service can dispatch them as one batch —
  cross-window and cross-operator overlap (§6.3) instead of the old
  synchronous one-chunk-at-a-time loop.  `__call__` remains the
  degenerate submit-then-resolve case with behavior identical to the old
  synchronous operator.

Scheduling/makespan accounting lives in `repro.core.service`; each chunk
opens one DispatchGroup whose makespan (greedy worker pool + rate limit)
covers every call made for the chunk, including retries and fallbacks —
the same numbers the operator used to compute locally.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.cancel import QueryCancelled
from repro.core.executors import CallResult, Predictor
from repro.core.faults import DeadlineExceeded, TransientError
from repro.core.service import (DispatchGroup, InferenceHandle,
                                InferenceRequest, InferenceService, makespan)
from repro.core.stats import stats_key
from repro.relational.plan import PredictInfo
from repro.relational.table import Table, _coerce

__all__ = ["DEFAULTS", "PredictStats", "PredictOperator", "PromptCache",
           "PendingBatch", "PendingChunk", "makespan", "extract_json",
           "parse_structured", "cast_value", "render_rows"]

DEFAULTS = {
    "batch_size": 16,        # marshaled rows per call
    "n_threads": 16,         # parallel workers
    "use_batching": True,    # multi-row marshaling
    "use_dedup": True,       # prompt deduplication
    "rate_limit_rpm": 0,     # 0 = unlimited
    "retry_limit": 2,
    "chunk_size": 2048,      # vectorized chunk (DuckDB-analog)
    "inflight_windows": 1,   # chunks kept submitted ahead of resolution
    "dispatch_workers": 1,   # per-backend dispatch pool (1 = synchronous)
    "num_slots": 8,          # continuous-batching decode slots (jax)
    "n_samples": 1,          # self-consistency streams per row (jax)
    # front-door multi-tenancy tags: every request the operator submits
    # carries them, so dispatch batches are session-pure and the service
    # can account (and cancel) per session.  "" = plain Python API.
    "tenant": "",
    "session": "",
    # resilience (core/faults.py).  deadline_ms: end-to-end query budget
    # via the §5.3 precedence (expression WITH > model OPTIONS > session
    # SET); 0 = none.  query_start_ts anchors it (the database stamps
    # time.monotonic() at query start so every operator of one query
    # derives the same absolute deadline).  retry_backoff_s: base of the
    # exponential backoff between transient-failure retries (deterministic
    # seeded jitter); 0 = retry immediately, the old behavior.
    "deadline_ms": 0,
    "query_start_ts": 0.0,
    "retry_backoff_s": 0.0,
}


@dataclasses.dataclass
class PredictStats:
    calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    sim_latency_s: float = 0.0     # modeled makespan (workers + rate limit)
    serial_latency_s: float = 0.0  # sum of per-call latencies
    wall_s: float = 0.0
    rows_in: int = 0
    cache_hits: int = 0
    retries: int = 0
    batch_fallbacks: int = 0
    null_outputs: int = 0
    pc_hits: int = 0               # cross-query prompt-cache hits
    pc_misses: int = 0             # lookups that had to dispatch a call
    inflight_hits: int = 0         # submits that joined a pending handle
    # engine-side serving accounting (jax backend; zero for API backends)
    prefill_tokens: int = 0        # tokens prefit through the model
    decode_tokens: int = 0         # lock-step decode tokens generated
    prefix_hits: int = 0           # shared-prefix KV memo/radix hits
    radix_hit_tokens: int = 0      # prompt tokens served from the radix tree
    # cascade accounting (CascadePredictor backend; zero for direct routes)
    proxy_calls: int = 0           # proxy-stage prompts scored
    escalated_calls: int = 0       # expensive-stage calls actually made
    cascade_rows: int = 0          # rows routed through a cascade
    escalated_rows: int = 0        # rows escalated to the expensive stage
    # resilience accounting (core/faults.py)
    transient_retries: int = 0     # resubmits after transient backend errors
    deadline_drops: int = 0        # calls/retries abandoned past the deadline
    degraded_calls: int = 0        # cascade batches degraded to proxy-only

    def add(self, o: "PredictStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))


_JSON_RE = re.compile(r"[\[{].*[\]}]", re.DOTALL)


def extract_json(text: str) -> Optional[object]:
    """Locate and parse the outermost JSON value in model text, tolerating
    surrounding prose. Returns the decoded value or None."""
    m = _JSON_RE.search(text)
    if not m:
        return None
    try:
        return json.loads(m.group(0))
    except json.JSONDecodeError:
        return None


def parse_structured(text: str, schema: Sequence[Tuple[str, str]],
                     num_rows: int) -> Optional[List[dict]]:
    """Extract typed rows from model text; returns None if unusable."""
    v = extract_json(text)
    if v is None:
        return None
    objs = v if isinstance(v, list) else [v]
    if len(objs) < num_rows:
        return None
    out = []
    for o in objs[:num_rows]:
        if not isinstance(o, dict):
            return None
        row = {}
        for name, typ in schema:
            row[name] = cast_value(o.get(name), typ)
        out.append(row)
    return out


def cast_value(v, typ: str):
    t = typ.upper()
    try:
        if v is None:
            return None
        if t == "INTEGER":
            return int(v)
        if t == "DOUBLE":
            return float(v)
        if t == "BOOLEAN":
            if isinstance(v, str):
                return v.strip().lower() in ("true", "yes", "1")
            return bool(v)
        return str(v)
    except (TypeError, ValueError):
        return None


def render_rows(rows: List[dict]) -> str:
    """Render marshaled input rows into the prompt tail.  Module-level so
    the CascadePredictor can split a marshaled prompt back into its
    (preamble, rendered rows) parts when re-batching escalations."""
    if len(rows) == 1:
        return "Input: " + json.dumps(rows[0], default=str)
    return (f"Inputs ({len(rows)} rows — return a JSON array with "
            f"exactly {len(rows)} objects, in order): "
            + json.dumps(rows, default=str))


_MISS = object()

_STRICT = ("\nSTRICT: output MUST be raw JSON parsable by json.loads, "
           "nothing else.\n")


class PromptCache:
    """Cross-query prompt cache, owned by the database and shared by every
    PredictOperator it creates. Keyed by (model, instruction, input tuple);
    survives across operators, chunks, and queries, so a repeated query (or
    an overlapping one against the same model/instruction) re-uses prior
    inference results instead of re-dispatching calls.

    Eviction is LRU: `get` re-inserts the hit entry at the back of the
    (insertion-ordered) dict, `put` evicts from the front, so hot entries
    survive churn that would have rotated them out under FIFO.

    All access is lock-protected: with per-backend dispatch pools, flushes
    (and the operators that feed the cache from their results) run off the
    submitting thread, and the touch-on-get delete/re-insert pair is not
    atomic under the GIL — two unsynchronized readers of one hot key would
    race the delete."""

    def __init__(self, max_entries: int = 200_000):
        self._d: Dict[Tuple, List[Optional[object]]] = {}
        self._lock = threading.Lock()
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple):
        with self._lock:
            v = self._d.get(key, _MISS)
            if v is _MISS:
                self.misses += 1
            else:
                self.hits += 1
                del self._d[key]           # touch-on-get: move to MRU end
                self._d[key] = v
            return v

    def put(self, key: Tuple, value: List[Optional[object]]) -> None:
        with self._lock:
            if key not in self._d and len(self._d) >= self.max_entries:
                self._d.pop(next(iter(self._d)))      # LRU eviction
            self._d[key] = value

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()

    # -- warm-state snapshots (core/snapshot.py) -----------------------
    def export_state(self) -> List[Tuple[Tuple, List[Optional[object]]]]:
        """(key, value) pairs in LRU order (oldest first), so a restore
        that overflows max_entries keeps the hottest tail."""
        with self._lock:
            return list(self._d.items())

    def restore_state(self, items) -> int:
        """Re-insert snapshot entries (hit/miss counters untouched)."""
        for k, v in items:
            self.put(k, v)
        return len(items)


@dataclasses.dataclass
class PendingBatch:
    """One marshaled call in flight: the chunk-row indices it answers, the
    rendered input rows, and the service handle.  `owned` is False when
    the request joined another submitter's identical in-flight handle
    (the joiner must not account the call's tokens)."""
    idxs: List[int]
    rows: List[dict]
    handle: InferenceHandle
    owned: bool


@dataclasses.dataclass
class PendingChunk:
    """Result of `PredictOperator.submit`: everything `resolve` needs to
    turn the dispatched requests back into an output table."""
    table: Table
    keys: List[Tuple]
    use_dedup: bool
    seen: Dict[Tuple, int]
    cached: Dict[int, List[Optional[object]]]
    batches: List[PendingBatch]
    group: DispatchGroup


class PredictOperator:
    def __init__(self, info: PredictInfo, executor: Predictor,
                 session_options: Dict[str, object],
                 prompt_cache: Optional[PromptCache] = None,
                 service: Optional[InferenceService] = None,
                 stats_store=None):
        # --- configuration stage (precedence per §5.3) ---
        opts = dict(DEFAULTS)
        opts.update({k: v for k, v in session_options.items()
                     if k in DEFAULTS})
        opts.update({k: v for k, v in (info.options or {}).items()})
        self.opts = opts
        self.info = info
        self.executor = executor
        executor.configure(opts)
        # --- loading stage ---
        executor.load()
        # dispatch goes through the (usually database-owned) service;
        # standalone operators get a private one
        self.service = service if service is not None else InferenceService()
        # dedup store: the database-owned cross-query cache when injected,
        # else a private per-operator dict
        self.prompt_cache = prompt_cache
        self.cache: Dict[Tuple, List[Optional[object]]] = {}
        # cascaded executors carry a stage tag: their (possibly
        # proxy-resolved) answers must not poison the direct route's
        # cross-query prompt-cache namespace, and their dispatch
        # accounting records under the staged stats key
        self._stage = str(getattr(executor, "stats_stage", "") or "")
        # the namespace must cover every option that changes the *answer*
        # for the same (model, instruction, input): n_samples majority
        # voting, sampling temperature, token/string budgets, and the
        # table-generation row budget.  Batching/slot/window options shape
        # dispatch, not answers, and stay out so they keep sharing entries.
        shaping = tuple(
            (k, opts.get(k, d)) for k, d in (
                ("n_samples", 1), ("temperature", 0.7),
                ("max_tokens", 4096), ("max_str", 24), ("gen_rows", 4))
            if opts.get(k, d) != d)
        self._ns = (info.model_name, self._instruction()) + shaping + \
            ((self._stage,) if self._stage else ())
        self.stats = PredictStats()
        # adaptive statistics: calls/tokens/latency are recorded by the
        # service at dispatch; the operator records retries + fallbacks
        self.stats_store = stats_store
        self._skey = stats_key(info)
        # absolute deadline on the time.monotonic() scale (0 = none):
        # derived once here from the precedence-resolved deadline_ms and
        # the query-start anchor, stamped on every request this operator
        # submits, and re-checked before every retry attempt
        dl_ms = float(opts.get("deadline_ms", 0) or 0)
        self._deadline_ts = 0.0
        if dl_ms > 0:
            start = float(opts.get("query_start_ts", 0.0) or 0.0)
            self._deadline_ts = (start or time.monotonic()) + dl_ms / 1000.0

    def _cache_put(self, k: Tuple, v: List[Optional[object]]) -> None:
        # total parse failures are memoized for the operator's lifetime
        # only: a transient malformed response must not become a sticky
        # NULL answer across queries
        if self.prompt_cache is None or all(x is None for x in v):
            self.cache[k] = v
        else:
            self.prompt_cache.put(self._ns + (k,), v)

    # ------------------------------ prompts --------------------------------
    def _instruction(self) -> str:
        instr = self.info.prompt.instruction if self.info.prompt else \
            f"predict {', '.join(n for n, _ in self.info.outputs)}"
        types = ", ".join(f'"{n}" ({t})' for n, t in self.info.outputs)
        return (f"You are a precise data engine. Task: {instr}\n"
                f"Return ONLY a JSON value with keys {types}. "
                f"No explanations, no code fences.")

    def _render_rows(self, rows: List[dict]) -> str:
        return render_rows(rows)

    # ------------------------------ dispatch -------------------------------
    def _open_group(self) -> DispatchGroup:
        return self.service.open_group(
            workers=int(self.opts.get("n_threads", 16)),
            rpm=float(self.opts.get("rate_limit_rpm", 0)))

    def _submit_call(self, prompt: str, nr: int, rows, instr: str, *,
                     exact_rows: bool = False
                     ) -> Tuple[InferenceHandle, bool]:
        req = InferenceRequest(
            model_name=self.info.model_name, instruction=instr,
            prompt=prompt, schema=tuple(self.info.outputs),
            num_rows=nr if exact_rows else max(nr, 1),
            executor=self.executor, rows=rows,
            dedup=bool(self.opts.get("use_dedup", True)),
            stats_key=self._skey, stage=self._stage,
            tenant=str(self.opts.get("tenant", "") or ""),
            session=str(self.opts.get("session", "") or ""),
            deadline_ts=self._deadline_ts)
        handle, owned = self.service.submit_one(req)
        if not owned:
            self.stats.inflight_hits += 1
        return handle, owned

    def _consume(self, handle: InferenceHandle, owned: bool,
                 group: DispatchGroup) -> CallResult:
        """Force a handle and account it: the call's tokens (owner only)
        and its modeled latency, appended to the chunk's dispatch group in
        consumption order so the greedy makespan matches the synchronous
        schedule exactly."""
        res = handle.result()            # flushes if still queued
        if owned:
            self._account(res)
            group.latencies.append(res.sim_latency_s)
        return res

    def _call_now(self, prompt: str, nr: int, rows, instr: str,
                  group: DispatchGroup, *, exact_rows: bool = False
                  ) -> CallResult:
        """Synchronous call through the service (retries, fallbacks)."""
        handle, owned = self._submit_call(prompt, nr, rows, instr,
                                          exact_rows=exact_rows)
        return self._consume(handle, owned, group)

    # ------------------------------ resilience -----------------------------
    def _session(self) -> str:
        return str(self.opts.get("session", "") or "")

    def _remaining(self) -> float:
        """Seconds until the query deadline (+inf when none is set)."""
        if not self._deadline_ts:
            return float("inf")
        return self._deadline_ts - time.monotonic()

    def _backoff(self, attempt: int, prompt: str) -> None:
        """Exponential backoff before retry `attempt` (1-based), with
        deterministic jitter seeded from the prompt so replays sleep the
        same schedule.  Capped at the remaining deadline; a zero base
        (the default) retries immediately like the old bare loop."""
        base = float(self.opts.get("retry_backoff_s", 0) or 0)
        if base <= 0:
            return
        h = hashlib.sha256(f"backoff:{attempt}:{prompt}".encode()).digest()
        jitter = 0.5 + h[0] / 512.0            # deterministic [0.5, 1.0)
        delay = base * (2 ** (attempt - 1)) * jitter
        rem = self._remaining()
        if rem != float("inf"):
            delay = min(delay, max(0.0, rem))
        if delay > 0:
            time.sleep(delay)

    def _force_result(self, handle: InferenceHandle, owned: bool,
                      group: DispatchGroup, *, prompt: str, nr: int,
                      rows, instr: str, exact_rows: bool = False
                      ) -> Optional[CallResult]:
        """Force a handle, absorbing the fault model: transient backend
        failures (injected faults, call timeouts, open breakers) are
        retried with deterministic exponential backoff, re-checking the
        remaining deadline before each attempt; an expired deadline or an
        exhausted retry budget returns None and the caller degrades to
        NULL outputs instead of crashing the query."""
        retries = int(self.opts.get("retry_limit", 2))
        attempt = 0
        while True:
            try:
                return self._consume(handle, owned, group)
            except QueryCancelled:
                raise
            except DeadlineExceeded:
                # the service already counted the dispatch-side drop
                self.stats.deadline_drops += 1
                return None
            except TransientError:
                attempt += 1
                if attempt > retries:
                    return None
                if self._remaining() <= 0:
                    self.stats.deadline_drops += 1
                    self.service.note_deadline_drop(self._session())
                    return None
                self.stats.transient_retries += 1
                self.service.note_transient_retry(self._session())
                self._backoff(attempt, prompt)
                handle, owned = self._submit_call(prompt, nr, rows, instr,
                                                  exact_rows=exact_rows)

    # ------------------------------ execution -------------------------------
    def __call__(self, table: Table) -> Table:
        """Synchronous table/scalar inference — the degenerate pipeline:
        submit one chunk and resolve it immediately."""
        return self.resolve(self.submit(table))

    def submit(self, table: Table) -> PendingChunk:
        """Phase 1: probe caches, marshal the misses into batched requests
        and queue them on the inference service.  Returns without
        dispatching — `resolve` (or any service flush) does that."""
        t0 = time.time()
        n = len(table)
        self.stats.rows_in += n
        in_cols = [c for c in self.info.inputs]
        rows = [{c: table.row(i)[c] for c in in_cols} for i in range(n)] \
            if in_cols else [{} for _ in range(n)]
        keys = [tuple(sorted(r.items())) for r in rows]

        use_dedup = bool(self.opts.get("use_dedup", True))
        pending: List[int] = []
        seen: Dict[Tuple, int] = {}
        cached: Dict[int, List[Optional[object]]] = {}
        for i, k in enumerate(keys):
            if not use_dedup:
                pending.append(i)
                continue
            if k in seen:                  # in-chunk duplicate of a pending
                self.stats.cache_hits += 1   # key: no cache probe
                continue
            v = self.cache.get(k, _MISS)   # operator-lifetime memo
            if v is _MISS and self.prompt_cache is not None:
                v = self.prompt_cache.get(self._ns + (k,))
                if v is not _MISS:
                    self.stats.pc_hits += 1
            if v is not _MISS:
                self.stats.cache_hits += 1
                cached[i] = v
                continue
            seen[k] = i
            pending.append(i)
            if self.prompt_cache is not None:
                self.stats.pc_misses += 1

        bs = int(self.opts.get("batch_size", 16)) \
            if self.opts.get("use_batching", True) else 1
        group = self._open_group()
        instr = self._instruction()
        batches: List[PendingBatch] = []
        for s in range(0, len(pending), bs):
            idxs = pending[s:s + bs]
            batch_rows = [rows[i] for i in idxs]
            prompt = instr + "\n" + self._render_rows(batch_rows)
            handle, owned = self._submit_call(prompt, len(batch_rows),
                                              batch_rows, instr)
            batches.append(PendingBatch(idxs, batch_rows, handle, owned))

        self.stats.wall_s += time.time() - t0
        return PendingChunk(table, keys, use_dedup, seen, cached, batches,
                            group)

    def kick(self) -> None:
        """Speculatively start background dispatch of hot service queues
        (complete `max_dispatch`-sized slices on concurrency-capable
        backends).  Physical operators call this after each `submit` so
        dispatch overlaps the production of the next window instead of
        waiting for the first `resolve`."""
        self.service.kick()

    def resolve(self, pending: PendingChunk) -> Table:
        """Phase 2: force dispatch, parse/retry/fallback every batch, and
        assemble the output chunk.  `drain_for` dispatches exactly the
        slices covering this chunk's handles (scheduling
        concurrency-capable backends on their worker lanes); requests
        queued behind them — later inflight windows, other sessions —
        stay queued for their own resolve, so an early-exit Limit can
        still cancel them undispatched.  The per-handle `result()` calls
        below then block on any lane futures (synchronous backends
        dispatch inline during the drain)."""
        t0 = time.time()
        self.service.drain_for([b.handle for b in pending.batches])
        results: Dict[int, List[Optional[object]]] = {}
        for b in pending.batches:
            vals = self._resolve_batch(b, pending.group)
            for i, v in zip(b.idxs, vals):
                results[i] = v
                if pending.use_dedup:
                    self._cache_put(pending.keys[i], v)

        self.stats.sim_latency_s += pending.group.makespan()
        self.stats.serial_latency_s += pending.group.serial()

        out_vals: List[List[Optional[object]]] = []
        for i, k in enumerate(pending.keys):
            if i in results:
                out_vals.append(results[i])
            elif i in pending.cached:
                out_vals.append(pending.cached[i])
            elif pending.use_dedup and pending.seen.get(k) in results:
                out_vals.append(results[pending.seen[k]])
            else:
                out_vals.append([None] * len(self.info.outputs))

        out = pending.table
        for j, ((name, typ), col) in enumerate(
                zip(self.info.outputs, self.info.out_cols)):
            colvals = [v[j] for v in out_vals]
            self.stats.null_outputs += sum(1 for v in colvals if v is None)
            out = out.with_column(col, _coerce(colvals, typ), typ)
        self.stats.wall_s += time.time() - t0
        return out

    def cancel(self, pending: PendingChunk) -> None:
        """Discard a submitted chunk whose results are no longer needed
        (pipelined operator closed early, e.g. under a Limit).  Joined
        batches release their reference too, so a request is dropped from
        the queue exactly when its last interested chunk cancels."""
        for b in pending.batches:
            self.service.cancel(b.handle)

    # table generation (ρ^s)
    def scan(self, max_rows: int = 64) -> Table:
        t0 = time.time()
        group = self._open_group()
        prompt = self._instruction() + \
            f"\nReturn a JSON array of at most {max_rows} objects."
        raw = self.info.prompt.instruction if self.info.prompt else ""
        # num_rows=0 is meaningful here: table generation lets the model
        # decide cardinality
        handle, owned = self._submit_call(prompt, 0, [], raw,
                                          exact_rows=True)
        res = self._force_result(handle, owned, group, prompt=prompt, nr=0,
                                 rows=[], instr=raw, exact_rows=True)
        rows = []
        v = None if res is None else extract_json(res.text)
        if v is not None:
            objs = v if isinstance(v, list) else [v]
            for o in objs[:max_rows]:
                if isinstance(o, dict):
                    rows.append({n: cast_value(o.get(n), t)
                                 for n, t in self.info.outputs})
        self.stats.sim_latency_s += group.makespan()
        self.stats.serial_latency_s += group.serial()
        cols = {}
        sch = {}
        for (n, t), c in zip(self.info.outputs, self.info.out_cols):
            cols[c] = _coerce([r.get(n) for r in rows], t)
            sch[c] = t
        self.stats.wall_s += time.time() - t0
        return Table(cols, sch)

    # semantic aggregate (LLM AGG): one call per group, all groups
    # dispatched as one service batch
    def aggregate(self, groups: List[List[dict]]) -> List[Optional[object]]:
        t0 = time.time()
        group = self._open_group()
        instr = self._instruction()
        suffix = "\nAggregate ALL rows into ONE JSON object."
        pend = []
        for g in groups:
            prompt = instr + "\n" + self._render_rows(g) + suffix
            pend.append((g, *self._submit_call(prompt, 1, g, instr)))
        self.service.drain_for([h for _, h, _ in pend])
        outs = []
        retries = int(self.opts.get("retry_limit", 2))
        for g, handle, owned in pend:
            prompt = instr + "\n" + self._render_rows(g) + suffix
            res = self._force_result(handle, owned, group, prompt=prompt,
                                     nr=1, rows=g, instr=instr)
            parsed = None if res is None else \
                parse_structured(res.text, self.info.outputs, 1)
            attempt = 0
            while res is not None and parsed is None and attempt < retries:
                if self._remaining() <= 0:
                    # deadline re-check before each retry (see
                    # _resolve_batch): expired groups degrade to NULL
                    self.stats.deadline_drops += 1
                    self.service.note_deadline_drop(self._session())
                    break
                attempt += 1
                self._note_retry()
                stricter = (instr + _STRICT + self._render_rows(g) + suffix)
                sh, sowned = self._submit_call(stricter, 1, g, instr)
                res = self._force_result(sh, sowned, group, prompt=stricter,
                                         nr=1, rows=g, instr=instr)
                parsed = None if res is None else \
                    parse_structured(res.text, self.info.outputs, 1)
            outs.append(parsed[0][self.info.outputs[0][0]] if parsed else None)
        self.stats.sim_latency_s += group.makespan()
        self.stats.serial_latency_s += group.serial()
        self.stats.wall_s += time.time() - t0
        return outs

    # ------------------------------------------------------------------
    def _resolve_batch(self, b: PendingBatch, group: DispatchGroup
                       ) -> List[List[Optional[object]]]:
        """Parse one resolved batch (+strict retries, + per-tuple
        fallback). Returns per-row output value lists."""
        nr = len(b.rows)
        instr = self._instruction()
        prompt = instr + "\n" + self._render_rows(b.rows)
        res = self._force_result(b.handle, b.owned, group, prompt=prompt,
                                 nr=nr, rows=b.rows, instr=instr)
        if res is None:                 # deadline / retry budget exhausted
            return [[None] * len(self.info.outputs) for _ in b.idxs]
        parsed = parse_structured(res.text, self.info.outputs, nr)
        retries = int(self.opts.get("retry_limit", 2))
        attempt = 0
        while parsed is None and attempt < retries:
            if self._remaining() <= 0:
                # re-check the deadline before every retry attempt: a
                # nearly-expired chunk no longer burns the full
                # retry_limit — it degrades to NULLs immediately
                self.stats.deadline_drops += 1
                self.service.note_deadline_drop(self._session())
                return [[None] * len(self.info.outputs) for _ in b.idxs]
            attempt += 1
            self._note_retry()
            stricter = instr + _STRICT + self._render_rows(b.rows)
            sh, sowned = self._submit_call(stricter, nr, b.rows, instr)
            res = self._force_result(sh, sowned, group, prompt=stricter,
                                     nr=nr, rows=b.rows, instr=instr)
            if res is None:
                return [[None] * len(self.info.outputs) for _ in b.idxs]
            parsed = parse_structured(res.text, self.info.outputs, nr)

        if parsed is None and nr > 1:
            # §6.3: failed batch → per-tuple fallback, dispatched together
            self._note_fallback()
            subs = []
            for i, r in zip(b.idxs, b.rows):
                prompt = instr + "\n" + self._render_rows([r])
                handle, owned = self._submit_call(prompt, 1, [r], instr)
                subs.append(PendingBatch([i], [r], handle, owned))
            self.service.drain_for([sb.handle for sb in subs])
            return [self._resolve_batch(sb, group)[0] for sb in subs]
        if parsed is None:
            return [[None] * len(self.info.outputs)]
        names = [n for n, _ in self.info.outputs]
        return [[p[n] for n in names] for p in parsed]

    def _account(self, res: CallResult) -> None:
        self.stats.calls += 1
        self.stats.in_tokens += res.in_tokens
        self.stats.out_tokens += res.out_tokens
        self.stats.prefill_tokens += res.prefill_tokens
        self.stats.decode_tokens += res.decode_tokens
        self.stats.prefix_hits += res.prefix_hits
        self.stats.radix_hit_tokens += res.radix_hit_tokens
        self.stats.proxy_calls += res.proxy_calls
        self.stats.escalated_calls += res.escalated_calls
        self.stats.cascade_rows += res.cascade_rows
        self.stats.escalated_rows += res.escalated_rows
        self.stats.degraded_calls += res.degraded_calls

    def _note_retry(self) -> None:
        self.stats.retries += 1
        if self.stats_store is not None:
            self.stats_store.record_retry(self._skey)

    def _note_fallback(self) -> None:
        self.stats.batch_fallbacks += 1
        if self.stats_store is not None:
            self.stats_store.record_fallback(self._skey)
