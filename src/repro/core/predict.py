"""The physical PREDICT operator (paper §5) with the intra-operator
optimizations of §6:

  configuration stage  — option precedence: model OPTIONS > session SET >
                         defaults (§5.3)
  loading stage        — executor resolution via the registry
  execution stage      — chunked, vectorized:
      prompt rewriting      (§5.1: placeholders → key/value tuple data,
                             type instructions, row-count instructions)
      structured output     (§5.2: schema → grammar for local models /
                             JSON guidance for remote)
      prompt deduplication  (§6.1: concurrent input→output cache)
      multi-row marshaling  (§6.2: batch_size rows per call; cache-hit rows
                             excluded from the batch)
      parallel dispatch     (§6.3: worker pool + provider rate limit —
                             modeled as a greedy makespan schedule over the
                             per-call latencies; batch failure falls back
                             to per-tuple calls)
      typed extraction      (Table 3: VARCHAR/INTEGER/DOUBLE/BOOLEAN/
                             DATETIME), retry with stricter formatting on
                             unparsable output
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import re
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executors import CallResult, Predictor
from repro.relational.plan import PredictInfo
from repro.relational.table import Table, _coerce

DEFAULTS = {
    "batch_size": 16,        # marshaled rows per call
    "n_threads": 16,         # parallel workers
    "use_batching": True,    # multi-row marshaling
    "use_dedup": True,       # prompt deduplication
    "rate_limit_rpm": 0,     # 0 = unlimited
    "retry_limit": 2,
    "chunk_size": 2048,      # vectorized chunk (DuckDB-analog)
}


@dataclasses.dataclass
class PredictStats:
    calls: int = 0
    in_tokens: int = 0
    out_tokens: int = 0
    sim_latency_s: float = 0.0     # modeled makespan (workers + rate limit)
    serial_latency_s: float = 0.0  # sum of per-call latencies
    wall_s: float = 0.0
    rows_in: int = 0
    cache_hits: int = 0
    retries: int = 0
    batch_fallbacks: int = 0
    null_outputs: int = 0
    pc_hits: int = 0               # cross-query prompt-cache hits
    pc_misses: int = 0             # lookups that had to dispatch a call

    def add(self, o: "PredictStats") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))


def makespan(latencies: Sequence[float], workers: int, rpm: float = 0.0
             ) -> float:
    """Greedy schedule of calls onto `workers`, optionally throttled to
    `rpm` requests/minute (paper Fig. 5 model)."""
    if not latencies:
        return 0.0
    heap = [0.0] * max(1, workers)
    heapq.heapify(heap)
    gap = 60.0 / rpm if rpm else 0.0
    next_slot = 0.0
    end = 0.0
    for l in latencies:
        free = heapq.heappop(heap)
        start = max(free, next_slot)
        next_slot = start + gap
        fin = start + l
        end = max(end, fin)
        heapq.heappush(heap, fin)
    return end


_JSON_RE = re.compile(r"[\[{].*[\]}]", re.DOTALL)


def extract_json(text: str) -> Optional[object]:
    """Locate and parse the outermost JSON value in model text, tolerating
    surrounding prose. Returns the decoded value or None."""
    m = _JSON_RE.search(text)
    if not m:
        return None
    try:
        return json.loads(m.group(0))
    except json.JSONDecodeError:
        return None


def parse_structured(text: str, schema: Sequence[Tuple[str, str]],
                     num_rows: int) -> Optional[List[dict]]:
    """Extract typed rows from model text; returns None if unusable."""
    v = extract_json(text)
    if v is None:
        return None
    objs = v if isinstance(v, list) else [v]
    if len(objs) < num_rows:
        return None
    out = []
    for o in objs[:num_rows]:
        if not isinstance(o, dict):
            return None
        row = {}
        for name, typ in schema:
            row[name] = cast_value(o.get(name), typ)
        out.append(row)
    return out


def cast_value(v, typ: str):
    t = typ.upper()
    try:
        if v is None:
            return None
        if t == "INTEGER":
            return int(v)
        if t == "DOUBLE":
            return float(v)
        if t == "BOOLEAN":
            if isinstance(v, str):
                return v.strip().lower() in ("true", "yes", "1")
            return bool(v)
        return str(v)
    except (TypeError, ValueError):
        return None


_MISS = object()


class PromptCache:
    """Cross-query prompt cache, owned by the database and shared by every
    PredictOperator it creates. Keyed by (model, instruction, input tuple);
    survives across operators, chunks, and queries, so a repeated query (or
    an overlapping one against the same model/instruction) re-uses prior
    inference results instead of re-dispatching calls."""

    def __init__(self, max_entries: int = 200_000):
        self._d: Dict[Tuple, List[Optional[object]]] = {}
        self.max_entries = max_entries
        self.hits = 0
        self.misses = 0

    def get(self, key: Tuple):
        v = self._d.get(key, _MISS)
        if v is _MISS:
            self.misses += 1
        else:
            self.hits += 1
        return v

    def put(self, key: Tuple, value: List[Optional[object]]) -> None:
        if key not in self._d and len(self._d) >= self.max_entries:
            self._d.pop(next(iter(self._d)))          # FIFO eviction
        self._d[key] = value

    def __len__(self) -> int:
        return len(self._d)

    def clear(self) -> None:
        self._d.clear()


class PredictOperator:
    def __init__(self, info: PredictInfo, executor: Predictor,
                 session_options: Dict[str, object],
                 prompt_cache: Optional[PromptCache] = None):
        # --- configuration stage (precedence per §5.3) ---
        opts = dict(DEFAULTS)
        opts.update({k: v for k, v in session_options.items()
                     if k in DEFAULTS})
        opts.update({k: v for k, v in (info.options or {}).items()})
        self.opts = opts
        self.info = info
        self.executor = executor
        executor.configure(opts)
        # --- loading stage ---
        executor.load()
        # dedup store: the database-owned cross-query cache when injected,
        # else a private per-operator dict
        self.prompt_cache = prompt_cache
        self.cache: Dict[Tuple, List[Optional[object]]] = {}
        self._ns = (info.model_name, self._instruction())
        self.stats = PredictStats()

    def _cache_put(self, k: Tuple, v: List[Optional[object]]) -> None:
        # total parse failures are memoized for the operator's lifetime
        # only: a transient malformed response must not become a sticky
        # NULL answer across queries
        if self.prompt_cache is None or all(x is None for x in v):
            self.cache[k] = v
        else:
            self.prompt_cache.put(self._ns + (k,), v)

    # ------------------------------ prompts --------------------------------
    def _instruction(self) -> str:
        instr = self.info.prompt.instruction if self.info.prompt else \
            f"predict {', '.join(n for n, _ in self.info.outputs)}"
        types = ", ".join(f'"{n}" ({t})' for n, t in self.info.outputs)
        return (f"You are a precise data engine. Task: {instr}\n"
                f"Return ONLY a JSON value with keys {types}. "
                f"No explanations, no code fences.")

    def _render_rows(self, rows: List[dict]) -> str:
        if len(rows) == 1:
            return "Input: " + json.dumps(rows[0], default=str)
        return (f"Inputs ({len(rows)} rows — return a JSON array with "
                f"exactly {len(rows)} objects, in order): "
                + json.dumps(rows, default=str))

    # ------------------------------ execution -------------------------------
    def __call__(self, table: Table) -> Table:
        """Table/scalar inference: append predicted columns to `table`."""
        t0 = time.time()
        n = len(table)
        self.stats.rows_in += n
        in_cols = [c for c in self.info.inputs]
        rows = [{c: table.row(i)[c] for c in in_cols} for i in range(n)] \
            if in_cols else [{} for _ in range(n)]
        keys = [tuple(sorted(r.items())) for r in rows]

        use_dedup = bool(self.opts.get("use_dedup", True))
        pending: List[int] = []
        seen: Dict[Tuple, int] = {}
        cached: Dict[int, List[Optional[object]]] = {}
        for i, k in enumerate(keys):
            if not use_dedup:
                pending.append(i)
                continue
            if k in seen:                  # in-chunk duplicate of a pending
                self.stats.cache_hits += 1   # key: no cache probe
                continue
            v = self.cache.get(k, _MISS)   # operator-lifetime memo
            if v is _MISS and self.prompt_cache is not None:
                v = self.prompt_cache.get(self._ns + (k,))
                if v is not _MISS:
                    self.stats.pc_hits += 1
            if v is not _MISS:
                self.stats.cache_hits += 1
                cached[i] = v
                continue
            seen[k] = i
            pending.append(i)
            if self.prompt_cache is not None:
                self.stats.pc_misses += 1

        bs = int(self.opts.get("batch_size", 16)) \
            if self.opts.get("use_batching", True) else 1
        batches = [pending[i:i + bs] for i in range(0, len(pending), bs)]

        latencies: List[float] = []
        results: Dict[int, List[Optional[object]]] = {}
        for batch in batches:
            batch_rows = [rows[i] for i in batch]
            vals, lat = self._run_batch(batch_rows)
            latencies.extend(lat)
            for i, v in zip(batch, vals):
                results[i] = v
                if use_dedup:
                    self._cache_put(keys[i], v)

        workers = int(self.opts.get("n_threads", 16))
        rpm = float(self.opts.get("rate_limit_rpm", 0))
        self.stats.sim_latency_s += makespan(latencies, workers, rpm)
        self.stats.serial_latency_s += sum(latencies)

        out_vals: List[List[Optional[object]]] = []
        for i, k in enumerate(keys):
            if i in results:
                out_vals.append(results[i])
            elif i in cached:
                out_vals.append(cached[i])
            elif use_dedup and seen.get(k) in results:
                out_vals.append(results[seen[k]])
            else:
                out_vals.append([None] * len(self.info.outputs))

        out = table
        for j, ((name, typ), col) in enumerate(
                zip(self.info.outputs, self.info.out_cols)):
            colvals = [v[j] for v in out_vals]
            self.stats.null_outputs += sum(1 for v in colvals if v is None)
            out = out.with_column(col, _coerce(colvals, typ), typ)
        self.stats.wall_s += time.time() - t0
        return out

    # table generation (ρ^s)
    def scan(self, max_rows: int = 64) -> Table:
        t0 = time.time()
        instr = self._instruction() + \
            f"\nReturn a JSON array of at most {max_rows} objects."
        res = self.executor.complete(
            instr, self.info.outputs, num_rows=0, rows=[],
            instruction=self.info.prompt.instruction if self.info.prompt else "")
        self._account(res)
        rows = []
        v = extract_json(res.text)
        if v is not None:
            objs = v if isinstance(v, list) else [v]
            for o in objs[:max_rows]:
                if isinstance(o, dict):
                    rows.append({n: cast_value(o.get(n), t)
                                 for n, t in self.info.outputs})
        self.stats.sim_latency_s += res.sim_latency_s
        self.stats.serial_latency_s += res.sim_latency_s
        cols = {}
        sch = {}
        for (n, t), c in zip(self.info.outputs, self.info.out_cols):
            cols[c] = _coerce([r.get(n) for r in rows], t)
            sch[c] = t
        self.stats.wall_s += time.time() - t0
        return Table(cols, sch)

    # semantic aggregate (LLM AGG): one call per group
    def aggregate(self, groups: List[List[dict]]) -> List[Optional[object]]:
        t0 = time.time()
        outs = []
        lats = []
        for g in groups:
            instr = self._instruction()
            prompt = instr + "\n" + self._render_rows(g) + \
                "\nAggregate ALL rows into ONE JSON object."
            res = self.executor.complete(prompt, self.info.outputs, 1,
                                         rows=g, instruction=instr)
            self._account(res)
            lats.append(res.sim_latency_s)
            parsed = parse_structured(res.text, self.info.outputs, 1)
            outs.append(parsed[0][self.info.outputs[0][0]] if parsed else None)
        self.stats.sim_latency_s += makespan(
            lats, int(self.opts.get("n_threads", 16)),
            float(self.opts.get("rate_limit_rpm", 0)))
        self.stats.serial_latency_s += sum(lats)
        self.stats.wall_s += time.time() - t0
        return outs

    # ------------------------------------------------------------------
    def _run_batch(self, batch_rows: List[dict]
                   ) -> Tuple[List[List[Optional[object]]], List[float]]:
        """One marshaled call (+retries, + per-tuple fallback). Returns
        (per-row output value lists, call latencies)."""
        instr = self._instruction()
        nr = len(batch_rows)
        lats: List[float] = []

        text, lat = self._call(instr + "\n" + self._render_rows(batch_rows),
                               nr, batch_rows, instr)
        lats.append(lat)
        parsed = parse_structured(text, self.info.outputs, nr)
        retries = int(self.opts.get("retry_limit", 2))
        attempt = 0
        while parsed is None and attempt < retries:
            attempt += 1
            self.stats.retries += 1
            stricter = (instr + "\nSTRICT: output MUST be raw JSON parsable "
                        "by json.loads, nothing else.\n"
                        + self._render_rows(batch_rows))
            text, lat = self._call(stricter, nr, batch_rows, instr)
            lats.append(lat)
            parsed = parse_structured(text, self.info.outputs, nr)

        if parsed is None and nr > 1:
            # §6.3: failed batch → per-tuple fallback
            self.stats.batch_fallbacks += 1
            vals = []
            for r in batch_rows:
                v, l2 = self._run_batch([r])
                vals.append(v[0])
                lats.extend(l2)
            return vals, lats
        if parsed is None:
            return [[None] * len(self.info.outputs)], lats
        names = [n for n, _ in self.info.outputs]
        return [[p[n] for n in names] for p in parsed], lats

    def _call(self, prompt: str, nr: int, rows, instr) -> Tuple[str, float]:
        res = self.executor.complete(prompt, self.info.outputs, max(nr, 1),
                                     rows=rows, instruction=instr)
        self._account(res)
        return res.text, res.sim_latency_s

    def _account(self, res: CallResult) -> None:
        self.stats.calls += 1
        self.stats.in_tokens += res.in_tokens
        self.stats.out_tokens += res.out_tokens
