"""iPDB — the public database API.

    db = IPDB()
    db.register_table("Product", table)
    db.sql("CREATE LLM MODEL o4mini PATH 'oracle:pcparts' ON PROMPT API '...'")
    out = db.sql("SELECT name FROM Product WHERE LLM o4mini (PROMPT '...')")

Executor resolution by model PATH scheme:
    oracle:<name>   → OracleExecutor using a registered oracle fn
    jax:<arch>      → JaxExecutor on an in-process InferenceEngine
                      (smoke-size config of the named architecture)
    *.onnx / tabular:<name> → TabularExecutor via a registered predict fn
    custom:<name>   → a registered executor factory (tests/benchmarks)
"""
from __future__ import annotations

import dataclasses
import inspect
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional

from repro.core.cancel import CancelScope, QueryCancelled

from repro.core.executors import (JaxExecutor, OracleExecutor, Predictor,
                                  TabularExecutor)
from repro.core.optimizer import DEFAULT_FLAGS, Optimizer
from repro.core.predict import PredictOperator, PromptCache
from repro.core.rewrite import rewrites_section
from repro.core.service import InferenceService
from repro.core.stats import (CostModel, PilotSampler, StatisticsStore,
                              stats_section)
from repro.relational.binder import Binder
from repro.relational.catalog import Catalog, ModelEntry
from repro.relational.executor import ExecStats, PlanExecutor
from repro.relational.parser import (CreateModel, CreateTableAs, SelectStmt,
                                     SetStmt, parse_sql)
from repro.relational.plan import Node, PredictInfo, plan_repr
from repro.relational.table import Table


@dataclasses.dataclass
class QueryResult:
    table: Optional[Table]
    stats: ExecStats
    plan: Optional[str] = None


class IPDB:
    def __init__(self, *, session_options: Optional[Dict[str, object]] = None,
                 snapshot_dir: Optional[str] = None):
        self.catalog = Catalog()
        self.options: Dict[str, object] = {
            "batch_size": 16, "n_threads": 16, "use_batching": True,
            "use_dedup": True, "rate_limit_rpm": 0.0,
            "inflight_windows": 1, "max_dispatch_calls": 0,
            # per-backend dispatch worker pools: 1 = synchronous flush on
            # the submitting thread (the pre-pool behavior); >1 lets
            # concurrency-capable backends dispatch on background threads
            # (clamped to each executor's max_concurrency).  Speculative
            # flush starts complete max_dispatch_calls-sized slices early.
            "dispatch_workers": 1, "speculative_flush": True,
            # adaptive statistics: pilot-sample predicates with no history
            # at optimize time (only when the input is ≳4× the sample —
            # override with pilot_min_rows — so the pilot cost amortizes)
            "enable_pilot": True, "pilot_sample_rows": 16,
            # jax serving engine KV layout: "dense" keeps per-slot
            # max_len caches (seed behavior); "paged" switches to the
            # block-table page pool with zero-copy shared-prefix pages.
            # kv_pool_pages pins the pool size (None = grow on demand).
            "kv_layout": "dense", "kv_page_size": 64, "kv_pool_pages": None,
            # paged-engine prefix reuse: "radix" discovers partial token
            # overlap in a refcounted prefix tree; "exact" is the PR-5
            # whole-string memo.  kv_quant="int8" stores tree-frozen pages
            # as int8 with per-page scales (live pages stay fp).
            # n_samples>1 decodes that many streams per row off a shared
            # copy-on-write prompt fork and majority-votes the answer.
            "kv_prefix_mode": "radix", "kv_quant": "none", "n_samples": 1,
            # calibrated model cascades: any model whose merged options
            # carry cascade_proxy=<model> routes through a CascadePredictor
            # targeting cascade_target_precision (override per model via
            # OPTIONS or per expression via PREDICT ... WITH (...)).
            # cascade_min_records gates calibration on held-out evidence;
            # cascade_audit_every audits 1-in-N accepted rows to keep the
            # reservoir honest (0 disables).  enable_cascade (optimizer
            # flag, in DEFAULT_FLAGS) turns routing off entirely.
            "cascade_target_precision": 0.9, "cascade_min_records": 8,
            "cascade_audit_every": 16,
            # fault tolerance: per-dispatch-call timeout (0 = unbounded,
            # the seed behavior), deterministic-jitter retry backoff for
            # transient failures, per-backend circuit-breaker policy, and
            # a session-default end-to-end deadline (0 = none; override
            # per expression via WITH (deadline_ms=...)).  snapshot_keep
            # bounds the on-disk warm-state snapshot history.
            "call_timeout_s": 0.0, "retry_backoff_s": 0.0,
            "breaker_threshold": 3, "breaker_probe_every": 4,
            "deadline_ms": 0, "snapshot_keep": 3,
            **DEFAULT_FLAGS,
        }
        if session_options:
            self.options.update(session_options)
        self._oracles: Dict[str, Callable] = {}
        self._tabular_fns: Dict[str, Callable] = {}
        # keyed (arch, kv_layout, page_size, pool_pages)
        self._jax_engines: Dict[tuple, object] = {}
        self._oracle_kwargs: Dict[str, dict] = {}
        self._executor_factories: Dict[str, Callable] = {}
        self.last_stats: Optional[ExecStats] = None
        # cross-query prompt cache: shared by every predict operator this
        # database creates (keyed by model + instruction + input tuple)
        self.prompt_cache = PromptCache()
        # adaptive statistics: per-(model, instruction) observed
        # selectivity / tokens / latency / retry rates, persisting across
        # queries exactly like the prompt cache
        self.stats_store = StatisticsStore()
        # one inference service per session: every predict operator routes
        # its dispatch through it (batching, in-flight dedup, scheduling);
        # dispatched calls feed the statistics store
        self.inference_service = InferenceService(stats_store=self.stats_store)
        # front-door streams: parse/bind/optimize are serialized (the
        # binder's column-name counter and the optimizer's store access
        # are cheap; the chunked EXECUTION below them runs concurrently),
        # and each stream gets a monotonically numbered session tag
        self._bind_lock = threading.Lock()
        self._stream_seq = 0
        # crash-safe warm state: when snapshot_dir is set, opening the
        # database restores the newest valid snapshot (prompt cache,
        # statistics store, radix prefix-cache KV); corrupt or missing
        # snapshots mean a cold start, never an error.  Radix payloads are
        # restored lazily — engines are created on first use, so restored
        # KV is staged per engine cache key until then.
        self.snapshot_dir = snapshot_dir
        self.restored_snapshot: Optional[str] = None
        self.snapshot_skipped: List[str] = []
        self._pending_radix: Dict[tuple, dict] = {}
        if snapshot_dir:
            self._restore_snapshot()

    # -- warm-state snapshots --------------------------------------------
    def save_snapshot(self) -> Optional[str]:
        """Atomically write the database's warm state to `snapshot_dir`:
        prompt-cache entries, statistics-store records, and the radix
        prefix-cache KV pages of every live jax engine.  Returns the
        snapshot path, or None when no snapshot_dir is configured."""
        if not self.snapshot_dir:
            return None
        from repro.core.snapshot import write_snapshot
        radix: Dict[tuple, dict] = {}
        for key, eng in self._jax_engines.items():
            state = eng.export_radix_state()
            if state is not None and state.get("entries"):
                radix[key] = state
        payload = {
            "prompt_cache": self.prompt_cache.export_state(),
            "stats_store": self.stats_store.export_state(),
            "radix": radix,
        }
        return write_snapshot(self.snapshot_dir, payload,
                              keep=int(self.options.get("snapshot_keep", 3)))

    def _restore_snapshot(self) -> None:
        """Restore the newest valid snapshot; any failure (corrupt file,
        schema drift) degrades to a cold start, never an error."""
        from repro.core.snapshot import load_latest
        payload, path, skipped = load_latest(self.snapshot_dir)
        self.snapshot_skipped = skipped
        if payload is None:
            return
        try:
            self.prompt_cache.restore_state(payload.get("prompt_cache") or [])
            self.stats_store.restore_state(payload.get("stats_store") or {})
            self._pending_radix = dict(payload.get("radix") or {})
            self.restored_snapshot = path
        except Exception:
            # a half-applied restore must not poison the session
            self.prompt_cache = PromptCache()
            self.stats_store.clear()
            self._pending_radix = {}
            self.restored_snapshot = None
            if path:
                self.snapshot_skipped.append(path)

    # -- lifecycle -------------------------------------------------------
    def close(self, *, cancel_pending: bool = False) -> None:
        """Shut the session's inference service down and join its dispatch
        worker threads (idempotent).  Queued requests are drained first
        unless `cancel_pending`.  Sessions that never raise
        `dispatch_workers` above 1 have no threads to join, so existing
        callers that drop the database without closing leak nothing."""
        self.inference_service.shutdown(cancel_pending=cancel_pending)

    def __enter__(self) -> "IPDB":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(cancel_pending=exc_type is not None)

    # -- registration ---------------------------------------------------
    def register_table(self, name: str, t: Table) -> None:
        self.catalog.register_table(name, t)

    def register_oracle(self, name: str, fn: Callable, **kwargs) -> None:
        """Oracle executors for accuracy-bearing benchmarks:
        fn(instruction, rows) -> list of output dicts."""
        self._oracles[name] = fn
        self._oracle_kwargs[name] = kwargs

    def register_tabular(self, name: str, fn: Callable) -> None:
        self._tabular_fns[name] = fn

    def register_executor(self, name: str, factory: Callable) -> None:
        """Custom executor backends: `factory(entry) -> Predictor` is
        resolved by model PATH 'custom:<name>'.  Used by tests/benchmarks
        to plug scripted backends into the full SQL path."""
        self._executor_factories[name] = factory

    def set_option(self, key: str, value) -> None:
        self.options[key] = value

    # -- executor resolution ---------------------------------------------
    def _make_executor(self, entry: ModelEntry) -> Predictor:
        path = entry.path
        if path.startswith("oracle:"):
            name = path.split(":", 1)[1]
            if name not in self._oracles:
                raise KeyError(f"oracle {name!r} not registered")
            return OracleExecutor(self._oracles[name],
                                  **self._oracle_kwargs.get(name, {}))
        if path.startswith("jax:"):
            arch = path.split(":", 1)[1]
            layout = str(entry.options.get(
                "kv_layout", self.options.get("kv_layout", "dense")))
            pool = entry.options.get(
                "kv_pool_pages", self.options.get("kv_pool_pages"))
            pool = None if pool is None else int(pool)
            page_size = int(entry.options.get(
                "kv_page_size", self.options.get("kv_page_size", 64)))
            max_len = int(entry.options.get("max_len", 512))
            if layout == "dense":
                # paged-only knobs must not split behaviorally identical
                # dense engines into separate instances
                page_size, pool = 64, None
            pmode = str(entry.options.get(
                "kv_prefix_mode", self.options.get("kv_prefix_mode",
                                                   "radix")))
            quant = str(entry.options.get(
                "kv_quant", self.options.get("kv_quant", "none")))
            if layout == "dense":
                pmode, quant = "radix", "none"
            # every option that shapes the engine is part of the cache
            # key — two models must never silently share a mismatched one
            key = (arch, layout, page_size, pool, max_len, pmode, quant)
            if key not in self._jax_engines:
                import repro.configs as C
                from repro.serving.engine import InferenceEngine
                cfg = C.get_smoke_config(arch).replace(vocab_size=259)
                self._jax_engines[key] = InferenceEngine(
                    cfg, max_len=max_len,
                    kv_layout=layout, page_size=page_size,
                    page_pool_pages=pool, prefix_cache_mode=pmode,
                    kv_quant=quant)
                # warm-state restore is lazy: adopt the snapshot's radix
                # KV pages the moment the matching engine first exists.
                # A payload that no longer fits (geometry drift) is simply
                # dropped — a cold prefix cache, never a failed query.
                pending = self._pending_radix.pop(key, None)
                if pending:
                    try:
                        self._jax_engines[key].restore_radix_state(pending)
                    except Exception:
                        pass
            return JaxExecutor(self._jax_engines[key])
        if path.startswith("custom:"):
            name = path.split(":", 1)[1]
            if name not in self._executor_factories:
                raise KeyError(f"custom executor {name!r} not registered")
            return self._executor_factories[name](entry)
        if path.endswith(".onnx") or path.startswith("tabular:"):
            name = path.split(":", 1)[1] if ":" in path else entry.name
            if name not in self._tabular_fns:
                raise KeyError(f"tabular model fn {name!r} not registered")
            return TabularExecutor(self._tabular_fns[name])
        raise ValueError(f"cannot resolve executor for PATH {path!r}")

    def _predict_factory(self, info: PredictInfo,
                         extra_options: Optional[Dict[str, object]] = None
                         ) -> PredictOperator:
        entry = self.catalog.model(info.model_name)
        # catalog metadata flows into the operator (API url, secret, options)
        merged = dict(info.options or {})
        merged.setdefault("base_api", entry.base_api)
        info = dataclasses.replace(info, options=merged)
        session_options = self.options if not extra_options \
            else {**self.options, **extra_options}
        return PredictOperator(info, self._resolve_executor(entry, info),
                               session_options,
                               prompt_cache=self.prompt_cache,
                               service=self.inference_service,
                               stats_store=self.stats_store)

    def _factory_with(self, extra: Dict[str, object]):
        """Bind per-query extra options (deadline anchor, session tags)
        into the operator factory.  Tests monkeypatch `_predict_factory`
        with single-argument wrappers, so only pass `extra` when the
        current factory accepts it — a one-arg factory just loses the
        shared anchor and operators fall back to construction time."""
        fn = self._predict_factory
        try:
            takes_extra = len(inspect.signature(fn).parameters) >= 2
        except (TypeError, ValueError):
            takes_extra = True
        if takes_extra:
            return lambda info: fn(info, extra)
        return fn

    def _resolve_executor(self, entry: ModelEntry,
                          info: PredictInfo) -> Predictor:
        """Executor for one predict node: the entry's backend, wrapped in a
        CascadePredictor when a cascade proxy is configured (session
        option < model OPTIONS < expression WITH precedence) and the
        optimizer did not route the node direct."""
        merged = {**self.options, **(info.options or {})}
        proxy_name = merged.get("cascade_proxy")
        if (proxy_name and bool(merged.get("enable_cascade", True))
                and str(merged.get("cascade_route", "cascade")) != "direct"
                and not info.agg):
            from repro.core.cascade import CascadePredictor
            from repro.core.stats import stats_key
            proxy_entry = self.catalog.model(str(proxy_name))
            return CascadePredictor(
                self._make_executor(proxy_entry),
                self._make_executor(entry),
                store=self.stats_store, key=stats_key(info),
                proxy_model=str(proxy_name),
                target_precision=float(
                    merged.get("cascade_target_precision", 0.9)),
                min_records=int(merged.get("cascade_min_records", 8)),
                audit_every=int(merged.get("cascade_audit_every", 16)),
                # the expensive stage gets its own breaker (distinct from
                # the dispatch-level one keyed by the cascade's model
                # name), so an expensive-backend outage degrades routed
                # batches to proxy-only instead of failing them
                breaker=self.inference_service.breaker_for(
                    f"{entry.name}#expensive"))
        return self._make_executor(entry)

    # -- entry point -------------------------------------------------------
    def sql(self, query: str, *, explain: bool = False) -> QueryResult:
        stmt = parse_sql(query)
        if isinstance(stmt, SetStmt):
            self.options[stmt.key] = stmt.value
            return QueryResult(None, ExecStats())
        if isinstance(stmt, CreateModel):
            self.catalog.register_model(ModelEntry(
                name=stmt.name, path=stmt.path, type=stmt.model_type,
                on_prompt=stmt.on_prompt, base_api=stmt.api,
                relation=stmt.relation, input_set=stmt.features,
                output_set=stmt.output, options=stmt.options))
            return QueryResult(None, ExecStats())
        if isinstance(stmt, CreateTableAs):
            res = self._run_select(stmt.select, explain)
            self.catalog.register_table(stmt.name, res.table)
            return res
        if isinstance(stmt, SelectStmt):
            return self._run_select(stmt, explain)
        raise TypeError(type(stmt))

    # -- streaming sessions (the front door's entry point) -----------------
    def stream(self, query: str, *, tenant: str = "",
               session: Optional[str] = None,
               cancel_scope: Optional[CancelScope] = None,
               explain: bool = False,
               deadline_ms: Optional[int] = None) -> "QueryStream":
        """Open one streaming query session: parse/bind/optimize now
        (serialized under a short lock), execute lazily — iterating
        `QueryStream.chunks()` drains the chunked physical pipeline and
        yields each result chunk as it is produced.  Every inference
        request the session submits is tagged (tenant, session), so
        dispatch batches are session-pure, per-session ExecStats are
        deterministic under concurrency, and `cancel_scope.cancel()`
        (client disconnect, DELETE /query/<id>) drops the session's
        still-queued requests within one flush.  Only SELECT statements
        stream; DDL/SET go through `sql()`."""
        t0 = time.time()
        stmt = parse_sql(query)
        if not isinstance(stmt, SelectStmt):
            raise ValueError("stream() supports SELECT statements only; "
                             f"got {type(stmt).__name__}")
        scope = cancel_scope if cancel_scope is not None else CancelScope()
        svc = self.inference_service
        with self._bind_lock:
            self._stream_seq += 1
            tag = session or f"q{self._stream_seq}"
            plan = Binder(self.catalog, self.options).bind_select(stmt)
            svc.max_dispatch = int(self.options.get("max_dispatch_calls", 0))
            svc.speculative = bool(self.options.get("speculative_flush",
                                                    True))
            svc.cost_model = CostModel(self.stats_store, self.options)
            self._stamp_resilience(svc)
            pilot = self._make_pilot()
            opt = Optimizer(self.catalog, self.options,
                            stats=self.stats_store, pilot=pilot)
            plan = opt.optimize(plan)
        # deadline anchoring: operators derive their own deadline_ts from
        # the precedence-resolved deadline_ms (session < OPTIONS < WITH)
        # against this shared monotonic query start, so every expression
        # in the query races the same wall deadline
        extra: Dict[str, object] = {"tenant": tenant, "session": tag,
                                    "query_start_ts": time.monotonic()}
        if deadline_ms is not None:
            extra["deadline_ms"] = int(deadline_ms)
        ex = PlanExecutor(self.catalog, self._factory_with(extra),
                          chunk_size=int(self.options.get("chunk_size",
                                                          2048)),
                          stats_store=self.stats_store, cancel_scope=scope)
        plan_text = (plan_repr(plan) + "\n-- physical --\n"
                     + ex.physical_plan(plan) + "\n-- dispatch --\n"
                     + self._dispatch_repr() + "\n-- stats --\n"
                     + self._stats_repr(plan) + "\n-- cascade --\n"
                     + self._cascade_repr(plan) + "\n-- resilience --\n"
                     + self._resilience_repr() + "\n-- rewrites --\n"
                     + rewrites_section(opt.rewrite_events)) \
            if explain else None
        return QueryStream(self, plan, ex, scope, tag, tenant, plan_text,
                           pilot, t0)

    def _stamp_resilience(self, svc: InferenceService) -> None:
        """Push the session's resilience options onto the service before a
        query runs (mirrors the max_dispatch/speculative stamping)."""
        svc.call_timeout_s = float(self.options.get("call_timeout_s", 0)
                                   or 0)
        svc.set_breaker_policy(
            int(self.options.get("breaker_threshold", 3)),
            int(self.options.get("breaker_probe_every", 4)))

    def _resilience_repr(self) -> str:
        from repro.core.faults import resilience_section
        return resilience_section(self.inference_service, self.options)

    def _dispatch_repr(self) -> str:
        o = self.options
        line = ("InferenceService inflight_windows={} batch_size={} "
                "n_threads={} rate_limit_rpm={} max_dispatch_calls={} "
                "use_dedup={} use_batching={} dispatch_workers={} "
                "speculative_flush={}".format(
                    o.get("inflight_windows", 1), o.get("batch_size", 16),
                    o.get("n_threads", 16), o.get("rate_limit_rpm", 0),
                    o.get("max_dispatch_calls", 0),
                    o.get("use_dedup", True), o.get("use_batching", True),
                    o.get("dispatch_workers", 1),
                    o.get("speculative_flush", True)))
        # serving-engine KV layout + session-cumulative prefix-reuse
        # counters, so prefix sharing is visible at the query layer.
        # Layouts come from the LIVE engines (a model can override the
        # session default per-entry); the option is the fallback before
        # any jax engine exists.
        hits = prefill = decoded = radix_toks = 0
        used = total = hwm = 0
        for eng in self._jax_engines.values():
            hits += eng.total.prefix_hits
            prefill += eng.total.prefill_tokens
            decoded += eng.total.output_tokens
            radix_toks += eng.total.radix_hit_tokens
            alloc = getattr(eng, "_alloc", None)
            if alloc is not None:
                used += alloc.resident_pages
                total += alloc.num_pages
                hwm += alloc.high_water
        layouts = sorted({k[1] for k in self._jax_engines}) \
            or [str(o.get("kv_layout", "dense"))]
        line += ("\nEngine kv_layout={} kv_page_size={} kv_quant={} "
                 "prefix_hits={} radix_hit_tokens={} prefill_tokens={} "
                 "decode_tokens={}".format(
                     ",".join(layouts), o.get("kv_page_size", 64),
                     o.get("kv_quant", "none"), hits, radix_toks,
                     prefill, decoded))
        line += "\npool: {}/{} pages, hwm={}".format(used, total, hwm)
        return line

    def _stats_repr(self, plan: Node) -> str:
        return stats_section(plan, self.stats_store,
                             CostModel(self.stats_store, self.options))

    def _cascade_repr(self, plan: Node) -> str:
        from repro.core.cascade import cascade_section
        return cascade_section(plan, self.stats_store, self.options)

    def _make_pilot(self) -> Optional[PilotSampler]:
        if not bool(self.options.get("enable_pilot", True)):
            return None
        min_rows = self.options.get("pilot_min_rows")
        return PilotSampler(
            self._predict_factory, self.stats_store,
            sample_rows=int(self.options.get("pilot_sample_rows", 16)),
            min_table_rows=None if min_rows is None else int(min_rows))

    def explain(self, query: str) -> str:
        stmt = parse_sql(query)
        assert isinstance(stmt, SelectStmt)
        plan = Binder(self.catalog, self.options).bind_select(stmt)
        # no pilot sampling from EXPLAIN: explaining must stay side-effect
        # free; estimates use whatever the store has already observed
        optimizer = Optimizer(self.catalog, self.options,
                              stats=self.stats_store)
        opt = optimizer.optimize(plan)
        ex = PlanExecutor(self.catalog, self._predict_factory,
                          chunk_size=int(self.options.get("chunk_size", 2048)))
        return ("-- logical --\n" + plan_repr(plan)
                + "\n-- optimized --\n" + plan_repr(opt)
                + "\n-- physical --\n" + ex.physical_plan(opt)
                + "\n-- dispatch --\n" + self._dispatch_repr()
                + "\n-- stats --\n" + self._stats_repr(opt)
                + "\n-- cascade --\n" + self._cascade_repr(opt)
                + "\n-- resilience --\n" + self._resilience_repr()
                + "\n-- rewrites --\n"
                + rewrites_section(optimizer.rewrite_events))

    def _run_select(self, stmt: SelectStmt, explain: bool) -> QueryResult:
        t0 = time.time()
        plan = Binder(self.catalog, self.options).bind_select(stmt)
        svc = self.inference_service
        # apply the dispatch configuration BEFORE optimizing: pilot
        # sampling inside optimize() dispatches through the service too
        svc.max_dispatch = int(self.options.get("max_dispatch_calls", 0))
        svc.speculative = bool(self.options.get("speculative_flush", True))
        # fresh cost model per query so SET option changes take effect;
        # drives the service's smallest-makespan-first flush ordering
        svc.cost_model = CostModel(self.stats_store, self.options)
        self._stamp_resilience(svc)
        pilot = self._make_pilot()
        opt = Optimizer(self.catalog, self.options, stats=self.stats_store,
                        pilot=pilot)
        plan = opt.optimize(plan)
        # one monotonic anchor per query: deadline_ms (from any precedence
        # level) counts down from here in every operator
        extra: Dict[str, object] = {"query_start_ts": time.monotonic()}
        ex = PlanExecutor(self.catalog, self._factory_with(extra),
                          chunk_size=int(self.options.get("chunk_size", 2048)),
                          stats_store=self.stats_store)
        plan_text = (plan_repr(plan) + "\n-- physical --\n"
                     + ex.physical_plan(plan) + "\n-- dispatch --\n"
                     + self._dispatch_repr() + "\n-- stats --\n"
                     + self._stats_repr(plan) + "\n-- cascade --\n"
                     + self._cascade_repr(plan)) if explain else None
        before = dataclasses.replace(svc.stats)
        table = ex.run(plan)
        if plan_text is not None:
            # the resilience + rewrites sections close the report AFTER
            # execution so they can include what actually happened (retries
            # taken, breakers tripped, mid-query re-ranks)
            plan_text += ("\n-- resilience --\n" + self._resilience_repr()
                          + "\n-- rewrites --\n" + rewrites_section(
                              opt.rewrite_events, ex.rerank_log))
        st = ex.stats
        st.dispatch_batches = svc.stats.dispatch_batches \
            - before.dispatch_batches
        calls = svc.stats.dispatched_calls - before.dispatched_calls
        st.mean_batch_occupancy = (calls / st.dispatch_batches
                                   if st.dispatch_batches else 0.0)
        st.inflight_dedup_hits = svc.stats.inflight_dedup_hits \
            - before.inflight_dedup_hits
        # service-side resilience counters (operator-side retry/drop/
        # degradation counts are already absorbed from the op stats)
        st.backend_timeouts = svc.stats.backend_timeouts \
            - before.backend_timeouts
        st.breaker_rejections = svc.stats.breaker_rejections \
            - before.breaker_rejections
        if pilot is not None and pilot.calls:
            # pilot work is part of the query's honest accounting: calls
            # are kept in their own counter, tokens/latency join the totals
            st.pilot_calls = pilot.calls
            st.in_tokens += pilot.in_tokens
            st.out_tokens += pilot.out_tokens
            st.sim_latency_s += pilot.sim_latency_s
        st.wall_s = time.time() - t0
        self.last_stats = st
        return QueryResult(table, st, plan_text)


class QueryStream:
    """One streaming query session (created by `IPDB.stream`).

    Iterate `chunks()` to drain the chunked physical pipeline; each yielded
    Table is one result chunk, produced as soon as the pipeline finishes
    it.  `stats` is populated when the stream ends (normally, by
    cancellation, or by abandoning the iterator) from the service's
    per-session counters — never from global deltas, so concurrent streams
    account exactly.  `cancel()` (or firing the scope from any thread)
    raises QueryCancelled at the executing thread's next chunk boundary
    AND immediately drops the session's still-queued service requests, so
    a cancelled stream stops consuming dispatch within one flush."""

    def __init__(self, db: IPDB, plan: Node, executor: PlanExecutor,
                 scope: CancelScope, session: str, tenant: str,
                 plan_text: Optional[str], pilot: Optional[PilotSampler],
                 t0: float):
        self.db = db
        self.scope = scope
        self.session = session
        self.tenant = tenant
        self.plan = plan_text
        self.stats: Optional[ExecStats] = None
        self.cancelled = False
        self._plan_node = plan
        self._ex = executor
        self._pilot = pilot
        self._t0 = t0
        self._finished = threading.Event()
        scope.add_callback(self._on_cancel)

    # runs on the CANCELLING thread (not the executing one): dropping the
    # queued requests here — instead of waiting for the executing thread
    # to notice — is what bounds cancellation to one flush
    def _on_cancel(self) -> None:
        svc = self.db.inference_service
        svc.cancel_session(self.session)
        if self._finished.is_set():
            # scope fired after the stream already finished and released
            # its tag; drop the tombstone cancel_session just re-created
            svc.release_session(self.session)

    def cancel(self, reason: str = "") -> bool:
        return self.scope.cancel(reason)

    def chunks(self) -> Iterator[Table]:
        gen = self._ex.run_chunks(self._plan_node)
        try:
            for chunk in gen:
                yield chunk
        except QueryCancelled:
            self.cancelled = True
        finally:
            gen.close()
            self._finish()

    def run(self) -> QueryResult:
        """Materialize the whole stream (tests / non-streaming callers)."""
        parts = list(self.chunks())
        table: Optional[Table] = None
        if parts:
            table = parts[0]
            for p in parts[1:]:
                table = table.concat(p)
        return QueryResult(table, self.stats, self.plan)

    def _finish(self) -> None:
        if self._finished.is_set():
            return
        svc = self.db.inference_service
        st = self._ex.stats
        sess = svc.session_stats(self.session)
        if sess is not None:
            st.dispatch_batches = sess.dispatch_batches
            st.mean_batch_occupancy = (
                sess.dispatched_calls / sess.dispatch_batches
                if sess.dispatch_batches else 0.0)
            st.inflight_dedup_hits = sess.inflight_dedup_hits
            st.cancelled_requests = sess.cancelled_requests
            st.backend_timeouts = sess.backend_timeouts
            st.breaker_rejections = sess.breaker_rejections
        st.cancelled = self.cancelled
        if self._pilot is not None and self._pilot.calls:
            st.pilot_calls = self._pilot.calls
            st.in_tokens += self._pilot.in_tokens
            st.out_tokens += self._pilot.out_tokens
            st.sim_latency_s += self._pilot.sim_latency_s
        st.wall_s = time.time() - self._t0
        self.stats = st
        self.db.last_stats = st
        self._finished.set()
        svc.release_session(self.session)
