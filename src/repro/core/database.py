"""iPDB — the public database API.

    db = IPDB()
    db.register_table("Product", table)
    db.sql("CREATE LLM MODEL o4mini PATH 'oracle:pcparts' ON PROMPT API '...'")
    out = db.sql("SELECT name FROM Product WHERE LLM o4mini (PROMPT '...')")

Executor resolution by model PATH scheme:
    oracle:<name>   → OracleExecutor using a registered oracle fn
    jax:<arch>      → JaxExecutor on an in-process InferenceEngine
                      (smoke-size config of the named architecture)
    *.onnx / tabular:<name> → TabularExecutor via a registered predict fn
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

from repro.core.executors import (JaxExecutor, OracleExecutor, Predictor,
                                  TabularExecutor)
from repro.core.optimizer import DEFAULT_FLAGS, Optimizer
from repro.core.predict import PredictOperator, PromptCache
from repro.core.service import InferenceService
from repro.relational.binder import Binder
from repro.relational.catalog import Catalog, ModelEntry
from repro.relational.executor import ExecStats, PlanExecutor
from repro.relational.parser import (CreateModel, CreateTableAs, SelectStmt,
                                     SetStmt, parse_sql)
from repro.relational.plan import Node, PredictInfo, plan_repr
from repro.relational.table import Table


@dataclasses.dataclass
class QueryResult:
    table: Optional[Table]
    stats: ExecStats
    plan: Optional[str] = None


class IPDB:
    def __init__(self, *, session_options: Optional[Dict[str, object]] = None):
        self.catalog = Catalog()
        self.options: Dict[str, object] = {
            "batch_size": 16, "n_threads": 16, "use_batching": True,
            "use_dedup": True, "rate_limit_rpm": 0.0,
            "inflight_windows": 1, "max_dispatch_calls": 0,
            **DEFAULT_FLAGS,
        }
        if session_options:
            self.options.update(session_options)
        self._oracles: Dict[str, Callable] = {}
        self._tabular_fns: Dict[str, Callable] = {}
        self._jax_engines: Dict[str, object] = {}
        self._oracle_kwargs: Dict[str, dict] = {}
        self.last_stats: Optional[ExecStats] = None
        # cross-query prompt cache: shared by every predict operator this
        # database creates (keyed by model + instruction + input tuple)
        self.prompt_cache = PromptCache()
        # one inference service per session: every predict operator routes
        # its dispatch through it (batching, in-flight dedup, scheduling)
        self.inference_service = InferenceService()

    # -- registration ---------------------------------------------------
    def register_table(self, name: str, t: Table) -> None:
        self.catalog.register_table(name, t)

    def register_oracle(self, name: str, fn: Callable, **kwargs) -> None:
        """Oracle executors for accuracy-bearing benchmarks:
        fn(instruction, rows) -> list of output dicts."""
        self._oracles[name] = fn
        self._oracle_kwargs[name] = kwargs

    def register_tabular(self, name: str, fn: Callable) -> None:
        self._tabular_fns[name] = fn

    def set_option(self, key: str, value) -> None:
        self.options[key] = value

    # -- executor resolution ---------------------------------------------
    def _make_executor(self, entry: ModelEntry) -> Predictor:
        path = entry.path
        if path.startswith("oracle:"):
            name = path.split(":", 1)[1]
            if name not in self._oracles:
                raise KeyError(f"oracle {name!r} not registered")
            return OracleExecutor(self._oracles[name],
                                  **self._oracle_kwargs.get(name, {}))
        if path.startswith("jax:"):
            arch = path.split(":", 1)[1]
            if arch not in self._jax_engines:
                import repro.configs as C
                from repro.serving.engine import InferenceEngine
                cfg = C.get_smoke_config(arch).replace(vocab_size=259)
                self._jax_engines[arch] = InferenceEngine(
                    cfg, max_len=int(entry.options.get("max_len", 512)))
            return JaxExecutor(self._jax_engines[arch])
        if path.endswith(".onnx") or path.startswith("tabular:"):
            name = path.split(":", 1)[1] if ":" in path else entry.name
            if name not in self._tabular_fns:
                raise KeyError(f"tabular model fn {name!r} not registered")
            return TabularExecutor(self._tabular_fns[name])
        raise ValueError(f"cannot resolve executor for PATH {path!r}")

    def _predict_factory(self, info: PredictInfo) -> PredictOperator:
        entry = self.catalog.model(info.model_name)
        # catalog metadata flows into the operator (API url, secret, options)
        merged = dict(info.options or {})
        merged.setdefault("base_api", entry.base_api)
        info = dataclasses.replace(info, options=merged)
        return PredictOperator(info, self._make_executor(entry), self.options,
                               prompt_cache=self.prompt_cache,
                               service=self.inference_service)

    # -- entry point -------------------------------------------------------
    def sql(self, query: str, *, explain: bool = False) -> QueryResult:
        stmt = parse_sql(query)
        if isinstance(stmt, SetStmt):
            self.options[stmt.key] = stmt.value
            return QueryResult(None, ExecStats())
        if isinstance(stmt, CreateModel):
            self.catalog.register_model(ModelEntry(
                name=stmt.name, path=stmt.path, type=stmt.model_type,
                on_prompt=stmt.on_prompt, base_api=stmt.api,
                relation=stmt.relation, input_set=stmt.features,
                output_set=stmt.output, options=stmt.options))
            return QueryResult(None, ExecStats())
        if isinstance(stmt, CreateTableAs):
            res = self._run_select(stmt.select, explain)
            self.catalog.register_table(stmt.name, res.table)
            return res
        if isinstance(stmt, SelectStmt):
            return self._run_select(stmt, explain)
        raise TypeError(type(stmt))

    def _dispatch_repr(self) -> str:
        o = self.options
        return ("InferenceService inflight_windows={} batch_size={} "
                "n_threads={} rate_limit_rpm={} max_dispatch_calls={} "
                "use_dedup={} use_batching={}".format(
                    o.get("inflight_windows", 1), o.get("batch_size", 16),
                    o.get("n_threads", 16), o.get("rate_limit_rpm", 0),
                    o.get("max_dispatch_calls", 0),
                    o.get("use_dedup", True), o.get("use_batching", True)))

    def explain(self, query: str) -> str:
        stmt = parse_sql(query)
        assert isinstance(stmt, SelectStmt)
        plan = Binder(self.catalog, self.options).bind_select(stmt)
        opt = Optimizer(self.catalog, self.options).optimize(plan)
        ex = PlanExecutor(self.catalog, self._predict_factory,
                          chunk_size=int(self.options.get("chunk_size", 2048)))
        return ("-- logical --\n" + plan_repr(plan)
                + "\n-- optimized --\n" + plan_repr(opt)
                + "\n-- physical --\n" + ex.physical_plan(opt)
                + "\n-- dispatch --\n" + self._dispatch_repr())

    def _run_select(self, stmt: SelectStmt, explain: bool) -> QueryResult:
        t0 = time.time()
        plan = Binder(self.catalog, self.options).bind_select(stmt)
        plan = Optimizer(self.catalog, self.options).optimize(plan)
        ex = PlanExecutor(self.catalog, self._predict_factory,
                          chunk_size=int(self.options.get("chunk_size", 2048)))
        plan_text = (plan_repr(plan) + "\n-- physical --\n"
                     + ex.physical_plan(plan) + "\n-- dispatch --\n"
                     + self._dispatch_repr()) if explain else None
        svc = self.inference_service
        svc.max_dispatch = int(self.options.get("max_dispatch_calls", 0))
        before = dataclasses.replace(svc.stats)
        table = ex.run(plan)
        st = ex.stats
        st.dispatch_batches = svc.stats.dispatch_batches \
            - before.dispatch_batches
        calls = svc.stats.dispatched_calls - before.dispatched_calls
        st.mean_batch_occupancy = (calls / st.dispatch_batches
                                   if st.dispatch_batches else 0.0)
        st.inflight_dedup_hits = svc.stats.inflight_dedup_hits \
            - before.inflight_dedup_hits
        st.wall_s = time.time() - t0
        self.last_stats = st
        return QueryResult(table, st, plan_text)
