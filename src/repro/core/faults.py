"""Fault model, circuit breakers, and the deterministic chaos harness.

Three pieces live here, shared by the service, the predict operators,
the cascade, and the front door:

* An **error taxonomy** splitting retryable transport-level failures
  (``TransientError`` and subclasses) from non-retryable ones.  The
  ``InferenceService`` records transient-class errors on the affected
  handles instead of re-raising them out of ``flush``/``drain_for``, so
  one backend's hiccup cannot crash an unrelated operator's resolve.
* A per-backend **``CircuitBreaker``** (closed / open / half-open).
  Probe scheduling is *count-based*, not wall-clock-based: while open,
  every ``probe_every``-th attempted call is let through as a half-open
  probe.  This keeps breaker behavior deterministic under the scripted
  test harness (no sleeps, no clocks) while preserving the production
  semantics: a hung or dead backend is load-shed after
  ``failure_threshold`` consecutive failures and re-checked at a bounded
  rate.
* A seeded **``FaultInjector``** predictor wrapper.  Every injection
  decision is a pure function of ``(seed, prompt, occurrence)`` — the
  n-th time a given prompt is attempted it always gets the same fate,
  regardless of batch composition or dispatch-worker count.  Transient
  faults fire only on a prompt's *first* occurrence, so a retried call
  deterministically succeeds and chaos runs stay byte-identical to
  fault-free runs.
"""
from __future__ import annotations

import hashlib
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from .executors import CallResult, Predictor


# ---------------------------------------------------------------------------
# Error taxonomy
# ---------------------------------------------------------------------------

class TransientError(RuntimeError):
    """Retryable transport/backend failure (timeout, 5xx, breaker)."""


class TransientBackendError(TransientError):
    """Injected or real transient backend exception (a 5xx analogue)."""


class BackendTimeout(TransientError):
    """A dispatch lane's per-call timeout expired; the call is a zombie."""


class CircuitOpenError(TransientError):
    """The backend's circuit breaker is open; the call was load-shed."""


class DeadlineExceeded(RuntimeError):
    """The query's end-to-end deadline expired; work dropped, not retried."""


def is_transient(exc: BaseException) -> bool:
    return isinstance(exc, TransientError)


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------

CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


class CircuitBreaker:
    """Per-backend breaker with deterministic count-based probing.

    State machine:

    * **closed** — calls pass.  ``failure_threshold`` *consecutive*
      failures trip the breaker to **open**.
    * **open** — calls are rejected with ``CircuitOpenError``; every
      ``probe_every``-th attempt instead passes as a **half-open** probe.
    * **half-open** — exactly one in-flight probe.  Success closes the
      breaker; failure re-opens it (resetting the probe countdown).

    All transitions are driven by call outcomes, never wall-clock time,
    so tests and replays see identical breaker histories.
    """

    def __init__(self, name: str, *, failure_threshold: int = 3,
                 probe_every: int = 4) -> None:
        self.name = name
        self.failure_threshold = max(1, int(failure_threshold))
        self.probe_every = max(1, int(probe_every))
        self.state = CLOSED
        self.consecutive_failures = 0
        self._rejected_since_probe = 0
        self._probe_inflight = False
        self.failures = 0
        self.successes = 0
        self.rejections = 0
        self.opens = 0
        self.probes = 0
        self._lock = threading.Lock()

    def allow(self) -> bool:
        """Admission check; counts a rejection when returning False."""
        with self._lock:
            if self.state == CLOSED:
                return True
            if self.state == HALF_OPEN:
                # one probe at a time; everyone else is shed
                self.rejections += 1
                return False
            # open: let every probe_every-th attempt through as a probe
            self._rejected_since_probe += 1
            if (not self._probe_inflight
                    and self._rejected_since_probe >= self.probe_every):
                self.state = HALF_OPEN
                self._probe_inflight = True
                self._rejected_since_probe = 0
                self.probes += 1
                return True
            self.rejections += 1
            return False

    def record_success(self) -> None:
        with self._lock:
            self.successes += 1
            self.consecutive_failures = 0
            if self.state in (HALF_OPEN, OPEN):
                self.state = CLOSED
            self._probe_inflight = False

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            self.consecutive_failures += 1
            if self.state == HALF_OPEN:
                self.state = OPEN
                self._probe_inflight = False
                self._rejected_since_probe = 0
            elif (self.state == CLOSED
                    and self.consecutive_failures >= self.failure_threshold):
                self.state = OPEN
                self.opens += 1
                self._rejected_since_probe = 0

    def snapshot(self) -> Dict[str, object]:
        with self._lock:
            return {
                "state": self.state,
                "failures": self.failures,
                "successes": self.successes,
                "rejections": self.rejections,
                "opens": self.opens,
                "probes": self.probes,
            }


# ---------------------------------------------------------------------------
# Fault injector
# ---------------------------------------------------------------------------

def _decide(seed: int, prompt: str, occurrence: int, salt: str) -> float:
    """Deterministic uniform [0,1) from (seed, prompt, occurrence, salt)."""
    h = hashlib.sha256(
        f"{seed}:{salt}:{occurrence}:{prompt}".encode()).digest()
    return int.from_bytes(h[:8], "big") / float(1 << 64)


class FaultInjector(Predictor):
    """Deterministic chaos wrapper around any ``Predictor``.

    Fault classes (rates are independent probabilities per first-occurrence
    call; retries of the same prompt are deterministic successes):

    * ``transient_rate`` — raise ``TransientBackendError`` for the batch.
    * ``malform_rate``   — truncate the returned text mid-JSON.
    * ``latency_rate``   — multiply simulated latency by ``latency_spike``.
    * ``hang_s``         — with ``hang_rate``, block the call for up to
      ``hang_s`` wall seconds (releasable via :meth:`release_hangs` so
      tests never actually sleep that long).
    * ``outage`` — a ``(first_call, last_call)`` global call-index window
      during which *every* call raises ``TransientBackendError``
      irrespective of per-prompt decisions (a full-backend outage).

    The wrapper is registered like any custom predictor and is fully
    transparent when all rates are zero.
    """

    def __init__(self, inner: Predictor, *, seed: int = 0,
                 transient_rate: float = 0.0, malform_rate: float = 0.0,
                 latency_rate: float = 0.0, latency_spike: float = 8.0,
                 hang_rate: float = 0.0, hang_s: float = 30.0,
                 outage: Optional[Tuple[int, int]] = None) -> None:
        self.inner = inner
        self.seed = int(seed)
        self.transient_rate = float(transient_rate)
        self.malform_rate = float(malform_rate)
        self.latency_rate = float(latency_rate)
        self.latency_spike = float(latency_spike)
        self.hang_rate = float(hang_rate)
        self.hang_s = float(hang_s)
        self.outage = outage
        self.name = getattr(inner, "name", "faulty")
        self.options = getattr(inner, "options", {})
        self.max_concurrency = getattr(inner, "max_concurrency", 1)
        self._lock = threading.Lock()
        self._occurrence: Dict[str, int] = {}
        self._calls = 0
        self._hang_events: List[threading.Event] = []
        self.counters: Dict[str, int] = {
            "calls": 0, "transient": 0, "malformed": 0,
            "latency_spikes": 0, "hangs": 0, "outage_rejects": 0,
        }

    # -- Predictor plumbing delegates to the wrapped backend ------------
    def configure(self, options) -> None:
        self.inner.configure(options)
        self.options = getattr(self.inner, "options", options)

    def load(self) -> None:
        self.inner.load()

    def dispatch_workers(self) -> int:
        return self.inner.dispatch_workers()

    @property
    def stats_stage(self) -> str:
        return getattr(self.inner, "stats_stage", "")

    # -- chaos controls --------------------------------------------------
    def release_hangs(self) -> None:
        """Unblock every in-flight injected hang immediately."""
        with self._lock:
            evs, self._hang_events = self._hang_events, []
        for ev in evs:
            ev.set()

    def _occ(self, prompt: str) -> int:
        with self._lock:
            n = self._occurrence.get(prompt, 0)
            self._occurrence[prompt] = n + 1
            return n

    def _tick(self, n: int = 1) -> int:
        with self._lock:
            first = self._calls
            self._calls += n
            self.counters["calls"] += n
            return first

    def _bump(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] += n

    def _maybe_hang(self, prompt: str, occ: int) -> None:
        if self.hang_rate <= 0.0:
            return
        if _decide(self.seed, prompt, occ, "hang") < self.hang_rate:
            ev = threading.Event()
            with self._lock:
                self._hang_events.append(ev)
            self._bump("hangs")
            ev.wait(self.hang_s)

    def _mangle(self, res: CallResult, prompt: str, occ: int) -> CallResult:
        if (self.malform_rate > 0.0 and occ == 0
                and _decide(self.seed, prompt, occ, "malform")
                < self.malform_rate):
            self._bump("malformed")
            res.text = res.text[: max(1, len(res.text) // 2)].rstrip("}] \n")
        if (self.latency_rate > 0.0
                and _decide(self.seed, prompt, occ, "latency")
                < self.latency_rate):
            self._bump("latency_spikes")
            res.sim_latency_s *= self.latency_spike
        return res

    # -- the wrapped call ------------------------------------------------
    def complete(self, prompt, schema, num_rows, *, shared_prefix="",
                 rows=None, instruction=""):
        return self.complete_many(
            [prompt], schema, [num_rows], shared_prefix=shared_prefix,
            rows_list=[rows], instruction=instruction)[0]

    def complete_many(self, prompts, schema, num_rows_list, *,
                      shared_prefix="", rows_list=None, instruction=""):
        first = self._tick(len(prompts))
        if self.outage is not None:
            lo, hi = self.outage
            if any(lo <= first + i <= hi for i in range(len(prompts))):
                self._bump("outage_rejects", len(prompts))
                raise TransientBackendError(
                    f"{self.name}: injected outage window {self.outage}")
        occs = [self._occ(p) for p in prompts]
        for p, occ in zip(prompts, occs):
            if (self.transient_rate > 0.0 and occ == 0
                    and _decide(self.seed, p, occ, "transient")
                    < self.transient_rate):
                self._bump("transient")
                raise TransientBackendError(
                    f"{self.name}: injected transient failure")
            self._maybe_hang(p, occ)
        out = self.inner.complete_many(
            list(prompts), schema, list(num_rows_list),
            shared_prefix=shared_prefix, rows_list=rows_list,
            instruction=instruction)
        return [self._mangle(r, p, occ)
                for r, p, occ in zip(out, prompts, occs)]


# ---------------------------------------------------------------------------
# EXPLAIN helper
# ---------------------------------------------------------------------------

def resilience_section(service, options) -> str:
    """Render the body of the ``-- resilience --`` EXPLAIN section (the
    database adds the section header, like every other section)."""
    lines = []
    st = service.stats
    lines.append(
        "retries transient={t} deadline_drops={d} timeouts={o} "
        "degraded_calls={g}".format(
            t=st.transient_retries, d=st.deadline_drops,
            o=st.backend_timeouts, g=st.degraded_calls))
    brk = service.breaker_snapshots()
    if not brk:
        lines.append("breakers: none tripped")
    for name in sorted(brk):
        b = brk[name]
        lines.append(
            "breaker {n}: state={s} failures={f} rejections={r} "
            "opens={o} probes={p}".format(
                n=name, s=b["state"], f=b["failures"], r=b["rejections"],
                o=b["opens"], p=b["probes"]))
    lines.append(
        "policy: call_timeout_s={ct} retry_backoff_s={rb} "
        "breaker_threshold={bt} breaker_probe_every={pe}".format(
            ct=options.get("call_timeout_s", 0),
            rb=options.get("retry_backoff_s", 0),
            bt=options.get("breaker_threshold", 3),
            pe=options.get("breaker_probe_every", 4)))
    return "\n".join(lines)
