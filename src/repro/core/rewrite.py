"""Learned rewrite-pattern engine over logical plans.

querytorque-style loop brought in-process (ROADMAP "learned rewrite
engine"): a small registry of rewrite PATTERNS, an AST scanner that
detects where each applies, stats-store-driven benefit estimates through
the shared CostModel, and a validation gate that only lets a pattern fire
when its legality conditions hold on the rewritten plan.

Rules (applied in order, each to fixpoint):

  subsume_implied_select           two semantic selects whose predicts are
                                   signature-identical and whose predicates
                                   satisfy A => B: the weaker (implied)
                                   unit is redundant — drop its Filter and,
                                   when unreferenced elsewhere, its Predict.
  consolidate_duplicate_predicts   a Predict whose (model, prompt, inputs,
                                   outputs, answer-shaping options) signature
                                   duplicates one further down its input
                                   chain is replaced by a passthrough
                                   Project aliasing the earlier outputs —
                                   one inference pass instead of two.
  push_semantic_select_through_join  a semantic select above a join whose
                                   inputs come from one side runs below the
                                   join when the side's distinct input
                                   count beats the deduplicated above-join
                                   count (delegates the distinct-count
                                   machinery to the optimizer context).

Legality rests on referential transparency of signature-identical semantic
expressions — the same assumption the cross-query PromptCache and the
service's in-flight dedup already bake in: same (model, instruction,
answer-shaping options, input row) => same answer.

Every match is recorded as a RewriteEvent (fired / rejected / kept), the
raw material of EXPLAIN's `-- rewrites --` section.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import List, Optional, Set, Tuple

from repro.relational.expr import (BinOp, Col, Expr, Lit, Not, PredictExpr,
                                   find_predicts)
from repro.relational.plan import (Filter, GroupBy, Join, Limit, Node,
                                   OrderBy, Predict, Project, SemanticJoin,
                                   walk_plan)

__all__ = ["RewriteEvent", "RewriteEngine", "predict_signature",
           "predicate_implies", "rewrites_section"]

#: options that change the *answer* of a semantic call, with their
#: defaults — mirrors the PromptCache namespace in `core.predict`.  Two
#: PredictInfos are duplicates only when these agree (dispatch-shaping
#: options like batch_size deliberately stay out).
_ANSWER_OPTS = (("n_samples", 1), ("temperature", 0.7),
                ("max_tokens", 4096), ("max_str", 24), ("gen_rows", 4))


def predict_signature(info) -> Tuple:
    """Answer-identity signature of a PredictInfo: two nodes with equal
    signatures compute the same values for the same input rows."""
    opts = info.options or {}
    shaping = tuple((k, repr(opts.get(k, d))) for k, d in _ANSWER_OPTS
                    if opts.get(k, d) != d)
    return (info.model_name,
            info.prompt.raw if info.prompt else None,
            tuple(info.inputs),
            tuple((n, t) for n, t in info.outputs),
            bool(info.agg), shaping)


@dataclasses.dataclass
class RewriteEvent:
    rule: str
    site: str
    action: str        # fired | rejected | kept
    detail: str        # why / estimated benefit


# ---------------------------------------------------------------------------
# plan / expression helpers
# ---------------------------------------------------------------------------
def _rebuild_replace(n: Node, target: Node, repl: Node) -> Node:
    """Rebuild `n` with the node instance `target` replaced by `repl`."""
    if n is target:
        return repl
    kw = {}
    changed = False
    for f in dataclasses.fields(n):
        v = getattr(n, f.name)
        if isinstance(v, Node):
            nv = _rebuild_replace(v, target, repl)
            changed |= nv is not v
            kw[f.name] = nv
        else:
            kw[f.name] = v
    if not changed:
        return n
    out = type(n)(**kw)
    if isinstance(n, GroupBy):
        out.llm_agg_infos = getattr(n, "llm_agg_infos", {})
    return out


def _expr_cols(e: Expr) -> Set[str]:
    return set(e.columns()) | {p.resolved_col for p in find_predicts(e)
                               if p.resolved_col}


def _referenced_cols(plan: Node, exclude: Tuple[Node, ...] = ()) -> Set[str]:
    """Every column name any node in `plan` consumes (filters, projections,
    sort/group/join keys, predict inputs), skipping the `exclude` node
    instances."""
    skip = {id(x) for x in exclude}
    cols: Set[str] = set()
    for x in walk_plan(plan):
        if id(x) in skip:
            continue
        if isinstance(x, Filter):
            cols |= _expr_cols(x.predicate)
        elif isinstance(x, Project):
            for _, e in x.exprs:
                cols |= _expr_cols(e)
        elif isinstance(x, OrderBy):
            for e, _ in x.keys:
                cols |= _expr_cols(e)
        elif isinstance(x, Join):
            cols |= set(x.left_keys) | set(x.right_keys)
            if x.extra is not None:
                cols |= _expr_cols(x.extra)
        elif isinstance(x, GroupBy):
            cols |= set(x.keys)
            for _, _, arg in x.aggs:
                if arg is not None:
                    cols |= _expr_cols(arg)
            for info in getattr(x, "llm_agg_infos", {}).values():
                cols |= set(info.inputs)
        elif isinstance(x, Predict):
            cols |= set(x.info.inputs)
        elif isinstance(x, SemanticJoin):
            cols |= set(x.info.inputs)
    return cols


# -- predicate normalization + implication ----------------------------------
_FLIP = {"<": ">", ">": "<", "<=": ">=", ">=": "<=", "=": "=", "!=": "!="}
_CMP_OPS = {"=", "!=", "<", ">", "<=", ">="}


def _normalize_pred(pred: Expr, out_cols: Set[str]
                    ) -> Optional[Tuple[str, str, object]]:
    """(col, op, literal) for predicates of shape <out col> <cmp> <literal>
    over one of `out_cols`; bare boolean references normalize to (=, True)
    and their negation to (=, False).  None for anything more complex."""
    def as_col(e: Expr) -> Optional[str]:
        if isinstance(e, Col) and e.name in out_cols:
            return e.name
        if isinstance(e, PredictExpr) and e.resolved_col in out_cols:
            return e.resolved_col
        return None

    if isinstance(pred, BinOp) and pred.op in _CMP_OPS:
        c = as_col(pred.left)
        if c is not None and isinstance(pred.right, Lit):
            return (c, pred.op, pred.right.value)
        c = as_col(pred.right)
        if c is not None and isinstance(pred.left, Lit):
            return (c, _FLIP[pred.op], pred.left.value)
        return None
    c = as_col(pred)
    if c is not None:
        return (c, "=", True)
    if isinstance(pred, Not):
        c = as_col(pred.child)
        if c is not None:
            return (c, "=", False)
    return None


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _value_sat(v, op: str, lit) -> bool:
    """Does the single value `v` satisfy `x op lit`?"""
    try:
        if op == "=":
            return bool(v == lit)
        if op == "!=":
            return bool(v != lit)
        if not (_is_num(v) and _is_num(lit)):
            return False
        return {"<": v < lit, ">": v > lit,
                "<=": v <= lit, ">=": v >= lit}[op]
    except TypeError:
        return False


def predicate_implies(op_a: str, va, op_b: str, vb) -> bool:
    """True when `x op_a va` implies `x op_b vb` for every non-NULL x
    (NULL rows fail both sides under the engine's comparison semantics).
    Interval containment on numeric literals; equality on anything."""
    if op_a == "=":
        return _value_sat(va, op_b, vb)
    if op_a == "!=":
        return op_b == "!=" and type(va) is type(vb) and va == vb
    if not (_is_num(va) and _is_num(vb)):
        return False
    strict = op_a in ("<", ">")
    if op_a in (">", ">="):
        if op_b == ">":
            return va > vb or (strict and va >= vb)
        if op_b == ">=":
            return va >= vb
        if op_b == "!=":
            return va > vb or (strict and va >= vb)
        return False
    if op_a in ("<", "<="):
        if op_b == "<":
            return va < vb or (strict and va <= vb)
        if op_b == "<=":
            return va <= vb
        if op_b == "!=":
            return va < vb or (strict and va <= vb)
        return False
    return False


# ---------------------------------------------------------------------------
class RewriteEngine:
    """Pattern registry + scanner + validation gate over one logical plan.

    `ctx` is the owning Optimizer (duck-typed): the join rule borrows its
    distinct-count statistics and placement costing, and reads its rule
    flags so ablation switches keep working through the engine."""

    MAX_PASSES = 8

    def __init__(self, catalog, cost_model, ctx=None):
        self.cat = catalog
        self.cost = cost_model
        self.ctx = ctx
        self.events: List[RewriteEvent] = []
        self._noted: Set[Tuple[str, str]] = set()

    # -- registry ---------------------------------------------------------
    def _rules(self):
        return (
            ("subsume_implied_select", self._subsume_implied, True),
            ("consolidate_duplicate_predicts", self._consolidate, True),
            ("push_semantic_select_through_join", self._push_through_join,
             False),
        )

    # -- driver -----------------------------------------------------------
    def rewrite(self, plan: Node) -> Node:
        for name, rule, order_sensitive in self._rules():
            for _ in range(self.MAX_PASSES):
                cand = rule(plan)
                if cand is None:
                    break
                new_plan, site, detail = cand
                ok, why = self._validate(plan, new_plan, order_sensitive)
                if ok:
                    self.events.append(
                        RewriteEvent(name, site, "fired", detail))
                    plan = new_plan
                else:
                    self.events.append(
                        RewriteEvent(name, site, "rejected", why))
                    break
        return plan

    def scan(self, plan: Node) -> List[Tuple[str, str, str]]:
        """Detection only: (rule, site, detail) for every pattern that
        currently applies, without rewriting anything."""
        out = []
        for name, rule, _ in self._rules():
            cand = rule(plan)
            if cand is not None:
                out.append((name, cand[1], cand[2]))
        return out

    # -- validation gate --------------------------------------------------
    def _validate(self, old: Node, new: Node,
                  order_sensitive: bool) -> Tuple[bool, str]:
        try:
            so, sn = old.schema(self.cat), new.schema(self.cat)
        except Exception:
            return False, "schema computation failed on rewritten plan"
        if order_sensitive and list(so.items()) != list(sn.items()):
            return False, "output schema changed"
        if not order_sensitive and dict(so) != dict(sn):
            return False, "output schema changed"
        def sigs(p):
            return Counter(predict_signature(x.info) for x in walk_plan(p)
                           if isinstance(x, (Predict, SemanticJoin)))
        if sigs(new) - sigs(old):
            return False, "rewrite introduced new semantic work"
        return True, ""

    def _note(self, rule: str, site: str, detail: str) -> None:
        """Record a matched-but-not-fired pattern once per site."""
        if (rule, site) not in self._noted:
            self._noted.add((rule, site))
            self.events.append(RewriteEvent(rule, site, "kept", detail))

    def _est_rows(self, n: Node) -> float:
        try:
            return float(n.est_rows(self.cat))
        except Exception:
            return 32.0

    # -- rule: duplicate-subexpression consolidation -----------------------
    def _dup_below(self, upper: Predict) -> Optional[Predict]:
        """A signature-identical Predict on `upper`'s input chain whose
        outputs are still row-aligned with (and visible at) `upper`'s
        position: the chain may only pass through Filter / OrderBy / Limit
        (row subsets, never value changes) and other Predicts that do not
        overwrite `upper`'s input columns."""
        sig = predict_signature(upper.info)
        inputs = set(upper.info.inputs)
        cur = upper.child
        while cur is not None:
            if isinstance(cur, Predict):
                if cur.child is None:
                    return None
                if predict_signature(cur.info) == sig:
                    return cur
                if set(cur.info.out_cols) & inputs:
                    return None
                cur = cur.child
            elif isinstance(cur, (Filter, OrderBy, Limit)):
                cur = cur.child
            else:
                return None
        return None

    def _consolidate(self, plan: Node):
        for upper in walk_plan(plan):
            if not (isinstance(upper, Predict) and upper.child is not None
                    and not upper.info.agg):
                continue
            lower = self._dup_below(upper)
            if lower is None:
                continue
            try:
                child_schema = list(upper.child.schema(self.cat))
            except Exception:
                continue
            if any(c in child_schema for c in upper.info.out_cols):
                continue
            exprs = [(c, Col(c)) for c in child_schema]
            exprs += [(uc, Col(lc)) for uc, lc
                      in zip(upper.info.out_cols, lower.info.out_cols)]
            repl = Project(upper.child, exprs)
            rows = self._est_rows(upper.child)
            est = self.cost.estimate(upper.info, rows)
            site = (f"Predict[{upper.info.model_name}] "
                    f"out={upper.info.out_cols}")
            detail = (f"duplicate of out={lower.info.out_cols}; aliases "
                      f"shared answers, saves ~{est.expected_calls:.0f} "
                      f"calls over ~{rows:.0f} rows")
            return _rebuild_replace(plan, upper, repl), site, detail
        return None

    # -- rule: predicate implication / subsumption -------------------------
    def _subsume_implied(self, plan: Node):
        for head in walk_plan(plan):
            if not isinstance(head, Filter):
                continue
            # linear Filter/Predict region below (and including) `head`
            chain: List[Node] = []
            cur: Optional[Node] = head
            while isinstance(cur, (Filter, Predict)):
                if isinstance(cur, Predict) and cur.child is None:
                    break
                chain.append(cur)
                cur = cur.child
            base = cur
            if base is None or len(chain) < 3:
                continue
            cand = self._find_subsumption(plan, chain)
            if cand is None:
                continue
            drop, site, detail = cand
            dropped = {id(x) for x in drop}
            new_chain: Node = base
            for node in reversed(chain):
                if id(node) in dropped:
                    continue
                if isinstance(node, Filter):
                    new_chain = Filter(new_chain, node.predicate,
                                       node.selectivity)
                else:
                    new_chain = Predict(new_chain, node.info)
            return _rebuild_replace(plan, head, new_chain), site, detail
        return None

    def _find_subsumption(self, plan: Node, chain: List[Node]):
        """One (dropped nodes, site, detail) candidate in a linear region,
        or None.  A filter B is subsumed when some filter A in the region
        normalizes over a signature-identical predict at the same output
        position and A's predicate implies B's."""
        predicts = [x for x in chain if isinstance(x, Predict)]
        normed = []            # (filter, predict, out_idx, op, lit)
        for f in chain:
            if not isinstance(f, Filter):
                continue
            for p in predicts:
                norm = _normalize_pred(f.predicate, set(p.info.out_cols))
                if norm is not None:
                    col, op, lit = norm
                    normed.append((f, p, p.info.out_cols.index(col), op,
                                   lit))
                    break
        for fa, pa, ia, opa, va in normed:
            for fb, pb, ib, opb, vb in normed:
                if fb is fa or ia != ib:
                    continue
                if predict_signature(pa.info) != predict_signature(pb.info):
                    continue
                if not predicate_implies(opa, va, opb, vb):
                    continue
                drop: List[Node] = [fb]
                if pb is not pa:
                    # dropping the predict too: its outputs must be dead
                    # outside fb, and every predict executing after it in
                    # the region must share its signature (so removal can
                    # only shed calls, never inflate another unit's input)
                    if set(pb.info.out_cols) & _referenced_cols(
                            plan, exclude=(fb,)):
                        continue
                    above = chain[:chain.index(pb)]
                    sig = predict_signature(pb.info)
                    if any(isinstance(q, Predict)
                           and predict_signature(q.info) != sig
                           for q in above):
                        continue
                    drop.append(pb)
                rows = self._est_rows(pb.child if pb.child else pb)
                est = self.cost.estimate(pb.info, rows)
                saved = (f"saves ~{est.expected_calls:.0f} calls"
                         if pb is not pa else "drops a redundant filter")
                site = (f"Filter[{opb}{vb!r}] over "
                        f"Predict[{pb.info.model_name}]")
                detail = (f"implied by [{opa}{va!r}] on an identical "
                          f"predict; {saved}")
                return drop, site, detail
        return None

    # -- rule: semantic select vs join placement ---------------------------
    def _push_through_join(self, plan: Node):
        ctx = self.ctx
        if ctx is None or not ctx.flags.get("enable_join_order", True):
            return None
        for n in walk_plan(plan):
            if not (isinstance(n, Filter) and find_predicts(n.predicate)
                    and isinstance(n.child, Predict)
                    and n.child.child is not None
                    and isinstance(n.child.child, Join)):
                continue
            pred_node = n.child
            join = pred_node.child
            inputs = set(pred_node.info.inputs)
            lsch = set(join.left.schema(self.cat))
            rsch = set(join.right.schema(self.cat))
            side = "left" if inputs <= lsch else \
                "right" if inputs <= rsch else None
            site = (f"Filter over Predict[{pred_node.info.model_name}] "
                    f"over Join")
            if side is None:
                self._note("push_semantic_select_through_join", site,
                           "inputs straddle both join sides")
                continue
            side_plan = join.left if side == "left" else join.right
            d_side = ctx._distinct_count(side_plan, list(inputs))
            d_join = ctx._distinct_count(join, list(inputs))
            if d_side is None or d_join is None:
                self._note("push_semantic_select_through_join", site,
                           "no distinct-count statistics (non-cheap input)")
                continue
            c_side = ctx._placement_cost(pred_node, d_side)
            c_join = ctx._placement_cost(pred_node, d_join)
            if not c_side < c_join:
                self._note(
                    "push_semantic_select_through_join", site,
                    f"kept above join: {side} side distinct={d_side:.0f} "
                    f"not cheaper than above-join distinct={d_join:.0f}")
                continue
            sub = Filter(Predict(side_plan, pred_node.info), n.predicate,
                         n.selectivity)
            if side == "left":
                repl = Join(sub, join.right, join.kind, join.left_keys,
                            join.right_keys, join.extra)
            else:
                repl = Join(join.left, sub, join.kind, join.left_keys,
                            join.right_keys, join.extra)
            detail = (f"pushed to {side} side: distinct={d_side:.0f} < "
                      f"above-join distinct={d_join:.0f} "
                      f"(calls {c_side[0]:.0f} vs {c_join[0]:.0f})")
            return _rebuild_replace(plan, n, repl), site, detail
        return None


# ---------------------------------------------------------------------------
def rewrites_section(events: List[RewriteEvent],
                     rerank_lines: Optional[List[str]] = None) -> str:
    """EXPLAIN `-- rewrites --` body: one line per pattern match (fired /
    rejected / kept with the benefit estimate or legality reason), then one
    line per mid-query re-rank the executor performed."""
    lines = [f"{ev.rule} @ {ev.site}: {ev.action} ({ev.detail})"
             for ev in events]
    for r in rerank_lines or []:
        lines.append("reopt: " + r)
    return "\n".join(lines) if lines else "(no rewrites fired)"
