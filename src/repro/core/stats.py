"""Adaptive statistics + cost model for the semantic optimizer.

The paper's §6 reorderings (select ordering, select-vs-join, predict
pull-up) only pay off when the optimizer knows predicate selectivities and
per-call costs.  This module closes the loop:

  StatisticsStore   database-owned, persists across queries (exactly like
                    the cross-query PromptCache).  Per (model, instruction)
                    key it accumulates observed selectivity (rows in vs
                    rows passing the semantic predicate), input/output
                    token counts, per-call modeled latency, and retry/
                    fallback rates.  Fed by
                      * the physical layer — FilterOp-over-PredictOp and
                        SemanticJoinOp record predicate pass rates as
                        chunks/windows resolve;
                      * the InferenceService — every dispatched call
                        records its tokens and modeled latency;
                      * the PredictOperator — strict retries and per-tuple
                        fallbacks.

  CostModel         turns a store record (or, lacking one, the optimizer's
                    static hints) into a CostEstimate: expected calls ×
                    tokens × per-call latency, reduced through the same
                    greedy worker-pool + rate-limit makespan model the
                    executor reports (`service.makespan`).  The optimizer
                    ranks commuting semantic selects by the classic
                    cost/(1 - selectivity) rule, which minimizes expected
                    stack cost (and, at uniform per-call cost, expected
                    call count).

  PilotSampler      for predicates with NO history the optimizer dispatches
                    a small deterministic reservoir sample (default 16
                    rows) through the normal PredictOperator path at
                    optimize time.  Answers land in the cross-query
                    PromptCache, so pilot work is never wasted; pilot calls
                    are accounted separately (`ExecStats.pilot_calls`).
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import math
import threading
from collections import deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executors import default_latency_model
from repro.core.service import makespan, staged_key

__all__ = ["stats_key", "staged_key", "PredicateStats", "CascadeStats",
           "CascadeCalibration", "StatisticsStore", "CostEstimate",
           "CostModel", "PilotSampler", "expected_stack_cost", "order_rank",
           "stats_section"]


def stats_key(info) -> Tuple[str, str]:
    """Store key for a PredictInfo: (model, raw instruction).  Uses the
    user-written instruction (not the fully rendered prompt preamble) so
    the key is stable across schema-preamble tweaks."""
    instr = info.prompt.instruction if info.prompt else \
        "predict " + ", ".join(n for n, _ in info.outputs)
    return (info.model_name, instr)


#: chunk-level predicate records kept in the sliding recency window
_RECENT_WINDOW = 32


@dataclasses.dataclass
class PredicateStats:
    """Accumulated observations for one (model, instruction) key."""
    rows_in: int = 0          # predicate inputs observed
    rows_passed: int = 0      # inputs that satisfied the predicate
    calls: int = 0            # executor calls dispatched
    in_tokens: int = 0
    out_tokens: int = 0
    latency_s: float = 0.0    # sum of per-call modeled latencies
    retries: int = 0
    fallbacks: int = 0
    pilot_calls: int = 0      # subset of `calls` made by pilot sampling
    pilot_rows: int = 0       # subset of `rows_in` observed by pilots
    # sliding window of the last `_RECENT_WINDOW` (rows_in, rows_passed)
    # chunk records: the decayed view the rewrite engine / mid-query
    # re-ranker consults so drifting data cannot pin a stale order.  The
    # lifetime `selectivity` stays the planner's deterministic default —
    # windowed reads are opt-in (window content depends on record order,
    # which concurrent sessions interleave).
    recent: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=_RECENT_WINDOW))

    @property
    def selectivity(self) -> Optional[float]:
        if self.rows_in <= 0:
            return None
        return self.rows_passed / self.rows_in

    @property
    def windowed_selectivity(self) -> Optional[float]:
        """Pass rate over the recency window only (None when empty)."""
        rin = sum(r for r, _ in self.recent)
        if rin <= 0:
            return None
        return sum(p for _, p in self.recent) / rin

    @property
    def mean_in_tokens(self) -> Optional[float]:
        return self.in_tokens / self.calls if self.calls else None

    @property
    def mean_out_tokens(self) -> Optional[float]:
        return self.out_tokens / self.calls if self.calls else None

    @property
    def mean_latency_s(self) -> Optional[float]:
        return self.latency_s / self.calls if self.calls else None

    @property
    def retry_rate(self) -> float:
        return self.retries / self.calls if self.calls else 0.0

    @property
    def fallback_rate(self) -> float:
        return self.fallbacks / self.calls if self.calls else 0.0


#: confidence-histogram resolution for cascade score sketches
_CASCADE_BINS = 20
#: held-out agreement reservoir capacity per (model, instruction) key
_CASCADE_RESERVOIR = 256


class CascadeStats:
    """Cascade calibration state for one (model, instruction) key:

      * a held-out AGREEMENT RESERVOIR — up to `_CASCADE_RESERVOIR`
        (row_hash → (proxy confidence, proxy verdict, proxy == expensive))
        records from escalated/audited rows, the ground truth behind
        threshold calibration.  Keyed by the deterministic row hash and
        evicted keep-smallest-hashes, the reservoir's final content is a
        pure set-union of everything recorded — independent of the order
        concurrent dispatch workers insert in (the store's determinism
        contract);
      * SCORE-DISTRIBUTION SKETCHES — per-verdict confidence histograms
        over every proxy-scored row, used to estimate the escalation rate
        a threshold pair implies;
      * routing counters (rows routed/escalated, per-stage calls, audit
        agreement), all order-independent sums.
    """
    __slots__ = ("reservoir", "_heap", "hist_pos", "hist_neg", "routed_rows",
                 "escalated_rows", "proxy_calls", "expensive_calls",
                 "audited", "audit_agree", "degraded_batches")

    def __init__(self):
        self.reservoir: Dict[int, Tuple[float, bool, bool]] = {}
        # max-heap over the reservoir keys (stored negated): capacity
        # eviction pops the current largest hash in O(log n) instead of
        # re-sorting the whole reservoir under the lock on every insert.
        # Invariant: _heap holds exactly the reservoir's keys, once each.
        self._heap: List[int] = []
        self.hist_pos = np.zeros(_CASCADE_BINS, np.int64)
        self.hist_neg = np.zeros(_CASCADE_BINS, np.int64)
        self.routed_rows = 0
        self.escalated_rows = 0
        self.proxy_calls = 0
        self.expensive_calls = 0
        self.audited = 0
        self.audit_agree = 0
        # batches whose expensive stage was skipped (breaker open /
        # transient outage): EXPLAIN surfaces contract status `degraded`
        self.degraded_batches = 0

    @property
    def n_records(self) -> int:
        return len(self.reservoir)


@dataclasses.dataclass
class CascadeCalibration:
    """A calibrated (threshold pair, contract estimate) snapshot for one
    cascade key.  `CascadePredictor.load()` takes ONE snapshot per query —
    evidence recorded while the query runs only affects future queries,
    which is what keeps routing deterministic under concurrent dispatch.

    tau_pos / tau_neg are the per-verdict acceptance thresholds: a
    proxy-positive row resolves immediately iff conf >= tau_pos (likewise
    negative/tau_neg); everything below either threshold escalates.  A
    threshold of 2.0 (> any confidence) means 'always escalate that
    verdict class'."""
    target: float
    tau_pos: float = 2.0
    tau_neg: float = 2.0
    escalation_rate: float = 1.0       # expected escalated-row fraction
    empirical_precision: Optional[float] = None
    n_records: int = 0                 # reservoir size behind the snapshot
    #: cold (not enough held-out evidence: escalate everything),
    #: ok (contract achievable at these thresholds),
    #: unachievable (no threshold meets the target: route direct),
    #: violated (audited precision fell below the target: route direct)
    status: str = "cold"


class StatisticsStore:
    """Cross-query observation store, owned by the database (a sibling of
    `IPDB.prompt_cache`).  All writers go through the record_* methods so
    a future persistent backend only has one surface to replace.

    Writers are lock-protected: with per-backend dispatch pools the
    InferenceService records calls from worker threads concurrently with
    the submitting thread's predicate probes, and the read-modify-write
    counter updates would otherwise lose increments under the GIL.  All
    recorded quantities are order-independent sums, so concurrent dispatch
    cannot change what the store converges to."""

    def __init__(self):
        self._d: Dict[Tuple[str, str], PredicateStats] = {}
        self._c: Dict[Tuple[str, str], CascadeStats] = {}
        self._lock = threading.Lock()

    def entry(self, key: Tuple[str, str]) -> PredicateStats:
        with self._lock:
            rec = self._d.get(key)
            if rec is None:
                rec = self._d[key] = PredicateStats()
            return rec

    def cascade_entry(self, key: Tuple[str, str]) -> CascadeStats:
        with self._lock:
            rec = self._c.get(key)
            if rec is None:
                rec = self._c[key] = CascadeStats()
            return rec

    def cascade_get(self, key: Tuple[str, str]) -> Optional[CascadeStats]:
        with self._lock:
            return self._c.get(key)

    def get(self, key: Tuple[str, str]) -> Optional[PredicateStats]:
        with self._lock:
            return self._d.get(key)

    def keys(self) -> Iterable[Tuple[str, str]]:
        with self._lock:
            return list(self._d.keys())

    def __len__(self) -> int:
        with self._lock:
            return len(self._d)

    def clear(self) -> None:
        with self._lock:
            self._d.clear()
            self._c.clear()

    # -- warm-state snapshots (core/snapshot.py) -------------------------
    _PRED_FIELDS = ("rows_in", "rows_passed", "calls", "in_tokens",
                    "out_tokens", "latency_s", "retries", "fallbacks",
                    "pilot_calls", "pilot_rows")
    _CASC_FIELDS = ("routed_rows", "escalated_rows", "proxy_calls",
                    "expensive_calls", "audited", "audit_agree",
                    "degraded_batches")

    def export_state(self) -> Dict[str, object]:
        """Plain-python snapshot payload: every predicate record (with its
        recency window) and every cascade record (reservoir + sketches).
        numpy arrays become lists and the eviction heap is dropped — both
        are rebuilt on restore — so the payload pickles small and stays
        stable across numpy versions."""
        with self._lock:
            preds = {}
            for key, rec in self._d.items():
                d = {f: getattr(rec, f) for f in self._PRED_FIELDS}
                d["recent"] = list(rec.recent)
                preds[key] = d
            cascades = {}
            for key, rec in self._c.items():
                d = {f: getattr(rec, f) for f in self._CASC_FIELDS}
                d["reservoir"] = dict(rec.reservoir)
                d["hist_pos"] = rec.hist_pos.tolist()
                d["hist_neg"] = rec.hist_neg.tolist()
                cascades[key] = d
        return {"predicates": preds, "cascades": cascades}

    def restore_state(self, state: Dict[str, object]) -> int:
        """Rebuild records from an `export_state` payload (additive onto
        whatever the store already holds; fresh stores restore exactly)."""
        n = 0
        for key, d in (state.get("predicates") or {}).items():
            rec = self.entry(tuple(key))
            with self._lock:
                for f in self._PRED_FIELDS:
                    setattr(rec, f, d.get(f, 0))
                rec.recent = deque((tuple(t) for t in d.get("recent", [])),
                                   maxlen=_RECENT_WINDOW)
            n += 1
        for key, d in (state.get("cascades") or {}).items():
            rec = self.cascade_entry(tuple(key))
            with self._lock:
                for f in self._CASC_FIELDS:
                    setattr(rec, f, d.get(f, 0))
                rec.reservoir = {int(h): tuple(v)
                                 for h, v in d.get("reservoir", {}).items()}
                rec._heap = [-h for h in rec.reservoir]
                heapq.heapify(rec._heap)
                rec.hist_pos = np.asarray(
                    d.get("hist_pos", [0] * _CASCADE_BINS), np.int64)
                rec.hist_neg = np.asarray(
                    d.get("hist_neg", [0] * _CASCADE_BINS), np.int64)
            n += 1
        return n

    # -- writers ---------------------------------------------------------
    def record_call(self, key, in_tokens: int, out_tokens: int,
                    latency_s: float, *, pilot: bool = False) -> None:
        rec = self.entry(key)
        with self._lock:
            rec.calls += 1
            rec.in_tokens += int(in_tokens)
            rec.out_tokens += int(out_tokens)
            rec.latency_s += float(latency_s)
            if pilot:
                rec.pilot_calls += 1

    def record_predicate(self, key, rows_in: int, rows_passed: int, *,
                         pilot: bool = False) -> None:
        rec = self.entry(key)
        with self._lock:
            rec.rows_in += int(rows_in)
            rec.rows_passed += int(rows_passed)
            rec.recent.append((int(rows_in), int(rows_passed)))
            if pilot:
                rec.pilot_rows += int(rows_in)

    def record_retry(self, key) -> None:
        rec = self.entry(key)
        with self._lock:
            rec.retries += 1

    def record_fallback(self, key) -> None:
        rec = self.entry(key)
        with self._lock:
            rec.fallbacks += 1

    # -- cascade writers ---------------------------------------------------
    def record_cascade_scores(self, key, confs: Sequence[float],
                              verdicts: Sequence[bool]) -> None:
        """Fold one proxy-scored batch into the per-verdict confidence
        sketches (every routed row, not just escalated ones)."""
        rec = self.cascade_entry(key)
        with self._lock:
            for c, pos in zip(confs, verdicts):
                b = min(_CASCADE_BINS - 1,
                        max(0, int(float(c) * _CASCADE_BINS)))
                (rec.hist_pos if pos else rec.hist_neg)[b] += 1

    def record_cascade_agreement(self, key, row_hash: int, conf: float,
                                 verdict: bool, agree: bool, *,
                                 audited: bool = False) -> None:
        """One held-out observation: the proxy said `verdict` with `conf`
        and the expensive model (dis)agreed.  Deterministic capacity
        eviction keeps the `_CASCADE_RESERVOIR` smallest row hashes, so
        the reservoir converges to the same set regardless of the order
        concurrent workers record in."""
        rec = self.cascade_entry(key)
        h = int(row_hash)
        val = (float(conf), bool(verdict), bool(agree))
        with self._lock:
            if audited:
                rec.audited += 1
                rec.audit_agree += int(bool(agree))
            if h in rec.reservoir:
                rec.reservoir[h] = val          # update in place, heap keeps h
            elif len(rec.reservoir) < _CASCADE_RESERVOIR:
                rec.reservoir[h] = val
                heapq.heappush(rec._heap, -h)
            elif h < -rec._heap[0]:
                # smaller than the current max hash: the max is the record
                # the old sort-everything pass would have dropped
                evicted = -heapq.heapreplace(rec._heap, -h)
                del rec.reservoir[evicted]
                rec.reservoir[h] = val
            # else: h exceeds every retained hash — dropped on arrival,
            # exactly as insert-then-trim discarded it

    def record_cascade_batch(self, key, rows: int, escalated: int,
                             proxy_calls: int, expensive_calls: int, *,
                             degraded: int = 0) -> None:
        rec = self.cascade_entry(key)
        with self._lock:
            rec.routed_rows += int(rows)
            rec.escalated_rows += int(escalated)
            rec.proxy_calls += int(proxy_calls)
            rec.expensive_calls += int(expensive_calls)
            rec.degraded_batches += int(degraded)

    # -- cascade calibration ----------------------------------------------
    def calibrate_cascade(self, key, target_precision: float, *,
                          min_records: int = 8) -> CascadeCalibration:
        """Derive the acceptance-threshold pair meeting `target_precision`
        from the held-out reservoir.  Per verdict class, records are sorted
        by descending confidence (hash-tie-broken for a total order) and
        the threshold is the confidence of the LARGEST prefix whose
        agreement rate still meets the target — maximum coverage at the
        contracted precision.  A class with no qualifying prefix keeps
        tau=2.0 (always escalate).  The implied escalation rate comes from
        the score sketches (reservoir fallback), the empirical precision
        from audit records when present, else the accepted reservoir
        slice."""
        target = min(max(float(target_precision), 0.0), 1.0)
        rec = self.cascade_get(key)
        cal = CascadeCalibration(target=target)
        if rec is None:
            return cal
        with self._lock:
            records = [(c, pos, agree, h)
                       for h, (c, pos, agree) in rec.reservoir.items()]
            hist_pos = rec.hist_pos.copy()
            hist_neg = rec.hist_neg.copy()
            audited, audit_agree = rec.audited, rec.audit_agree
        cal.n_records = len(records)
        if cal.n_records < max(1, int(min_records)):
            return cal                 # cold: escalate everything

        def best_tau(cls_records) -> float:
            # cls_records: [(conf, agree, hash)] for one verdict class
            cls_records.sort(key=lambda t: (-t[0], t[2]))
            tau, good = 2.0, 0
            for k, (conf, agree, _) in enumerate(cls_records, start=1):
                good += int(agree)
                # a threshold is only well-defined at a confidence
                # boundary: tau = conf accepts EVERY record of a tie
                # group, so a prefix cutting inside one would promise a
                # precision its own acceptance set does not have
                if k < len(cls_records) and cls_records[k][0] == conf:
                    continue
                if good / k >= target:
                    tau = conf
            return tau

        cal.tau_pos = best_tau([(c, a, h) for c, p, a, h in records if p])
        cal.tau_neg = best_tau([(c, a, h) for c, p, a, h in records
                                if not p])
        if cal.tau_pos > 1.0 and cal.tau_neg > 1.0:
            cal.escalation_rate = 1.0
            cal.status = "unachievable"
            return cal

        # escalation rate a threshold implies: sketch mass whose bin
        # center falls below the class threshold
        centers = (np.arange(_CASCADE_BINS) + 0.5) / _CASCADE_BINS
        total = int(hist_pos.sum() + hist_neg.sum())
        if total > 0:
            esc = (int(hist_pos[centers < cal.tau_pos].sum())
                   + int(hist_neg[centers < cal.tau_neg].sum()))
            cal.escalation_rate = esc / total
        else:
            esc = sum(1 for c, p, a, h in records
                      if c < (cal.tau_pos if p else cal.tau_neg))
            cal.escalation_rate = esc / len(records)

        accepted = [a for c, p, a, h in records
                    if c >= (cal.tau_pos if p else cal.tau_neg)]
        if audited > 0:
            cal.empirical_precision = audit_agree / audited
        elif accepted:
            cal.empirical_precision = sum(accepted) / len(accepted)
        cal.status = "ok"
        if audited >= 16 and (audit_agree / audited) < target:
            cal.status = "violated"    # contract broken on audited rows
        return cal


# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CostEstimate:
    selectivity: float
    sel_source: str           # observed | hint | default
    expected_calls: float
    per_call_s: float
    in_tokens: float          # expected total
    out_tokens: float
    makespan_s: float


def expected_stack_cost(n_rows: float,
                        units: Sequence[Tuple[float, float]]) -> float:
    """Expected cost of running a stack of commuting semantic selects in
    the given order: units = [(per_row_cost, selectivity), ...], unit 0
    executed first.  Each unit pays its per-row cost on the rows surviving
    the units before it."""
    total, rows = 0.0, float(n_rows)
    for cost, sel in units:
        total += rows * cost
        rows *= min(max(float(sel), 0.0), 1.0)
    return total


def order_rank(per_row_cost: float, selectivity: float) -> float:
    """Rank metric for ordering commuting selects: ascending
    cost/(1 - selectivity) minimizes `expected_stack_cost` (standard
    exchange argument); at uniform cost it reduces to ascending
    selectivity, which minimizes expected call count."""
    return per_row_cost / max(1e-6, 1.0 - min(max(selectivity, 0.0), 1.0))


class CostModel:
    """Unified cost model over a StatisticsStore.  Observed statistics win;
    static hints (`selectivity_hint`, caller-provided token estimates) are
    the fallback, so a cold store reproduces the old heuristics exactly."""

    #: below this many observed predicate inputs the store is not trusted
    MIN_OBS_ROWS = 1

    def __init__(self, store: Optional[StatisticsStore],
                 options: Optional[Dict[str, object]] = None):
        self.store = store if store is not None else StatisticsStore()
        self.opts = dict(options or {})

    # -- components ------------------------------------------------------
    def selectivity(self, info) -> Tuple[float, str]:
        rec = self.store.get(stats_key(info))
        if rec is not None and rec.rows_in >= self.MIN_OBS_ROWS:
            return float(rec.selectivity), "observed"
        hint = (info.options or {}).get("selectivity_hint")
        if hint is not None:
            return float(hint), "hint"
        return 0.5, "default"

    def per_call(self, info, fallback_in_tokens: Optional[float] = None
                 ) -> Tuple[float, float, float]:
        """(in_tokens, out_tokens, modeled latency) per executor call."""
        rec = self.store.get(stats_key(info))
        if rec is not None and rec.calls > 0:
            return (rec.mean_in_tokens, rec.mean_out_tokens,
                    rec.mean_latency_s)
        in_t = float(fallback_in_tokens) if fallback_in_tokens is not None \
            else 64.0
        out_t = 4.0 * max(1, len(info.outputs))
        return in_t, out_t, default_latency_model(in_t, out_t)

    def _calls_for(self, info, rows: float) -> float:
        bs = 1.0
        if bool(self.opts.get("use_batching", True)):
            bs = float((info.options or {}).get(
                "batch_size", self.opts.get("batch_size", 16)))
        rec = self.store.get(stats_key(info))
        inflate = 1.0 + (rec.retry_rate + rec.fallback_rate
                         if rec is not None and rec.calls else 0.0)
        return math.ceil(max(0.0, rows) / max(1.0, bs)) * inflate

    def _makespan(self, n_calls: float, per_call_s: float) -> float:
        workers = max(1, int(self.opts.get("n_threads", 16)))
        rpm = float(self.opts.get("rate_limit_rpm", 0.0) or 0.0)
        n = int(math.ceil(n_calls))
        if n <= 0:
            return 0.0
        cap = 10_000
        if n <= cap:
            return makespan([per_call_s] * n, workers, rpm)
        # identical latencies → makespan scales linearly past the cap
        return makespan([per_call_s] * cap, workers, rpm) * (n / cap)

    # -- API -------------------------------------------------------------
    def estimate(self, info, est_in_rows: float,
                 fallback_in_tokens: Optional[float] = None) -> CostEstimate:
        sel, src = self.selectivity(info)
        in_t, out_t, lat = self.per_call(info, fallback_in_tokens)
        calls = self._calls_for(info, est_in_rows)
        return CostEstimate(
            selectivity=sel, sel_source=src, expected_calls=calls,
            per_call_s=lat, in_tokens=calls * in_t, out_tokens=calls * out_t,
            makespan_s=self._makespan(calls, lat))

    def queue_makespan(self, key: Optional[Tuple[str, str]], n_calls: int,
                       fallback_per_call_s: Optional[float] = None) -> float:
        """Expected makespan of one InferenceService queue of `n_calls`
        requests under this model: the store's observed mean per-call
        latency for `key` when it has history, else the caller's fallback
        (else the default latency model), reduced through the same greedy
        worker/rpm schedule as `estimate()`.  Drives the service's
        smallest-expected-makespan-first flush prioritization."""
        per = None
        if key is not None:
            rec = self.store.get(key)
            if rec is not None and rec.calls:
                per = rec.mean_latency_s
        if per is None:
            per = (float(fallback_per_call_s)
                   if fallback_per_call_s is not None
                   else default_latency_model(64.0, 8.0))
        return self._makespan(float(n_calls), per)

    def rank(self, info, fallback_in_tokens: Optional[float] = None
             ) -> Tuple[float, float, float]:
        """Sort key for commuting semantic selects (ascending = run
        first).  Primary: cost/(1-selectivity); ties broken by the static
        token estimate then selectivity.  On a cold store with no
        selectivity hints every unit gets sel=0.5, so the primary key is
        monotone in the token estimate and the ordering matches the old
        size heuristic; an explicit selectivity_hint now (correctly)
        participates in the cost rank instead of only breaking ties."""
        sel, _ = self.selectivity(info)
        _, _, lat = self.per_call(info, fallback_in_tokens)
        fb = float(fallback_in_tokens) if fallback_in_tokens is not None \
            else 64.0
        return (order_rank(lat, sel), fb, sel)


# ---------------------------------------------------------------------------
class PilotSampler:
    """Optimize-time selectivity calibration for predicates with no
    history.  Runs a deterministic reservoir sample of the predicate's
    input through the normal PredictOperator path (same InferenceService,
    same PromptCache — sampled answers are re-used by the real execution),
    then records the observed pass rate in the store."""

    def __init__(self, predict_factory, store: StatisticsStore, *,
                 sample_rows: int = 16, min_table_rows: Optional[int] = None):
        self.predict_factory = predict_factory
        self.store = store
        self.sample_rows = max(1, int(sample_rows))
        # a pilot over most of the input defeats its purpose: only sample
        # when the table is several times larger than the sample
        self.min_table_rows = (4 * self.sample_rows if min_table_rows is None
                               else int(min_table_rows))
        self.calls = 0
        self.in_tokens = 0
        self.out_tokens = 0
        self.sim_latency_s = 0.0

    def _sample_idx(self, n: int, key) -> np.ndarray:
        h = hashlib.sha256(("pilot:" + repr(key)).encode()).digest()
        rng = np.random.default_rng(int.from_bytes(h[:8], "little"))
        return np.sort(rng.choice(n, size=self.sample_rows, replace=False))

    def wants(self, info) -> bool:
        """True when a pilot could teach us something about `info`: no
        predicate history in the store yet."""
        if self.predict_factory is None:
            return False
        rec = self.store.get(stats_key(info))
        return rec is None or rec.rows_in == 0

    def calibrate(self, predicate, info, base_table) -> bool:
        """Sample `base_table`, run `info`'s predict over the sample,
        evaluate `predicate` on the result and record the pass rate.
        Returns True when a pilot actually ran."""
        if not self.wants(info):
            return False               # history exists — nothing to learn
        key = stats_key(info)
        n = len(base_table)
        if n <= max(self.min_table_rows, self.sample_rows):
            return False               # cannot amortize the pilot cost
        sample = base_table.take(self._sample_idx(n, key))
        op = self.predict_factory(info)
        out = op(sample)
        mask = np.asarray(predicate.evaluate(out), bool)
        self.store.record_predicate(key, len(out), int(mask.sum()),
                                    pilot=True)
        self.store.entry(key).pilot_calls += op.stats.calls
        self.calls += op.stats.calls
        self.in_tokens += op.stats.in_tokens
        self.out_tokens += op.stats.out_tokens
        self.sim_latency_s += op.stats.sim_latency_s
        return True


# ---------------------------------------------------------------------------
def stats_section(plan, store: StatisticsStore,
                  cost_model: CostModel) -> str:
    """EXPLAIN `-- stats --` body: one line per Predict/SemanticJoin node,
    estimated selectivity/cost next to the store's observations."""
    from repro.relational.plan import Predict, SemanticJoin, walk_plan

    def fmt(v, spec="{:.3f}"):
        return spec.format(v) if v is not None else "n/a"

    lines: List[str] = []
    for node in walk_plan(plan):
        if not isinstance(node, (Predict, SemanticJoin)):
            continue
        info = node.info
        key = stats_key(info)
        rows = float(info.options.get(
            "est_cross_rows", info.options.get("est_in_rows", 0.0)) or 0.0)
        est = cost_model.estimate(info, rows)
        # prefer the selectivity the optimizer actually stamped on the plan
        # (it may predate observations added by later queries)
        if "est_selectivity" in info.options:
            est = dataclasses.replace(
                est, selectivity=float(info.options["est_selectivity"]),
                sel_source=str(info.options.get("sel_source",
                                                est.sel_source)))
        rec = store.get(key)
        kind = type(node).__name__
        instr = key[1] if len(key[1]) <= 48 else key[1][:45] + "..."
        obs = "none"
        if rec is not None:
            obs = (f"sel={fmt(rec.selectivity)} calls={rec.calls} "
                   f"mean_lat_s={fmt(rec.mean_latency_s, '{:.2f}')} "
                   f"tokens={fmt(rec.mean_in_tokens, '{:.0f}')}in/"
                   f"{fmt(rec.mean_out_tokens, '{:.0f}')}out "
                   f"retry_rate={rec.retry_rate:.2f} "
                   f"pilot_calls={rec.pilot_calls}")
        lines.append(
            f"{kind}[{info.model_name}] '{instr}'\n"
            f"  est: sel={est.selectivity:.3f} ({est.sel_source}) "
            f"rows={rows:.0f} calls={est.expected_calls:.0f} "
            f"makespan_s={est.makespan_s:.2f}\n"
            f"  obs: {obs}")
    return "\n".join(lines) if lines else "(no semantic operators)"
