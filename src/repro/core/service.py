"""Shared inference service: the async dispatch layer between relational
operators and model executors (paper §6.3, generalized).

Operators no longer call executors directly.  They build
`InferenceRequest`s and `submit()` them to the database-owned
`InferenceService`, receiving `InferenceHandle` futures.  The service

  * maintains one queue per (model, instruction, schema) — requests that
    can be answered by the same executor configuration batch together,
    across chunks, windows and operators;
  * dedups in-flight requests: a second identical request submitted while
    the first is still pending joins the existing handle instead of
    re-dispatching (complementing the cross-query PromptCache, which only
    covers *resolved* results);
  * dispatches each queue in one `Predictor.complete_many` call per
    `flush()` — for the JAX backend that is one continuous-batching run
    over all marshaled prompts, for the oracle/tabular backends one
    vectorized pass — optionally capped at `max_dispatch` calls per batch
    (a simple provider rate limit);
  * owns makespan accounting: per-call modeled latencies are recorded on
    `DispatchGroup`s (one per predict chunk) and reduced with the same
    greedy worker-pool + rpm model that previously lived inside
    `PredictOperator`.

Synchronous execution is the degenerate case: submit immediately followed
by flush()+resolve behaves exactly like the old direct `complete()` path.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.executors import CallResult, Predictor


def makespan(latencies: Sequence[float], workers: int, rpm: float = 0.0
             ) -> float:
    """Greedy schedule of calls onto `workers`, optionally throttled to
    `rpm` requests/minute (paper Fig. 5 model)."""
    if not latencies:
        return 0.0
    heap = [0.0] * max(1, workers)
    heapq.heapify(heap)
    gap = 60.0 / rpm if rpm else 0.0
    next_slot = 0.0
    end = 0.0
    for l in latencies:
        free = heapq.heappop(heap)
        start = max(free, next_slot)
        next_slot = start + gap
        fin = start + l
        end = max(end, fin)
        heapq.heappush(heap, fin)
    return end


@dataclasses.dataclass
class DispatchGroup:
    """Accounting scope for one unit of operator work (one predict chunk,
    one aggregate call, one table scan).  Every call made on behalf of the
    group — including retries and per-tuple fallbacks — records its
    modeled latency here in batch order (the operator appends as it
    consumes results), so the group's greedy makespan matches the old
    per-chunk `PredictOperator` accounting exactly."""
    workers: int = 16
    rpm: float = 0.0
    latencies: List[float] = dataclasses.field(default_factory=list)

    def makespan(self) -> float:
        return makespan(self.latencies, self.workers, self.rpm)

    def serial(self) -> float:
        return float(sum(self.latencies))


@dataclasses.dataclass
class InferenceRequest:
    """One executor call to be: a fully rendered prompt plus the metadata
    the executor needs to answer and the service needs to route it."""
    model_name: str
    instruction: str
    prompt: str
    schema: Tuple[Tuple[str, str], ...]
    num_rows: int
    executor: Predictor
    rows: Optional[List[dict]] = None
    shared_prefix: str = ""
    dedup: bool = True                 # False: never join another handle
    # statistics-store key ((model, raw instruction)); set by the predict
    # operator so dispatch accounting can feed the adaptive cost model
    stats_key: Optional[Tuple[str, str]] = None

    @property
    def queue_key(self) -> Tuple:
        # shared_prefix included so every dispatch batch is
        # prefix-homogeneous (executors apply one prefix per batch)
        return (self.model_name, self.instruction, self.schema,
                self.shared_prefix)

    @property
    def dedup_key(self) -> Tuple:
        return (self.model_name, self.instruction, self.schema,
                self.shared_prefix, self.prompt, self.num_rows)


class InferenceHandle:
    """Future for one dispatched (or joined) request."""
    __slots__ = ("request", "_service", "_result", "refs")

    def __init__(self, request: InferenceRequest, service: "InferenceService"):
        self.request = request
        self._service = service
        self._result: Optional[CallResult] = None
        self.refs = 1                  # submitters sharing this handle

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> CallResult:
        if self._result is None:
            self._service.flush()
        if self._result is None:
            raise RuntimeError("inference request cancelled before dispatch")
        return self._result


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    dispatched_calls: int = 0          # executor calls actually made
    dispatch_batches: int = 0          # complete_many invocations
    inflight_dedup_hits: int = 0       # submits that joined a pending handle

    @property
    def mean_batch_occupancy(self) -> float:
        if self.dispatch_batches == 0:
            return 0.0
        return self.dispatched_calls / self.dispatch_batches


class InferenceService:
    """Batching request broker between predict operators and executors.

    `submit()` enqueues; nothing reaches an executor until `flush()`
    (called implicitly by `InferenceHandle.result()`), so pipelined
    operators can stack several windows of requests and have them
    dispatched as one batch per (model, instruction) queue."""

    def __init__(self, *, max_dispatch: int = 0, stats_store=None):
        # queues preserve submission order (dict insertion order)
        self._queues: Dict[Tuple, List[InferenceHandle]] = {}
        self._inflight: Dict[Tuple, InferenceHandle] = {}
        self.max_dispatch = int(max_dispatch)   # 0 = unbounded batch
        self.stats = ServiceStats()
        # optional adaptive StatisticsStore: every dispatched call records
        # its tokens + modeled latency under the request's stats_key
        self.stats_store = stats_store

    # -- submission ------------------------------------------------------
    def open_group(self, workers: int = 16, rpm: float = 0.0) -> DispatchGroup:
        return DispatchGroup(max(1, int(workers)), float(rpm))

    def submit_one(self, request: InferenceRequest
                   ) -> Tuple[InferenceHandle, bool]:
        """Enqueue one request.  Returns (handle, owned): owned is False
        when the request joined an identical pending handle (in-flight
        dedup) — the joiner must not account the call's tokens."""
        self.stats.submitted += 1
        if request.dedup:
            h = self._inflight.get(request.dedup_key)
            if h is not None and not h.done:
                h.refs += 1
                self.stats.inflight_dedup_hits += 1
                return h, False
        h = InferenceHandle(request, self)
        self._queues.setdefault(request.queue_key, []).append(h)
        if request.dedup:
            self._inflight[request.dedup_key] = h
        return h, True

    def submit(self, requests: Sequence[InferenceRequest]
               ) -> List[InferenceHandle]:
        return [self.submit_one(r)[0] for r in requests]

    # -- dispatch --------------------------------------------------------
    def flush(self) -> None:
        """Dispatch every queued request.  Each per-queue slice of at most
        `max_dispatch` requests is one dispatch batch: one
        `complete_many` executor call."""
        for qkey in list(self._queues):
            handles = self._queues.pop(qkey, [])
            if not handles:
                continue
            step = self.max_dispatch if self.max_dispatch > 0 else len(handles)
            for s in range(0, len(handles), step):
                self._dispatch(handles[s:s + step])

    def _dispatch(self, handles: List[InferenceHandle]) -> None:
        reqs = [h.request for h in handles]
        # clear the in-flight map BEFORE the executor runs: if it raises,
        # later identical submits must re-dispatch instead of joining a
        # handle that can never resolve
        for r in reqs:
            if r.dedup:
                self._inflight.pop(r.dedup_key, None)
        executor = reqs[0].executor
        results = executor.complete_many(
            [r.prompt for r in reqs], list(reqs[0].schema),
            [r.num_rows for r in reqs],
            shared_prefix=reqs[0].shared_prefix,
            rows_list=[r.rows for r in reqs],
            instruction=reqs[0].instruction)
        self.stats.dispatch_batches += 1
        self.stats.dispatched_calls += len(reqs)
        for h, res in zip(handles, results):
            h._result = res
            if self.stats_store is not None and h.request.stats_key:
                self.stats_store.record_call(
                    h.request.stats_key, res.in_tokens, res.out_tokens,
                    res.sim_latency_s)

    def drain(self) -> None:
        """Flush until no request remains queued."""
        while any(self._queues.values()):
            self.flush()

    def cancel(self, handle: InferenceHandle) -> bool:
        """Release one submitter's interest in a still-queued handle
        (pipelined operator closed early, e.g. under an early-exit Limit).
        The request is removed from its queue only when the last
        submitter cancels — joined submitters keep it alive."""
        if handle.done:
            return False
        handle.refs -= 1
        if handle.refs > 0:
            return False
        q = self._queues.get(handle.request.queue_key)
        if q and handle in q:
            q.remove(handle)
            if handle.request.dedup:
                self._inflight.pop(handle.request.dedup_key, None)
            return True
        return False

    @property
    def pending(self) -> int:
        return sum(len(v) for v in self._queues.values())

    def describe(self) -> str:
        return (f"InferenceService queues={len(self._queues)} "
                f"pending={self.pending} max_dispatch="
                f"{self.max_dispatch or 'unbounded'}")
