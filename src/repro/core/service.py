"""Shared inference service: the async dispatch layer between relational
operators and model executors (paper §6.3, generalized).

Operators no longer call executors directly.  They build
`InferenceRequest`s and `submit()` them to the database-owned
`InferenceService`, receiving `InferenceHandle` futures.  The service

  * maintains one queue per (model, instruction, schema) — requests that
    can be answered by the same executor configuration batch together,
    across chunks, windows and operators;
  * dedups in-flight requests: a second identical request submitted while
    the first is still pending joins the existing handle instead of
    re-dispatching (complementing the cross-query PromptCache, which only
    covers *resolved* results);
  * dispatches each queue in one `Predictor.complete_many` call per
    `flush()` — for the JAX backend that is one continuous-batching run
    over all marshaled prompts, for the oracle/tabular backends one
    vectorized pass — optionally capped at `max_dispatch` calls per batch
    (a simple provider rate limit);
  * runs dispatch batches on PER-BACKEND WORKER POOLS when the backend
    declares it can take concurrent dispatches
    (`Predictor.dispatch_workers()` > 1): queues for different (model,
    instruction) keys flush on background threads while operators keep
    submitting, so an oracle/API-style backend's modeled wait overlaps
    the local JAX engine's real compute.  `dispatch_workers = 1` (the
    default) is exactly the old synchronous flush;
  * prioritizes flushes smallest-expected-makespan-first: queues whose
    expected dispatch makespan (PR 3 CostModel over the statistics store)
    is lowest are started first, so short batches are never stuck behind
    a long-running one.  Prioritization never starves a queue — every
    `flush()` dispatches every queued request;
  * speculatively flushes hot queues (`kick()`): when a queue has
    accumulated at least `max_dispatch` requests for a concurrency-capable
    backend, the complete slices a later `flush()` would dispatch anyway
    are started early in the background.  Batch composition is invariant
    (the same prefix slices, in submission order), so accounting does not
    depend on when the kick happened;
  * owns makespan accounting: per-call modeled latencies are recorded on
    `DispatchGroup`s (one per predict chunk) and reduced with the same
    greedy worker-pool + rpm model that previously lived inside
    `PredictOperator`.

Determinism contract: rows, ExecStats and modeled latencies are
byte-identical regardless of `dispatch_workers` and of which worker
finishes first.  This holds because (a) batch composition is a pure
function of submission order + `max_dispatch`, (b) handles are resolved
by the operator in submission order, (c) all shared state (queues,
in-flight map, counters, StatisticsStore, PromptCache) is lock-protected
and accumulates order-independent sums.  `tests/test_concurrent_dispatch.py`
pins this with a scripted-latency backend and barrier-forced worst-case
interleavings.

Synchronous execution is the degenerate case: submit immediately followed
by flush()+resolve behaves exactly like the old direct `complete()` path.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.cancel import QueryCancelled
from repro.core.executors import CallResult, Predictor, default_latency_model
from repro.core.faults import (CLOSED, BackendTimeout, CircuitBreaker,
                               CircuitOpenError, DeadlineExceeded,
                               TransientError)


def makespan(latencies: Sequence[float], workers: int, rpm: float = 0.0
             ) -> float:
    """Greedy schedule of calls onto `workers`, optionally throttled to
    `rpm` requests/minute (paper Fig. 5 model)."""
    if not latencies:
        return 0.0
    heap = [0.0] * max(1, workers)
    heapq.heapify(heap)
    gap = 60.0 / rpm if rpm else 0.0
    next_slot = 0.0
    end = 0.0
    for l in latencies:
        free = heapq.heappop(heap)
        start = max(free, next_slot)
        next_slot = start + gap
        fin = start + l
        end = max(end, fin)
        heapq.heappush(heap, fin)
    return end


@dataclasses.dataclass
class DispatchGroup:
    """Accounting scope for one unit of operator work (one predict chunk,
    one aggregate call, one table scan).  Every call made on behalf of the
    group — including retries and per-tuple fallbacks — records its
    modeled latency here in batch order (the operator appends as it
    consumes results), so the group's greedy makespan matches the old
    per-chunk `PredictOperator` accounting exactly.  Appends happen on the
    consuming operator's thread only, never on dispatch workers, which is
    what keeps the latency order (and the float sums) deterministic."""
    workers: int = 16
    rpm: float = 0.0
    latencies: List[float] = dataclasses.field(default_factory=list)

    def makespan(self) -> float:
        return makespan(self.latencies, self.workers, self.rpm)

    def serial(self) -> float:
        return float(sum(self.latencies))


def staged_key(key: Tuple[str, str], stage: str) -> Tuple[str, str]:
    """Statistics-store key for one cascade stage of a (model, instruction)
    predicate.  Stage-tagged keys keep a cascaded dispatch's merged-call
    accounting separate from the base key, so a predicate's per-call stats
    are never double-counted (once inside the cascade stages, once at the
    service) — the fix for the PR 7 stats double-count."""
    if not stage:
        return key
    return (f"{key[0]}#{stage}", key[1])


@dataclasses.dataclass
class InferenceRequest:
    """One executor call to be: a fully rendered prompt plus the metadata
    the executor needs to answer and the service needs to route it."""
    model_name: str
    instruction: str
    prompt: str
    schema: Tuple[Tuple[str, str], ...]
    num_rows: int
    executor: Predictor
    rows: Optional[List[dict]] = None
    shared_prefix: str = ""
    dedup: bool = True                 # False: never join another handle
    # statistics-store key ((model, raw instruction)); set by the predict
    # operator so dispatch accounting can feed the adaptive cost model
    stats_key: Optional[Tuple[str, str]] = None
    # cascade stage tag ("" = direct).  Staged requests batch and dedup
    # separately from direct ones, and their dispatch accounting records
    # under `staged_key(stats_key, stage)` so a cascaded predicate's base
    # key only ever sees the per-stage records written by the cascade
    # executor itself (never the merged two-stage call on top of them).
    stage: str = ""
    # front-door multi-tenancy tags ("" = the plain Python API).  Both
    # are part of queue_key AND dedup_key: requests of different tenants
    # or sessions never share a dispatch batch or join each other's
    # handles, so (a) per-session ExecStats are a pure function of that
    # session's own submission order (byte-identical across
    # interleavings), and (b) cancelling one session can drop its whole
    # queued backlog without touching another session's handles.
    tenant: str = ""
    session: str = ""
    # absolute end-to-end deadline on the time.monotonic() scale (0 = no
    # deadline).  Set once per query from `deadline_ms` (§5.3 option
    # precedence / front-door request body) and shared by every request
    # of the query, so it needs no place in queue_key: batches are
    # session-pure and a session runs one query at a time.  Expired
    # requests are dropped at dispatch with `DeadlineExceeded` instead of
    # being sent to the backend.
    deadline_ts: float = 0.0

    @property
    def queue_key(self) -> Tuple:
        # shared_prefix included so every dispatch batch is
        # prefix-homogeneous (executors apply one prefix per batch)
        return (self.model_name, self.instruction, self.schema,
                self.shared_prefix, self.stage, self.tenant, self.session)

    @property
    def dedup_key(self) -> Tuple:
        return (self.model_name, self.instruction, self.schema,
                self.shared_prefix, self.prompt, self.num_rows, self.stage,
                self.tenant, self.session)


class InferenceHandle:
    """Future for one dispatched (or joined) request.

    Lifecycle: QUEUED (in a service queue) → DISPATCHING (popped for a
    dispatch batch; `_event` is set iff the batch runs on a worker thread)
    → DONE (`_result` or `_error` set).  A handle dropped from its queue
    without dispatch (cancel, shutdown, a failed flush) stays result-less
    and `result()` raises."""
    __slots__ = ("request", "_service", "_result", "_error", "_event",
                 "refs")

    def __init__(self, request: InferenceRequest, service: "InferenceService"):
        self.request = request
        self._service = service
        self._result: Optional[CallResult] = None
        self._error: Optional[BaseException] = None
        self._event: Optional[threading.Event] = None
        self.refs = 1                  # submitters sharing this handle

    @property
    def done(self) -> bool:
        return self._result is not None or self._error is not None

    def result(self) -> CallResult:
        if not self.done:
            self._service._force(self)
        if self._error is not None:
            raise self._error
        if self._result is None:
            raise RuntimeError("inference request cancelled before dispatch")
        return self._result


@dataclasses.dataclass
class SessionCounters:
    """Per-session dispatch accounting (front-door streams).  Because a
    session's requests never share a batch with another session's (the
    session tag is part of queue_key), these are well-defined per-session
    numbers, not an attribution heuristic — they are the session-scoped
    analog of the global before/after deltas `IPDB.sql` takes on
    ServiceStats, which would double-count under concurrent sessions."""
    submitted: int = 0
    dispatched_calls: int = 0
    dispatch_batches: int = 0
    inflight_dedup_hits: int = 0
    cancelled_requests: int = 0        # queued handles dropped by a cancel
    transient_retries: int = 0         # operator retries after TransientError
    deadline_drops: int = 0            # requests dropped past their deadline
    backend_timeouts: int = 0          # dispatch batches killed by call timeout
    breaker_rejections: int = 0        # requests shed by an open breaker


@dataclasses.dataclass
class ServiceStats:
    submitted: int = 0
    dispatched_calls: int = 0          # executor calls actually made
    dispatch_batches: int = 0          # complete_many invocations
    inflight_dedup_hits: int = 0       # submits that joined a pending handle
    # worker-pool accounting (not surfaced in per-query ExecStats: the
    # sync/async split is an execution detail, batch composition is not)
    async_batches: int = 0             # batches run on a worker thread
    speculative_batches: int = 0       # batches started by kick()
    # resilience accounting (see core/faults.py): surfaced per-query in
    # ExecStats and globally in EXPLAIN's -- resilience -- section
    transient_retries: int = 0         # operator retries after TransientError
    deadline_drops: int = 0            # requests dropped past their deadline
    backend_timeouts: int = 0          # dispatch batches killed by call timeout
    breaker_rejections: int = 0        # requests shed by an open breaker
    degraded_calls: int = 0            # cascade batches degraded to proxy-only

    @property
    def mean_batch_occupancy(self) -> float:
        if self.dispatch_batches == 0:
            return 0.0
        return self.dispatched_calls / self.dispatch_batches


class _Lane:
    """Per-backend dispatch lane: at most `workers` batches of one
    executor run concurrently; excess batches wait in `pending` and are
    started FIFO as running ones finish (so per-queue slice order is
    preserved without blocking a pool thread on a semaphore)."""
    __slots__ = ("workers", "active", "pending")

    def __init__(self, workers: int):
        self.workers = max(1, int(workers))
        self.active = 0
        self.pending: Deque["_DispatchTask"] = collections.deque()


@dataclasses.dataclass
class _DispatchTask:
    """One dispatch batch: a slice of one queue, ready to execute (its
    in-flight keys are already cleared)."""
    handles: List[InferenceHandle]
    speculative: bool = False


class InferenceService:
    """Batching request broker between predict operators and executors.

    `submit()` enqueues; nothing reaches an executor until `flush()`
    (called implicitly by `InferenceHandle.result()`) or a speculative
    `kick()`, so pipelined operators can stack several windows of requests
    and have them dispatched as one batch per (model, instruction) queue."""

    #: upper bound on concurrently running dispatch batches, all backends
    POOL_THREADS = min(32, 4 * (os.cpu_count() or 4))

    def __init__(self, *, max_dispatch: int = 0, stats_store=None,
                 cost_model=None, speculative: bool = True):
        # guards queues, in-flight map, lanes, counters and handle state
        # transitions; executor calls NEVER run under it
        self._lock = threading.RLock()
        self._idle = threading.Condition(self._lock)
        # queues preserve submission order (dict insertion order)
        self._queues: Dict[Tuple, List[InferenceHandle]] = {}
        self._inflight: Dict[Tuple, InferenceHandle] = {}
        self._lanes: Dict[int, _Lane] = {}
        self._pool: Optional[ThreadPoolExecutor] = None
        self._outstanding = 0          # scheduled-but-unfinished async tasks
        self._closed = False
        self.max_dispatch = int(max_dispatch)   # 0 = unbounded batch
        self.speculative = bool(speculative)
        self.stats = ServiceStats()
        # resilience policy (database stamps these from the §5.3 option
        # precedence before each query).  call_timeout_s = 0 keeps the
        # exact old unbounded-call behavior; breakers only ever act after
        # transient failures, so a healthy backend never notices them.
        self.call_timeout_s = 0.0
        self.breaker_threshold = 3
        self.breaker_probe_every = 4
        # per-backend breakers keyed by model name — stable across the
        # per-operator executor instances and across queries (the service
        # is database-owned), which is what lets a tripped breaker shed
        # load for every later query until a probe succeeds
        self._breakers: Dict[str, CircuitBreaker] = {}
        # front-door accounting: per-session dispatch counters and
        # per-tenant dispatched-call totals (fairness-ratio reporting),
        # plus the tombstone set of cancelled sessions (submits from a
        # cancelled session fail fast instead of re-queueing work)
        self._sessions: Dict[str, SessionCounters] = {}
        self._tenant_calls: Dict[str, int] = collections.defaultdict(int)
        self._cancelled_sessions: set = set()
        # optional adaptive StatisticsStore: every dispatched call records
        # its tokens + modeled latency under the request's stats_key
        self.stats_store = stats_store
        # optional PR 3 CostModel: drives smallest-expected-makespan-first
        # flush prioritization (falls back to a local estimate when absent)
        self.cost_model = cost_model

    # -- submission ------------------------------------------------------
    def open_group(self, workers: int = 16, rpm: float = 0.0) -> DispatchGroup:
        return DispatchGroup(max(1, int(workers)), float(rpm))

    def submit_one(self, request: InferenceRequest
                   ) -> Tuple[InferenceHandle, bool]:
        """Enqueue one request.  Returns (handle, owned): owned is False
        when the request joined an identical pending handle (in-flight
        dedup) — the joiner must not account the call's tokens."""
        with self._lock:
            if self._closed:
                raise RuntimeError("InferenceService is shut down")
            if request.session and request.session in self._cancelled_sessions:
                # the session's scope fired: nothing new may enter the
                # queues on its behalf (retries/fallbacks die fast here
                # instead of re-queueing work the client walked away from)
                raise QueryCancelled(
                    f"session {request.session!r} cancelled")
            self.stats.submitted += 1
            sess = self._session_counters(request.session)
            if sess is not None:
                sess.submitted += 1
            if request.dedup:
                h = self._inflight.get(request.dedup_key)
                # joinable while the entry lives (queued, or speculatively
                # dispatched and not yet retired by a flush) — even if the
                # speculative batch already finished, so dedup outcomes
                # never depend on worker timing.  A failed handle is never
                # joined (its error must not propagate to new submitters).
                if h is not None and h._error is None:
                    h.refs += 1
                    self.stats.inflight_dedup_hits += 1
                    if sess is not None:
                        sess.inflight_dedup_hits += 1
                    return h, False
            h = InferenceHandle(request, self)
            self._queues.setdefault(request.queue_key, []).append(h)
            if request.dedup:
                self._inflight[request.dedup_key] = h
            return h, True

    def submit(self, requests: Sequence[InferenceRequest]
               ) -> List[InferenceHandle]:
        return [self.submit_one(r)[0] for r in requests]

    # -- prioritization --------------------------------------------------
    def expected_queue_makespan(self, handles: Sequence[InferenceHandle]
                                ) -> float:
        """Expected makespan of dispatching `handles` as one queue: the
        PR 3 CostModel when available (observed mean per-call latency from
        the statistics store, greedy worker/rpm reduction), else the same
        computation with the default latency model over a prompt-length
        token estimate."""
        req = handles[0].request
        n = len(handles)
        in_t = sum(len(h.request.prompt) for h in handles) / (4.0 * n)
        fallback = default_latency_model(in_t, 4.0 * max(1, len(req.schema)))
        if self.cost_model is not None:
            return self.cost_model.queue_makespan(req.stats_key, n, fallback)
        per = None
        if self.stats_store is not None and req.stats_key:
            rec = self.stats_store.get(req.stats_key)
            if rec is not None and rec.calls:
                per = rec.mean_latency_s
        return makespan([fallback if per is None else per] * n, 16)

    def prioritized(self) -> List[Tuple]:
        """Queue keys in dispatch-priority order: ascending expected
        makespan, ties broken by submission order (stable), so every
        flush drains every queue — prioritization reorders, never
        starves."""
        with self._lock:
            return self._priority_order()

    def _priority_order(self) -> List[Tuple]:
        ranked = []
        for i, (qkey, handles) in enumerate(self._queues.items()):
            if handles:
                ranked.append((self.expected_queue_makespan(handles), i,
                               qkey))
        ranked.sort(key=lambda t: (t[0], t[1]))
        return [qkey for _, _, qkey in ranked]

    # -- dispatch --------------------------------------------------------
    def _take_slices(self, qkey: Tuple, *, speculative: bool = False
                     ) -> List[_DispatchTask]:
        """Pop dispatchable slices of one queue (caller holds the lock).
        Slice boundaries are a pure function of submission order and
        `max_dispatch` — identical whether taken by flush() or kick() —
        so batch composition never depends on dispatch timing.

        In-flight dedup keys are cleared here for flush() takes — flush IS
        the synchronous dispatch point, after which an identical submit
        must re-dispatch.  Speculative takes leave their keys joinable
        (and take only complete slices, a trailing partial stays queued):
        a duplicate submitted before the next flush joins the handle
        exactly as it would have joined the still-queued handle under
        synchronous dispatch, keeping dedup outcomes — hence ExecStats —
        a pure function of submission order, not of when kick() ran.  The
        keys are purged at the next flush (`_purge_dispatched`); a batch
        that failed cannot be joined either way, since `_error` marks its
        handles done."""
        handles = self._queues.get(qkey) or []
        step = self.max_dispatch if self.max_dispatch > 0 else len(handles)
        if step <= 0:
            return []
        n_take = (len(handles) // step) * step if speculative \
            else len(handles)
        if n_take == 0:
            return []
        take, rest = handles[:n_take], handles[n_take:]
        if rest:
            self._queues[qkey] = rest
        else:
            self._queues.pop(qkey, None)
        tasks = []
        for s in range(0, len(take), step):
            batch = take[s:s + step]
            if not speculative:
                for h in batch:
                    if h.request.dedup:
                        self._inflight.pop(h.request.dedup_key, None)
            tasks.append(_DispatchTask(batch, speculative=speculative))
        return tasks

    def _purge_dispatched(self) -> None:
        """Drop in-flight entries whose dispatch already started (left
        joinable by speculative kicks) — flush is the moment synchronous
        dispatch would have retired them (caller holds the lock)."""
        stale = [k for k, h in self._inflight.items()
                 if h.done or h._event is not None]
        for k in stale:
            del self._inflight[k]

    def _workers_for(self, task: _DispatchTask) -> int:
        return task.handles[0].request.executor.dispatch_workers()

    def flush(self) -> None:
        """Dispatch every queued request, smallest expected makespan
        first.  Each per-queue slice of at most `max_dispatch` requests is
        one dispatch batch: one `complete_many` executor call.  Batches
        for backends that declare dispatch concurrency are scheduled on
        their worker lanes (non-blocking) BEFORE the synchronous batches
        run inline, so background dispatch overlaps the inline work."""
        inline: List[_DispatchTask] = []
        background: List[_DispatchTask] = []
        with self._lock:
            self._purge_dispatched()
            for qkey in self._priority_order():
                for task in self._take_slices(qkey):
                    if self._workers_for(task) > 1:
                        background.append(task)
                    else:
                        inline.append(task)
            for task in background:
                self._schedule(task)
        # an executor failure marks its own batch's handles (they raise at
        # result()) but must not strand the other popped batches — dispatch
        # them all, then re-raise the first failure like the old
        # queue-at-a-time flush did
        first_err: Optional[BaseException] = None
        for task in inline:
            try:
                self._dispatch(task.handles)
            except BaseException as e:
                if first_err is None:
                    first_err = e
        if first_err is not None:
            raise first_err

    def drain_for(self, handles: Sequence[InferenceHandle]) -> None:
        """Dispatch until every given handle is dispatched or scheduled.
        Slices are taken in the same priority order and with the same
        prefix-of-the-queue composition as flush(), but the take stops at
        the slice containing the LAST target handle: requests queued
        behind the targets — later inflight windows, other sessions'
        work — stay queued for their own resolve.  That is what makes
        early-exit real: a Limit that closes its pipeline can still
        cancel the next window's requests before any flush dispatches
        them (with max_dispatch=0 a queue is a single slice, so this
        degenerates to flush's whole-queue dispatch and nothing changes).
        Batch membership remains a pure function of submission order."""
        first_err: Optional[BaseException] = None
        targets = set(handles)
        while True:
            inline: List[_DispatchTask] = []
            with self._lock:
                self._purge_dispatched()
                todo = {h.request.queue_key for h in targets
                        if not h.done and h._event is None}
                if not todo:
                    break
                progressed = False
                for qkey in self._priority_order():
                    if qkey not in todo:
                        continue
                    for task in self._take_slices_for(qkey, targets):
                        progressed = True
                        if self._workers_for(task) > 1:
                            self._schedule(task)
                        else:
                            inline.append(task)
                if not progressed:
                    break       # targets left the queues (cancelled)
            for task in inline:
                try:
                    self._dispatch(task.handles)
                except BaseException as e:
                    if first_err is None:
                        first_err = e
        if first_err is not None:
            raise first_err

    def _take_slices_for(self, qkey: Tuple, targets: set
                         ) -> List[_DispatchTask]:
        """Like `_take_slices` (non-speculative), but only the prefix of
        the queue through the last target handle, rounded up to a slice
        boundary (caller holds the lock)."""
        handles = self._queues.get(qkey) or []
        step = self.max_dispatch if self.max_dispatch > 0 else len(handles)
        if step <= 0:
            return []
        last = -1
        for i, h in enumerate(handles):
            if h in targets:
                last = i
        if last < 0:
            return []
        n_take = ((last // step) + 1) * step
        take, rest = handles[:n_take], handles[n_take:]
        if rest:
            self._queues[qkey] = rest
        else:
            self._queues.pop(qkey, None)
        tasks = []
        for s in range(0, len(take), step):
            batch = take[s:s + step]
            for h in batch:
                if h.request.dedup:
                    self._inflight.pop(h.request.dedup_key, None)
            tasks.append(_DispatchTask(batch))
        return tasks

    def kick(self) -> None:
        """Speculative flush of hot queues: start, in the background, the
        complete `max_dispatch`-sized slices that a later flush() would
        dispatch anyway, for backends with dispatch concurrency.  Called
        by operators after submitting a window so dispatch overlaps the
        production of the next window, before `inflight_windows` fills.
        A no-op when `max_dispatch` is 0 (an unbounded flush batches the
        whole queue in one call — dispatching early would change batch
        composition) or when the backend is synchronous."""
        if not self.speculative or self.max_dispatch <= 0:
            return
        with self._lock:
            if self._closed:
                return
            # no prioritization here: every eligible slice is handed to a
            # background lane anyway, and kick runs after every submitted
            # window — keep it O(queues), not O(pending requests)
            for qkey in list(self._queues):
                handles = self._queues.get(qkey)
                if not handles or len(handles) < self.max_dispatch:
                    continue
                if handles[0].request.executor.dispatch_workers() <= 1:
                    continue
                for task in self._take_slices(qkey, speculative=True):
                    self._schedule(task)

    # -- worker lanes ----------------------------------------------------
    def _schedule(self, task: _DispatchTask) -> None:
        """Hand one batch to its backend's lane (caller holds the lock)."""
        for h in task.handles:
            h._event = threading.Event()
        if self._pool is None:
            self._pool = ThreadPoolExecutor(
                max_workers=self.POOL_THREADS,
                thread_name_prefix="ipdb-dispatch")
        ex = task.handles[0].request.executor
        lane = self._lanes.get(id(ex))
        if lane is None:
            lane = self._lanes[id(ex)] = _Lane(self._workers_for(task))
        else:
            lane.workers = self._workers_for(task)
        self._outstanding += 1
        if task.speculative:
            self.stats.speculative_batches += 1
        lane.pending.append(task)
        self._pump(lane)

    def _pump(self, lane: _Lane) -> None:
        while lane.active < lane.workers and lane.pending:
            task = lane.pending.popleft()
            lane.active += 1
            self._pool.submit(self._run_task, lane, task)

    def _run_task(self, lane: _Lane, task: _DispatchTask) -> None:
        try:
            self._dispatch(task.handles, background=True)
        except Exception:
            pass                       # recorded on the handles already
        finally:
            with self._lock:
                lane.active -= 1
                self._outstanding -= 1
                if self._pool is not None:
                    self._pump(lane)
                if self._outstanding == 0:
                    self._idle.notify_all()

    # -- resilience ------------------------------------------------------
    def breaker_for(self, model_name: str) -> CircuitBreaker:
        """The (lazily created) circuit breaker guarding one backend."""
        with self._lock:
            b = self._breakers.get(model_name)
            if b is None:
                b = self._breakers[model_name] = CircuitBreaker(
                    model_name, failure_threshold=self.breaker_threshold,
                    probe_every=self.breaker_probe_every)
            return b

    def set_breaker_policy(self, threshold: int, probe_every: int) -> None:
        """Apply a (possibly changed) breaker policy to future AND already
        existing breakers — SET breaker_threshold must not be ignored just
        because a backend already saw traffic."""
        with self._lock:
            self.breaker_threshold = max(1, int(threshold))
            self.breaker_probe_every = max(1, int(probe_every))
            for b in self._breakers.values():
                b.failure_threshold = self.breaker_threshold
                b.probe_every = self.breaker_probe_every

    def breaker_open(self, model_name: str = "") -> bool:
        """True when the named breaker (or, with "", any breaker) is not
        closed — the front door's 503 admission signal."""
        with self._lock:
            if model_name:
                b = self._breakers.get(model_name)
                return b is not None and b.state != CLOSED
            return any(b.state != CLOSED for b in self._breakers.values())

    def breaker_snapshots(self) -> Dict[str, Dict[str, object]]:
        """Counters for every breaker that has seen a failure/rejection
        (EXPLAIN's -- resilience -- section; quiet breakers are elided)."""
        with self._lock:
            brs = list(self._breakers.items())
        return {name: b.snapshot() for name, b in brs
                if b.failures or b.rejections or b.state != CLOSED}

    def note_transient_retry(self, session: str = "", n: int = 1) -> None:
        """Operator hook: a resolve path re-submitted after a transient
        failure (counted here so the resilience section and per-session
        ExecStats see retries the executors never know about)."""
        with self._lock:
            self.stats.transient_retries += n
            sess = self._session_counters(session)
            if sess is not None:
                sess.transient_retries += n

    def note_deadline_drop(self, session: str = "", n: int = 1) -> None:
        """Operator hook: work abandoned because the deadline expired
        before it could even be submitted/retried."""
        with self._lock:
            self.stats.deadline_drops += n
            sess = self._session_counters(session)
            if sess is not None:
                sess.deadline_drops += n

    def _fail_batch(self, handles: List[InferenceHandle],
                    err: BaseException) -> None:
        with self._lock:
            for h in handles:
                h._error = err
                if h._event is not None:
                    h._event.set()

    def _call_executor(self, executor: Predictor,
                       reqs: List[InferenceRequest]) -> List[CallResult]:
        """One `complete_many` call, bounded by `call_timeout_s` when set.

        The bounded path runs the call on a daemon guard thread and joins
        it with the timeout: a hung backend strands only that zombie
        thread (its late result is discarded), while the lane worker
        returns `BackendTimeout` — so a wedged executor can no longer pin
        its lane, `drain()`, or `shutdown()` forever.  0 disables the
        guard and is byte-for-byte the old direct call."""
        args = ([r.prompt for r in reqs], list(reqs[0].schema),
                [r.num_rows for r in reqs])
        kwargs = dict(shared_prefix=reqs[0].shared_prefix,
                      rows_list=[r.rows for r in reqs],
                      instruction=reqs[0].instruction)
        timeout = float(self.call_timeout_s or 0.0)
        if timeout <= 0.0:
            return executor.complete_many(*args, **kwargs)
        box: Dict[str, object] = {}
        done = threading.Event()

        def _guard():
            try:
                box["res"] = executor.complete_many(*args, **kwargs)
            except BaseException as e:
                box["err"] = e
            finally:
                done.set()

        threading.Thread(target=_guard, daemon=True,
                         name="ipdb-call-guard").start()
        if not done.wait(timeout):
            raise BackendTimeout(
                f"{reqs[0].model_name}: dispatch batch of {len(reqs)} "
                f"exceeded call_timeout_s={timeout:g}; result discarded")
        if "err" in box:
            raise box["err"]  # type: ignore[misc]
        return box["res"]  # type: ignore[return-value]

    def _dispatch(self, handles: List[InferenceHandle],
                  background: bool = False) -> None:
        reqs = [h.request for h in handles]
        executor = reqs[0].executor
        # deadline propagation: expired work is dropped, not dispatched.
        # The whole batch shares one query's deadline (batches are
        # session-pure and deadline_ts is stamped once per query).
        dl = reqs[0].deadline_ts
        if dl and time.monotonic() >= dl:
            with self._lock:
                self.stats.deadline_drops += len(reqs)
                sess = self._session_counters(reqs[0].session)
                if sess is not None:
                    sess.deadline_drops += len(reqs)
            self._fail_batch(handles, DeadlineExceeded(
                f"deadline expired before dispatch "
                f"({len(reqs)} requests dropped)"))
            return
        breaker = self.breaker_for(reqs[0].model_name)
        if not breaker.allow():
            with self._lock:
                self.stats.breaker_rejections += len(reqs)
                sess = self._session_counters(reqs[0].session)
                if sess is not None:
                    sess.breaker_rejections += len(reqs)
            self._fail_batch(handles, CircuitOpenError(
                f"circuit open for backend {reqs[0].model_name!r}"))
            return
        try:
            results = self._call_executor(executor, reqs)
        except BaseException as e:
            if isinstance(e, BackendTimeout):
                with self._lock:
                    self.stats.backend_timeouts += 1
                    sess = self._session_counters(reqs[0].session)
                    if sess is not None:
                        sess.backend_timeouts += 1
            self._fail_batch(handles, e)
            # transient-class failures feed the breaker and are recorded
            # on the handles only — retry policy belongs to the resolving
            # operator, and one backend's hiccup must not propagate out of
            # flush()/drain_for() into an unrelated operator's resolve.
            # Non-transient errors bypass the breaker (they indicate a
            # caller bug, not backend health) and re-raise like before.
            if isinstance(e, TransientError):
                breaker.record_failure()
                return
            raise
        breaker.record_success()
        with self._lock:
            self.stats.dispatch_batches += 1
            self.stats.dispatched_calls += len(reqs)
            if background:
                self.stats.async_batches += 1
            # batches are session/tenant-homogeneous (tags are part of
            # queue_key), so whole-batch attribution is exact
            sess = self._session_counters(reqs[0].session)
            if sess is not None:
                sess.dispatch_batches += 1
                sess.dispatched_calls += len(reqs)
            if reqs[0].tenant:
                self._tenant_calls[reqs[0].tenant] += len(reqs)
            for h, res in zip(handles, results):
                h._result = res
                if h._event is not None:
                    h._event.set()
                # cascade batches degraded to proxy-only stamp the count
                # on their first merged CallResult (like the other
                # whole-batch cascade counters)
                dc = getattr(res, "degraded_calls", 0)
                if dc:
                    self.stats.degraded_calls += dc
        if self.stats_store is not None:
            for h, res in zip(handles, results):
                if h.request.stats_key:
                    self.stats_store.record_call(
                        staged_key(h.request.stats_key, h.request.stage),
                        res.in_tokens, res.out_tokens, res.sim_latency_s)

    # -- forcing / lifecycle ---------------------------------------------
    def _force(self, handle: InferenceHandle) -> None:
        """Block until `handle` is resolved: flush if it is still queued,
        then wait for its dispatch batch if one is running."""
        if not handle.done and handle._event is None:
            self.flush()               # still queued (or cancelled)
        ev = handle._event
        if ev is not None:
            ev.wait()

    def drain(self) -> None:
        """Flush until no request remains queued, then wait for every
        background dispatch batch to finish."""
        while True:
            with self._lock:
                if not any(self._queues.values()):
                    break
            self.flush()
        self.wait_idle()

    def wait_idle(self, timeout: Optional[float] = None) -> bool:
        """Wait until no background dispatch is outstanding.  Returns
        False on timeout."""
        with self._idle:
            return self._idle.wait_for(lambda: self._outstanding == 0,
                                       timeout=timeout)

    def shutdown(self, *, cancel_pending: bool = False) -> None:
        """Stop the service and join every worker thread (idempotent).
        With `cancel_pending` still-queued requests are dropped (their
        handles raise on `result()`); otherwise they are drained first.
        Either way, batches already running complete — a flush that has
        started is never interrupted mid-executor-call."""
        if not cancel_pending:
            self.drain()
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for handles in self._queues.values():
                for h in handles:
                    if h.request.dedup:
                        self._inflight.pop(h.request.dedup_key, None)
            self._queues.clear()
            # lane backlogs (scheduled but not yet running) will never be
            # pumped once the pool is gone: resolve their handles to a
            # shutdown error and release their outstanding counts, or
            # wait_idle below would block forever
            err = RuntimeError("InferenceService shut down before dispatch")
            for lane in self._lanes.values():
                while lane.pending:
                    task = lane.pending.popleft()
                    self._outstanding -= 1
                    for h in task.handles:
                        h._error = err
                        if h._event is not None:
                            h._event.set()
            self._lanes.clear()
            pool, self._pool = self._pool, None
            if self._outstanding == 0:
                self._idle.notify_all()
        if pool is not None:
            pool.shutdown(wait=True)
        self.wait_idle()

    def cancel(self, handle: InferenceHandle) -> bool:
        """Release one submitter's interest in a still-queued handle
        (pipelined operator closed early, e.g. under an early-exit Limit).
        The request is removed from its queue only when the last
        submitter cancels — joined submitters keep it alive.  A handle
        whose dispatch batch already started (flush or speculative kick)
        cannot be recalled: cancel returns False and the running batch
        completes normally.

        Refcount edge (regression-tested): the count is floored at 0 so a
        cancel that arrives after the handle was force-failed (session
        cancel, shutdown) or double-cancelled through two unwinding
        pipelines can never underflow and strip a ref a still-waiting
        joiner is counting on."""
        with self._lock:
            if handle.done:
                return False
            handle.refs = max(0, handle.refs - 1)
            if handle.refs > 0:
                return False
            q = self._queues.get(handle.request.queue_key)
            if q and handle in q:
                q.remove(handle)
                if not q:
                    self._queues.pop(handle.request.queue_key, None)
                if handle.request.dedup:
                    self._inflight.pop(handle.request.dedup_key, None)
                sess = self._session_counters(handle.request.session)
                if sess is not None:
                    sess.cancelled_requests += 1
                return True
            return False

    # -- front-door sessions ---------------------------------------------
    def _session_counters(self, session: str) -> Optional[SessionCounters]:
        """Counters for a tagged session ("" = untagged → None).  Caller
        holds the lock."""
        if not session:
            return None
        sess = self._sessions.get(session)
        if sess is None:
            sess = self._sessions[session] = SessionCounters()
        return sess

    def session_stats(self, session: str) -> SessionCounters:
        with self._lock:
            return dataclasses.replace(
                self._sessions.get(session) or SessionCounters())

    def tenant_dispatched(self, tenant: str) -> int:
        """Executor calls dispatched so far on behalf of `tenant` — the
        fairness scheduler's post-paid cost signal."""
        with self._lock:
            return self._tenant_calls.get(tenant, 0)

    def session_pending(self, session: str) -> int:
        """Still-queued requests tagged with `session` (leak check)."""
        with self._lock:
            return sum(1 for handles in self._queues.values()
                       for h in handles if h.request.session == session)

    def cancel_session(self, session: str) -> int:
        """Cancel-scope hook: drop every still-queued request of one
        session NOW, from the cancelling thread, without waiting for the
        executing pipeline to unwind.  Dropped handles fail with
        `QueryCancelled` (waking any blocked `result()`), lane backlogs
        that were scheduled but have not started are dropped too, and
        further submits for the session are rejected.  Batches whose
        executor call already started complete normally — cancellation
        takes effect within one flush, never mid-call.  Returns the
        number of requests dropped."""
        if not session:
            return 0
        err = QueryCancelled(f"session {session!r} cancelled")
        dropped = 0
        with self._lock:
            self._cancelled_sessions.add(session)
            for qkey in list(self._queues):
                handles = self._queues[qkey]
                if not handles or handles[0].request.session != session:
                    continue                   # queues are session-pure
                del self._queues[qkey]
                for h in handles:
                    if h.request.dedup:
                        self._inflight.pop(h.request.dedup_key, None)
                    h.refs = 0
                    h._error = err
                    if h._event is not None:
                        h._event.set()
                    dropped += 1
            # scheduled-but-not-started lane tasks: same treatment as
            # shutdown's backlog release (outstanding count must drop or
            # wait_idle deadlocks)
            for lane in self._lanes.values():
                keep: Deque[_DispatchTask] = collections.deque()
                while lane.pending:
                    task = lane.pending.popleft()
                    if task.handles[0].request.session != session:
                        keep.append(task)
                        continue
                    self._outstanding -= 1
                    for h in task.handles:
                        if h.request.dedup:
                            self._inflight.pop(h.request.dedup_key, None)
                        h.refs = 0
                        h._error = err
                        if h._event is not None:
                            h._event.set()
                        dropped += 1
                lane.pending = keep
            sess = self._session_counters(session)
            if sess is not None:
                sess.cancelled_requests += dropped
            if self._outstanding == 0:
                self._idle.notify_all()
        return dropped

    def release_session(self, session: str) -> None:
        """Forget a finished session's tombstone + counters (the front
        door calls this when the session object is torn down, so the
        per-session maps stay bounded by live sessions)."""
        with self._lock:
            self._cancelled_sessions.discard(session)
            self._sessions.pop(session, None)

    @property
    def pending(self) -> int:
        with self._lock:
            return sum(len(v) for v in self._queues.values())

    @property
    def inflight_batches(self) -> int:
        with self._lock:
            return self._outstanding

    def describe(self) -> str:
        return (f"InferenceService queues={len(self._queues)} "
                f"pending={self.pending} max_dispatch="
                f"{self.max_dispatch or 'unbounded'} "
                f"speculative={self.speculative}")
