"""Kernel microbench: interpret-mode Pallas vs jnp-reference wall time on
CPU (structural check only — real perf numbers come from the roofline
analysis; interpret mode executes the kernel body in Python)."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3, **kw):
    fn(*args, **kw)                      # compile/warm
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args, **kw))
    return (time.time() - t0) / reps


def run(quick: bool = False):
    key = jax.random.PRNGKey(0)
    rows = []
    B, S, H, KV, D = 1, 256, 4, 2, 64
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(key, (B, S, KV, D))
    v = jax.random.normal(key, (B, S, KV, D))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    G = H // KV
    qr = q.reshape(B, S, KV, G, D).transpose(0, 2, 3, 1, 4).reshape(B * KV, G, S, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    pr = jnp.repeat(pos, KV, axis=0)
    t_ref = _time(ref.flash_attention_ref, qr, kr, vr, pr, pr)
    rows.append(("kernels.flash_attention.jnp_ref", round(t_ref * 1e6, 1),
                 f"S={S};H={H};D={D}"))
    if not quick:
        t_pal = _time(ops.flash_attention, q, k, v, pos, pos, interpret=True,
                      block_q=128, block_kv=128)
        rows.append(("kernels.flash_attention.pallas_interpret",
                     round(t_pal * 1e6, 1), "interpret-mode (CPU python loop)"))

    logits = jax.random.normal(key, (8, 50304))
    mask = jax.random.uniform(key, (8, 50304)) > 0.5
    t_ref = _time(ref.constrained_sample_ref, logits, mask,
                  jnp.zeros_like(logits))
    rows.append(("kernels.constrained_sample.jnp_ref", round(t_ref * 1e6, 1),
                 "B=8;V=50304"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
