"""Roofline table from the dry-run artifacts (§Roofline deliverable).

Reads results/dryrun/*.json (written by repro.launch.dryrun), emits the
per-(arch × shape) three-term table, the dominant bottleneck, the
MODEL_FLOPS/HLO_FLOPs useful ratio, and an ANALYTIC HBM lower bound
(params + activations + cache traffic) for context — the measured
HLO-bytes term counts every unfused operand/result access and therefore
upper-bounds real traffic (see EXPERIMENTS.md §Methodology).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

import repro.configs as C
from repro.models.config import SHAPES_BY_NAME

PEAK = 197e12
HBM = 819e9
ICI = 50e9
CHIPS = 256


def analytic_hbm_bytes(arch: str, shape_name: str, num_micro: int = 4) -> float:
    """Per-device HBM lower bound: weights touched per step + residual-
    stream activations + KV/state cache traffic."""
    cfg = C.get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    n_params = cfg.param_count(padded=True)
    if shape.kind == "train":
        # fwd read + bwd read + grad write (bf16 compute copies) per
        # microbatch + Adam update (3 reads + 3 writes fp32) once
        w = n_params * (2 * 3 * num_micro + 6 * 4) / CHIPS
        tokens = shape.global_batch * shape.seq_len
        acts = tokens * cfg.d_model * 2 * 2 * 8 * cfg.num_layers / CHIPS
        return w + acts
    if shape.kind == "prefill":
        w = n_params * 2 / CHIPS
        tokens = shape.global_batch * shape.seq_len
        acts = tokens * cfg.d_model * 2 * 2 * 4 * cfg.num_layers / CHIPS
        return w + acts
    # decode: weights once + cache read/write
    w = n_params * 2 / CHIPS
    lc = min(shape.seq_len, cfg.sliding_window) if cfg.sliding_window \
        else shape.seq_len
    cache = 0.0
    if cfg.has_attention:
        cache = (cfg.num_layers * shape.global_batch * lc *
                 cfg.num_kv_heads * cfg.head_dim * 2 * 2) / CHIPS
    if cfg.has_ssm:
        cache += (cfg.num_layers * shape.global_batch * cfg.d_inner *
                  (cfg.ssm_state + cfg.ssm_conv) * 4 * 2) / CHIPS
    return w + cache


def load_cells(dry_dir: str = "results/dryrun"):
    out = {}
    for p in Path(dry_dir).glob("*__single.json"):
        d = json.loads(p.read_text())
        if d.get("ok") and not d.get("skipped"):
            out[(d["arch"], d["shape"])] = d
    return out


def run(quick: bool = False, dry_dir: str = "results/dryrun"):
    rows = []
    cells = load_cells(dry_dir)
    for (arch, shape), d in sorted(cells.items()):
        rf = d["roofline"]
        dom = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        frac = rf["compute_s"] / dom if dom else 0.0
        ana = analytic_hbm_bytes(arch, shape)
        rows.append((
            f"roofline.{arch}.{shape}",
            round(dom * 1e6, 1),
            f"bound={rf['bound']};compute_s={rf['compute_s']:.4f};"
            f"memory_s={rf['memory_s']:.4f};"
            f"collective_s={rf['collective_s']:.4f};"
            f"useful_ratio={rf['useful_flops_ratio']:.3f};"
            f"compute_fraction={frac:.3f};"
            f"analytic_hbm_s={ana / HBM:.4f}"))
    if not rows:
        rows.append(("roofline.missing", None,
                     "run: python -m repro.launch.dryrun --all"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
