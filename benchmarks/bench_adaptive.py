"""Adaptive-statistics benchmark: skewed-selectivity stacked semantic
selects where pilot-calibrated, cost-based ordering beats the static
token-size heuristic.

Workload: two commuting semantic selects over one table with inverted
skew — the SHORT-input predicate keeps ~90% of rows, the LONG-input
predicate keeps ~5%.  The static heuristic (order by input size) runs the
short predicate first and pays for both predicates over most of the
table; the adaptive optimizer pilot-samples both predicates (16 rows
each), learns the selectivities, and runs the rare predicate first.

Systems:
  static        enable_pilot off, cold statistics store → size heuristic
  adaptive      pilot sampling on (cold store)
  adaptive_warm the same database re-queried: the store has observed
                statistics and the prompt cache has every answer

The run asserts the acceptance criteria: adaptive strictly reduces total
modeled calls (pilot calls included) AND modeled makespan vs static, with
bit-identical query results.
"""
from repro.core.database import IPDB
from repro.relational.table import Table

FILLER = "lorem ipsum dolor sit amet consectetur adipiscing elit " * 6


def _mk(n):
    return [{"rid": i, "short_txt": f"s{i}", "long_txt": FILLER + f"doc {i}"}
            for i in range(n)]


def oracle(instruction, rows):
    out = []
    for r in rows:
        if "long_txt" in r:
            i = int(str(r["long_txt"]).split()[-1])
            out.append({"rare": i % 20 == 0})        # ~5% pass
        else:
            i = int(str(r["short_txt"])[1:])
            out.append({"common": i % 10 != 1})      # ~90% pass
    return out


QUERY = ("SELECT rid FROM R WHERE "
         "LLM m (PROMPT 'is {rare BOOLEAN} in {{long_txt}}') = TRUE "
         "AND LLM m (PROMPT 'is {common BOOLEAN} in {{short_txt}}') = TRUE")


def _db(n, pilot):
    db = IPDB()
    db.register_table("R", Table.from_rows(_mk(n)))
    db.register_oracle("bench", oracle)
    db.sql("CREATE LLM MODEL m PATH 'oracle:bench' ON PROMPT")
    db.set_option("use_batching", False)     # per-row calls: clean counts
    db.set_option("enable_pilot", pilot)
    return db


def run(quick: bool = False):
    n = 120 if quick else 360
    db_s = _db(n, pilot=False)
    r_s = db_s.sql(QUERY)
    db_a = _db(n, pilot=True)
    r_a = db_a.sql(QUERY)
    r_w = db_a.sql(QUERY)                    # warm: stats + prompt cache

    if sorted(r_s.table.column("rid")) != sorted(r_a.table.column("rid")):
        raise AssertionError("adaptive ordering changed query results")
    if sorted(r_s.table.column("rid")) != sorted(r_w.table.column("rid")):
        raise AssertionError("warm re-run changed query results")

    total_s = r_s.stats.llm_calls + r_s.stats.pilot_calls
    total_a = r_a.stats.llm_calls + r_a.stats.pilot_calls
    if total_a >= total_s:
        raise AssertionError(
            f"adaptive made {total_a} calls (incl. pilot) vs static "
            f"{total_s} — expected a strict reduction")
    if r_a.stats.sim_latency_s >= r_s.stats.sim_latency_s:
        raise AssertionError(
            f"adaptive makespan {r_a.stats.sim_latency_s:.2f}s vs static "
            f"{r_s.stats.sim_latency_s:.2f}s — expected a strict reduction")

    rows = []
    for name, r in (("static", r_s), ("adaptive", r_a),
                    ("adaptive_warm", r_w)):
        s = r.stats
        total = s.llm_calls + s.pilot_calls
        rows.append((
            f"adaptive.{name}",
            round(s.sim_latency_s / max(1, total) * 1e6, 1),
            f"calls={s.llm_calls};pilot={s.pilot_calls};total={total};"
            f"makespan_s={s.sim_latency_s:.2f};tokens={s.tokens};"
            f"rows={len(r.table)}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
